//! UTS demo: the paper's second benchmark under every victim policy.
//! Without stealing the entire tree executes on node 0 (child-follows-
//! parent placement); each policy is then compared on makespan and steal
//! traffic.
//!
//!     cargo run --release --example uts_demo [b0]
//!
//! The optional `b0` argument sizes the root fan-out (default 120 — the
//! paper's configuration; CI's smoke step passes a small value so the
//! tree stays subcritical and quick).

use std::sync::Arc;

use parsteal::comm::LinkModel;
use parsteal::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy};
use parsteal::sched::SchedBackend;
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::workloads::{UtsGraph, UtsParams};

fn main() {
    let b0: u32 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(120);
    let params = UtsParams {
        b0,
        m: 5,
        q: 0.200014,
        g: 500_000.0, // 0.5 ms per tree node under the default cost model
        seed: 0x075,
        nodes: 4,
        max_depth: 20,
    };
    let graph = Arc::new(UtsGraph::new(params));
    println!(
        "UTS b0={} m={} q={} g={:.0}: tree of {} nodes, 4 runtime nodes x 8 workers\n",
        params.b0,
        params.m,
        params.q,
        params.g,
        graph.tree_size(100_000_000)
    );

    let cells: Vec<(&str, MigrateConfig)> = vec![
        ("No-Steal", MigrateConfig::disabled()),
        (
            "Chunk(4)",
            MigrateConfig {
                victim: VictimPolicy::Chunk(4),
                ..Default::default()
            },
        ),
        (
            "Half",
            MigrateConfig {
                victim: VictimPolicy::Half,
                ..Default::default()
            },
        ),
        (
            "Single",
            MigrateConfig {
                victim: VictimPolicy::Single,
                ..Default::default()
            },
        ),
        (
            "Single/ready-only",
            MigrateConfig {
                victim: VictimPolicy::Single,
                thief: ThiefPolicy::ReadyOnly,
                ..Default::default()
            },
        ),
    ];

    for (label, migrate) in cells {
        let report = Simulator::new(
            graph.clone(),
            SimConfig {
                workers_per_node: 8,
                link: LinkModel::cluster(),
                seed: 11,
                max_events: u64::MAX,
                record_polls: false,
                sched: SchedBackend::Central,
                batch_activations: true,
                pool_floor: parsteal::sched::POOL_FLOOR,
                faults: Default::default(),
            },
            CostModel::default_calibrated(),
            migrate,
            0,
        )
        .run();
        let s = report.total_steals();
        println!(
            "{label:<18} makespan {:>8.2}s  per-node {:?}  steals {}/{} ({} tasks)",
            report.makespan_us / 1e6,
            report
                .nodes
                .iter()
                .map(|n| n.tasks_executed)
                .collect::<Vec<_>>(),
            s.successful_steals,
            s.requests_sent,
            s.tasks_migrated
        );
    }
    println!("\n(Half ≈ Single ≫ No-Steal: with child-follows-parent placement no new\n work ever appears on a starving node, so stealing is the only balancer)");
}
