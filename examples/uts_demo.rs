//! UTS demo: the paper's second benchmark under every victim policy.
//! Without stealing the entire tree executes on node 0 (child-follows-
//! parent placement); each policy is then compared on makespan and steal
//! traffic.
//!
//!     cargo run --release --example uts_demo [b0]
//!
//! The optional `b0` argument sizes the root fan-out (default 120 — the
//! paper's configuration; CI's smoke step passes a small value so the
//! tree stays subcritical and quick).

use std::sync::Arc;

use parsteal::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::workloads::{UtsGraph, UtsParams};

fn main() {
    let b0: u32 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(120);
    let params = UtsParams {
        b0,
        m: 5,
        q: 0.200014,
        g: 500_000.0, // 0.5 ms per tree node under the default cost model
        seed: 0x075,
        nodes: 4,
        max_depth: 20,
    };
    let graph = Arc::new(UtsGraph::new(params));
    println!(
        "UTS b0={} m={} q={} g={:.0}: tree of {} nodes, 4 runtime nodes x 8 workers\n",
        params.b0,
        params.m,
        params.q,
        params.g,
        graph.tree_size(100_000_000)
    );

    let cells: Vec<(&str, MigrateConfig)> = vec![
        ("No-Steal", MigrateConfig::disabled()),
        (
            "Chunk(4)",
            MigrateConfig::default().with_victim(VictimPolicy::Chunk(4)),
        ),
        ("Half", MigrateConfig::default().with_victim(VictimPolicy::Half)),
        ("Single", MigrateConfig::default().with_victim(VictimPolicy::Single)),
        (
            "Single/ready-only",
            MigrateConfig::default()
                .with_victim(VictimPolicy::Single)
                .with_thief(ThiefPolicy::ReadyOnly),
        ),
    ];

    for (label, migrate) in cells {
        let report = Simulator::new(
            graph.clone(),
            SimConfig::default()
                .with_workers_per_node(8)
                .with_seed(11)
                .with_record_polls(false),
            CostModel::default_calibrated(),
            migrate,
            0,
        )
        .run();
        let s = report.total_steals();
        println!(
            "{label:<18} makespan {:>8.2}s  per-node {:?}  steals {}/{} ({} tasks)",
            report.makespan_us / 1e6,
            report
                .nodes
                .iter()
                .map(|n| n.tasks_executed)
                .collect::<Vec<_>>(),
            s.successful_steals,
            s.requests_sent,
            s.tasks_migrated
        );
    }
    println!("\n(Half ≈ Single ≫ No-Steal: with child-follows-parent placement no new\n work ever appears on a starving node, so stealing is the only balancer)");
}
