//! End-to-end driver: ALL layers compose.
//!
//! Distributed dense tiled Cholesky across 4 in-process nodes × 2
//! workers, with task bodies executing the **real AOT-compiled
//! JAX/Pallas tile kernels through PJRT** (L1+L2), coordinated by the
//! full L3 runtime (scheduler, activation messages, migrate thread,
//! Safra termination). Verifies ‖L·Lᵀ − A‖∞ against the input matrix
//! and compares work stealing ON vs OFF.
//!
//!     make artifacts && cargo run --release --example cholesky_e2e
//!
//! Without PJRT artifacts (CI smoke, machines without the XLA
//! extension) pass `--cpu` — or let the automatic fallback kick in —
//! to run the same end-to-end protocol on the pure-Rust oracle kernels
//! (`workloads::kernels`), still numerically verified:
//!
//!     cargo run --release --example cholesky_e2e -- --cpu --tiles 6 --tile-size 8
//!
//! Recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use parsteal::dataflow::data::TileStore;
use parsteal::dataflow::ttg::TaskGraph;
use parsteal::migrate::MigrateConfig;
use parsteal::node::{Cluster, ClusterConfig, TaskExecutor};
use parsteal::runtime::executor::build_tile_store;
use parsteal::runtime::{CpuCholeskyExecutor, KernelService, PjrtCholeskyExecutor};
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

/// Either kernel backend, with the same verify surface.
enum Exec {
    Pjrt(Arc<PjrtCholeskyExecutor>),
    Cpu(Arc<CpuCholeskyExecutor>),
}

impl Exec {
    fn executor(&self) -> Arc<dyn TaskExecutor> {
        match self {
            Exec::Pjrt(e) => e.clone(),
            Exec::Cpu(e) => e.clone(),
        }
    }

    fn verify(&self, reference: &TileStore) -> f64 {
        match self {
            Exec::Pjrt(e) => e.verify(reference),
            Exec::Cpu(e) => e.verify(reference),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flag_val = |name: &str, default: u32| -> u32 {
        args.iter()
            .position(|a| a == name)
            .and_then(|ix| args.get(ix + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let artifacts = std::path::PathBuf::from(
        args.iter()
            .find(|a| !a.starts_with("--") && a.parse::<u32>().is_err())
            .cloned()
            .unwrap_or_else(|| "artifacts".into()),
    );
    let force_cpu = args.iter().any(|a| a == "--cpu");
    let tiles = flag_val("--tiles", 10);
    let tile_size = flag_val("--tile-size", 32);
    let (nodes, workers) = (4u32, 2usize);
    // PJRT needs the AOT artifacts; fall back to the pure-Rust oracle
    // kernels when they are absent so the e2e stays runnable anywhere.
    let svc = if force_cpu {
        None
    } else {
        match KernelService::start(artifacts.clone(), Some(vec![tile_size]), 4) {
            Ok(svc) => Some(svc),
            Err(e) => {
                eprintln!("(PJRT artifacts unavailable: {e}; falling back to --cpu kernels)");
                None
            }
        }
    };
    println!(
        "E2E: {t}x{t} tiles of {n}x{n} f64 (global {g}x{g}), {p} nodes x {w} workers, {k} kernels",
        t = tiles,
        n = tile_size,
        g = tiles * tile_size,
        p = nodes,
        w = workers,
        k = if svc.is_some() { "PJRT" } else { "pure-Rust" }
    );

    for steal in [false, true] {
        let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size,
            nodes,
            dense_fraction: 1.0,
            seed: 0xE2E,
            all_dense: true,
        }));
        let reference = build_tile_store(&graph);
        let ex = match &svc {
            Some(svc) => Exec::Pjrt(Arc::new(PjrtCholeskyExecutor::new(
                graph.clone(),
                svc.clone(),
            ))),
            None => Exec::Cpu(Arc::new(CpuCholeskyExecutor::new(graph.clone()))),
        };
        let t0 = Instant::now();
        let report = Cluster::run(
            graph.clone(),
            ClusterConfig::default()
                .with_workers_per_node(workers)
                .with_migrate(if steal {
                    MigrateConfig::default()
                } else {
                    MigrateConfig::disabled()
                })
                .with_seed(2)
                .with_record_polls(false),
            ex.executor(),
        );
        let wall = t0.elapsed().as_secs_f64();
        let err = ex.verify(&reference);
        let steals = report.total_steals();
        println!(
            "steal={steal:<5} wall {wall:>6.2}s  tasks {}  per-node {:?}  steals {}/{}  ‖LLᵀ−A‖∞ = {err:.2e}  {}",
            report.tasks_total_executed(),
            report
                .nodes
                .iter()
                .map(|n| n.tasks_executed)
                .collect::<Vec<_>>(),
            steals.successful_steals,
            steals.requests_sent,
            if err < 1e-8 { "OK" } else { "FAIL" }
        );
        assert_eq!(report.tasks_total_executed(), graph.total_tasks().unwrap());
        assert!(err < 1e-8, "numerical verification failed");
    }
    println!("\nEnd-to-end OK: tile kernels -> distributed L3 runtime with work\nstealing (scheduler, activations, migrate thread, Safra), numerically verified.");
    Ok(())
}
