//! End-to-end driver: ALL layers compose.
//!
//! Distributed dense tiled Cholesky across 4 in-process nodes × 2
//! workers, with task bodies executing the **real AOT-compiled
//! JAX/Pallas tile kernels through PJRT** (L1+L2), coordinated by the
//! full L3 runtime (scheduler, activation messages, migrate thread,
//! Safra termination). Verifies ‖L·Lᵀ − A‖∞ against the input matrix
//! and compares work stealing ON vs OFF.
//!
//!     make artifacts && cargo run --release --example cholesky_e2e
//!
//! Recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use parsteal::comm::LinkModel;
use parsteal::dataflow::ttg::TaskGraph;
use parsteal::migrate::MigrateConfig;
use parsteal::node::{Cluster, ClusterConfig};
use parsteal::runtime::executor::build_tile_store;
use parsteal::runtime::{KernelService, PjrtCholeskyExecutor};
use parsteal::sched::SchedBackend;
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let (tiles, tile_size, nodes, workers) = (10u32, 32u32, 4u32, 2usize);
    println!(
        "E2E: {t}x{t} tiles of {n}x{n} f64 (global {g}x{g}), {p} nodes x {w} workers, PJRT kernels",
        t = tiles,
        n = tile_size,
        g = tiles * tile_size,
        p = nodes,
        w = workers
    );

    let svc = KernelService::start(artifacts, Some(vec![tile_size]), 4)?;
    for steal in [false, true] {
        let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size,
            nodes,
            dense_fraction: 1.0,
            seed: 0xE2E,
            all_dense: true,
        }));
        let reference = build_tile_store(&graph);
        let ex = Arc::new(PjrtCholeskyExecutor::new(graph.clone(), svc.clone()));
        let t0 = Instant::now();
        let report = Cluster::run(
            graph.clone(),
            ClusterConfig {
                workers_per_node: workers,
                link: LinkModel::ideal(),
                migrate: if steal {
                    MigrateConfig {
                        poll_interval_us: 100.0,
                        ..Default::default()
                    }
                } else {
                    MigrateConfig::disabled()
                },
                seed: 2,
                record_polls: false,
                sched: SchedBackend::Central,
                batch_activations: true,
                pool_floor: parsteal::sched::POOL_FLOOR,
            },
            ex.clone(),
        );
        let wall = t0.elapsed().as_secs_f64();
        let err = ex.verify(&reference);
        let steals = report.total_steals();
        println!(
            "steal={steal:<5} wall {wall:>6.2}s  tasks {}  per-node {:?}  steals {}/{}  ‖LLᵀ−A‖∞ = {err:.2e}  {}",
            report.tasks_total_executed(),
            report
                .nodes
                .iter()
                .map(|n| n.tasks_executed)
                .collect::<Vec<_>>(),
            steals.successful_steals,
            steals.requests_sent,
            if err < 1e-8 { "OK" } else { "FAIL" }
        );
        assert_eq!(report.tasks_total_executed(), graph.total_tasks().unwrap());
        assert!(err < 1e-8, "numerical verification failed");
    }
    println!("\nEnd-to-end OK: L1 Pallas kernels -> L2 JAX graph -> HLO text -> PJRT ->\nL3 distributed runtime with work stealing, numerically verified.");
    Ok(())
}
