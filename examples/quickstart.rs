//! Quickstart: define a task graph with the TTG-style builder (including
//! the paper's `is_stealable` hook), run it on the simulator with work
//! stealing on and off, and print the comparison.
//!
//!     cargo run --release --example quickstart [width]
//!
//! The optional `width` argument sizes the fan-out (default 4000; CI's
//! smoke step passes a few hundred).

use std::sync::Arc;

use parsteal::dataflow::task::{NodeId, TaskClass, TaskDesc};
use parsteal::dataflow::ttg::TtgBuilder;
use parsteal::migrate::MigrateConfig;
use parsteal::sim::{CostModel, SimConfig, Simulator};

fn main() {
    // A deliberately imbalanced fork graph: one root on node 0 fans out
    // into `width` independent tasks, all owned by node 0 — stealing is
    // the only way nodes 1..3 ever see work. Tasks with odd index are
    // marked non-stealable through the TTG hook (they represent work
    // pinned to its data), so at most half the work can migrate.
    let width: u32 = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(4_000);
    let nodes = 4;
    let graph = Arc::new(
        TtgBuilder::new("quickstart-fanout", nodes)
            .with_roots(vec![TaskDesc::indexed(TaskClass::Synthetic, 0, 0, 0)])
            .wrap_g(
                "fan",
                // the paper's Listing-1.1 extension: programmer decides
                // which tasks a thief may take
                |t| t.i % 2 == 0,
                move |t| {
                    if t.i == 0 {
                        (1..=width)
                            .map(|i| TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0))
                            .collect()
                    } else {
                        vec![]
                    }
                },
                |t| u32::from(t.i > 0),
                |_| NodeId(0),
                |_| 250.0, // 250 µs of work per task
            )
            .with_total_tasks(width as u64 + 1)
            .build(),
    );

    for steal in [false, true] {
        let migrate = if steal {
            MigrateConfig::default()
        } else {
            MigrateConfig::disabled()
        };
        let report = Simulator::new(
            graph.clone(),
            SimConfig::default()
                .with_workers_per_node(8)
                .with_seed(7)
                .with_record_polls(false),
            CostModel::default_calibrated(),
            migrate,
            0,
        )
        .run();
        let steals = report.total_steals();
        println!(
            "steal={steal:<5}  makespan {:>8.1} ms   per-node tasks {:?}   {} tasks migrated",
            report.makespan_us / 1e3,
            report
                .nodes
                .iter()
                .map(|n| n.tasks_executed)
                .collect::<Vec<_>>(),
            steals.tasks_migrated,
        );
    }
    println!("\n(with stealing the fan-out spreads across all 4 nodes; only even-index\n tasks move because the is_stealable hook pins the odd ones)");
}
