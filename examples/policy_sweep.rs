//! Policy sweep: the full cross-product of thief policy × victim policy
//! × waiting-time gate on the headline Cholesky workload — the
//! design-space exploration behind Figs. 2, 5 and 6, in one table.
//!
//!     cargo run --release --example policy_sweep [seeds]

use std::sync::Arc;

use parsteal::comm::LinkModel;
use parsteal::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::stats::Summary;
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn main() {
    let seeds: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    let nodes = 8;
    let graph = || {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles: 40,
            tile_size: 50,
            nodes,
            dense_fraction: 0.5,
            seed: 0xC404,
            all_dense: false,
        }))
    };
    let run = |migrate: MigrateConfig, seed: u64| {
        Simulator::new(
            graph(),
            SimConfig {
                workers_per_node: 8,
                link: LinkModel::cluster(),
                seed,
                max_events: u64::MAX,
                record_polls: false,
            },
            CostModel::default_calibrated(),
            migrate,
            50,
        )
        .run()
    };

    // baseline
    let base: Vec<f64> = (0..seeds)
        .map(|s| run(MigrateConfig::disabled(), 100 + s).makespan_us / 1e6)
        .collect();
    let base_mean = Summary::of(&base).mean;
    println!(
        "No-Steal baseline: {:.3}s mean over {} seeds ({} nodes x 8 workers, 40² tiles of 50²)\n",
        base_mean, seeds, nodes
    );
    println!(
        "{:<18} {:<10} {:<8} {:>9} {:>9} {:>9} {:>8}",
        "thief", "victim", "gate", "mean(s)", "sd", "speedup", "steal%"
    );

    for thief in [ThiefPolicy::ReadyOnly, ThiefPolicy::ReadySuccessors] {
        for victim in [
            VictimPolicy::Single,
            VictimPolicy::Chunk(4),
            VictimPolicy::Half,
        ] {
            for gate in [false, true] {
                let mc = MigrateConfig {
                    enabled: true,
                    thief,
                    victim,
                    use_waiting_time: gate,
                    poll_interval_us: 100.0,
                    max_inflight: 1,
            migrate_overhead_us: 150.0,
                };
                let mut times = Vec::new();
                let mut pct = 0.0;
                for s in 0..seeds {
                    let r = run(mc, 100 + s);
                    times.push(r.makespan_us / 1e6);
                    pct += r.total_steals().success_pct();
                }
                let su = Summary::of(&times);
                println!(
                    "{:<18} {:<10} {:<8} {:>9.3} {:>9.3} {:>9.3} {:>7.1}%",
                    format!("{thief:?}"),
                    victim.label(),
                    if gate { "wait" } else { "-" },
                    su.mean,
                    su.std,
                    base_mean / su.mean,
                    pct / seeds as f64
                );
            }
        }
    }
}
