//! Policy sweep: the full cross-product of thief policy × victim policy
//! × waiting-time gate on the headline Cholesky workload — the
//! design-space exploration behind Figs. 2, 5 and 6, in one table —
//! now swept per scheduler backend (central, sharded and the lock-free
//! workassist queue). The ranking of policies must be stable across
//! backends (the acceptance check for every non-central queue: same
//! Steal-vs-No-Steal ordering as central).
//!
//!     cargo run --release --example policy_sweep [seeds] [--sched=central|sharded|workassist|all]

use std::sync::Arc;

use parsteal::dataflow::task::TaskClass;
use parsteal::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy, VictimSelect};
use parsteal::sched::{BatchSite, SchedBackend};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::stats::Summary;
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn main() {
    let mut seeds: u64 = 3;
    let mut backends: Vec<SchedBackend> = SchedBackend::ALL.to_vec();
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("--sched=") {
            backends = match v {
                "both" | "all" => SchedBackend::ALL.to_vec(),
                one => match one.parse::<SchedBackend>() {
                    Ok(b) => vec![b],
                    Err(e) => {
                        eprintln!("{e}");
                        eprintln!(
                            "usage: policy_sweep [seeds] [--sched=central|sharded|workassist|all]"
                        );
                        std::process::exit(2);
                    }
                },
            };
        } else if let Ok(n) = arg.parse::<u64>() {
            seeds = n;
        } else {
            eprintln!("usage: policy_sweep [seeds] [--sched=central|sharded|workassist|all]");
            std::process::exit(2);
        }
    }
    let nodes = 8;
    let graph = || {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles: 40,
            tile_size: 50,
            nodes,
            dense_fraction: 0.5,
            seed: 0xC404,
            all_dense: false,
        }))
    };
    let run = |migrate: MigrateConfig, seed: u64, sched: SchedBackend| {
        Simulator::new(
            graph(),
            SimConfig::default()
                .with_workers_per_node(8)
                .with_seed(seed)
                .with_record_polls(false)
                .with_sched(sched),
            CostModel::default_calibrated(),
            migrate,
            50,
        )
        .run()
    };

    for sched in backends {
        // baseline
        let base: Vec<f64> = (0..seeds)
            .map(|s| run(MigrateConfig::disabled(), 100 + s, sched).makespan_us / 1e6)
            .collect();
        let base_mean = Summary::of(&base).mean;
        println!(
            "[{}] No-Steal baseline: {:.3}s mean over {} seeds \
             ({} nodes x 8 workers, 40² tiles of 50²)\n",
            sched.label(),
            base_mean,
            seeds,
            nodes
        );
        println!(
            "{:<18} {:<10} {:<8} {:>9} {:>9} {:>9} {:>8}",
            "thief", "victim", "gate", "mean(s)", "sd", "speedup", "steal%"
        );

        let mut site_batches = [0u64; BatchSite::COUNT];
        for thief in [ThiefPolicy::ReadyOnly, ThiefPolicy::ReadySuccessors] {
            for victim in [
                VictimPolicy::Single,
                VictimPolicy::Chunk(4),
                VictimPolicy::Half,
            ] {
                for gate in [false, true] {
                    let mc = MigrateConfig::default()
                        .with_thief(thief)
                        .with_victim(victim)
                        .with_use_waiting_time(gate);
                    let mut times = Vec::new();
                    let mut pct = 0.0;
                    for s in 0..seeds {
                        let r = run(mc, 100 + s, sched);
                        times.push(r.makespan_us / 1e6);
                        pct += r.total_steals().success_pct();
                        for (ix, (_, batches, _)) in r.batch_site_totals().iter().enumerate() {
                            site_batches[ix] += batches;
                        }
                    }
                    let su = Summary::of(&times);
                    println!(
                        "{:<18} {:<10} {:<8} {:>9.3} {:>9.3} {:>9.3} {:>7.1}%",
                        format!("{thief:?}"),
                        victim.label(),
                        if gate { "wait" } else { "-" },
                        su.mean,
                        su.std,
                        base_mean / su.mean,
                        pct / seeds as f64
                    );
                }
            }
        }
        // The split batch accounting, summed over the sweep: activation
        // ready sets dominate, steal replies and gate denials follow the
        // policy mix.
        let batches = BatchSite::ALL
            .iter()
            .map(|s| format!("{} {}", s.label(), site_batches[s.idx()]))
            .collect::<Vec<_>>()
            .join(", ");
        println!("[{}] batched inserts: {batches}", sched.label());
        // One composition-aware run: the per-class estimate snapshot the
        // --exec-per-class gate runs on (POTRF vs GEMM should differ).
        let mc = MigrateConfig::default().with_exec_per_class(true);
        let r = run(mc, 100, sched);
        let est = r.class_est_us_max();
        let classes = TaskClass::ALL
            .iter()
            .filter(|c| est[c.idx()] > 0.0)
            .map(|c| format!("{} {:.1}µs", c.name(), est[c.idx()]))
            .collect::<Vec<_>>()
            .join(", ");
        println!("[{}] --exec-per-class estimates: {classes}", sched.label());
        // …and one estimate-sharing run: how much victim knowledge the
        // steal replies carried, per node (merged digests / cold-class
        // adoptions — a node that stole nothing shows 0/0).
        let mc = MigrateConfig::default()
            .with_exec_per_class(true)
            .with_share_estimates(true);
        let r = run(mc, 100, sched);
        let per_node = r
            .nodes
            .iter()
            .map(|n| format!("{}/{}", n.digest_merges, n.digest_class_adoptions))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "[{}] --share-estimates digests merged/adoptions per node: {per_node} \
             (total {} merged, {} adopted)",
            sched.label(),
            r.digest_merges_total(),
            r.digest_class_adoptions_total()
        );
        // Uniform-vs-targeted victim-selection ablation at equal seeds:
        // both arms share estimates (the targeted selector reads digest
        // richness off the replies), so the only difference is *which*
        // victim each starving node asks. Expect the targeted arm to
        // convert a higher fraction of its requests into grants at a
        // no-worse makespan.
        for select in [VictimSelect::Uniform, VictimSelect::Targeted] {
            let mc = MigrateConfig::default()
                .with_share_estimates(true)
                .with_victim_select(select);
            let mut times = Vec::new();
            let mut pct = 0.0;
            for s in 0..seeds {
                let r = run(mc, 100 + s, sched);
                times.push(r.makespan_us / 1e6);
                pct += r.total_steals().success_pct();
            }
            let su = Summary::of(&times);
            println!(
                "[{}] --victim-select {:<8} mean {:.3}s  sd {:.3}s  grant rate {:.1}%",
                sched.label(),
                select.label(),
                su.mean,
                su.std,
                pct / seeds as f64
            );
        }
        println!();
    }
}
