//! Steal-protocol benches: the victim-side decision (policy + waiting-
//! time gate) and a full thief→victim→thief round trip over the
//! in-process fabric.

use std::sync::Arc;
use std::time::Duration;

use parsteal::comm::{LinkModel, Msg, Network};
use parsteal::dataflow::task::{NodeId, TaskClass, TaskDesc};
use parsteal::migrate::{
    protocol::decide_steal, ExecSnapshot, MigrateConfig, VictimOutcome, VictimPolicy,
    VictimSelector,
};
use parsteal::sched::{SchedQueue, TaskMeta};
use parsteal::util::bench::Bencher;
use parsteal::util::rng::thief_rng;
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn main() {
    let mut b = Bencher::default();
    println!("== steal protocol ==");

    let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles: 64,
        tile_size: 50,
        nodes: 4,
        ..Default::default()
    }));

    let fill_graph = graph.clone();
    let mut fill = move || {
        let q = SchedQueue::new();
        for i in 1..64u32 {
            for j in 0..i.min(8) {
                let t = CholeskyGraph::gemm(i, j, 0);
                q.insert_meta(t, (i + j) as i64, TaskMeta::of(fill_graph.as_ref(), t));
            }
        }
        q
    };

    for (label, victim) in [
        ("single", VictimPolicy::Single),
        ("chunk20", VictimPolicy::Chunk(20)),
        ("half", VictimPolicy::Half),
    ] {
        let mc = MigrateConfig::default().with_victim(victim);
        let g = graph.clone();
        b.bench_with_setup(
            &format!("decide_steal {label} (gated)"),
            &mut fill,
            move |q| {
                let est = ExecSnapshot::uniform(100.0);
                let d = decide_steal(&mc, g.as_ref(), &q, 8, &est, 5.0, 1e4);
                (q, d)
            },
        );
    }

    // Full message round trip through the fabric (ideal link).
    let (net, mb) = Network::new(2, LinkModel::ideal());
    b.bench("steal request/reply round trip (ideal link)", || {
        net.send(NodeId(0), NodeId(1), Msg::StealRequest {
            thief: NodeId(0),
            req: 1,
        });
        let _req = mb[1].recv_timeout(Duration::from_secs(1)).unwrap();
        net.send(
            NodeId(1),
            NodeId(0),
            Msg::StealReply {
                req: 1,
                tasks: vec![TaskDesc::indexed(TaskClass::Gemm, 5, 3, 1)],
                payload_bytes: 20_000,
                digest: None,
                denied_by_waiting_time: false,
            },
        );
        mb[0].recv_timeout(Duration::from_secs(1)).unwrap()
    });
    net.shutdown();

    // Victim selection: one pick per poll, uniform (the paper's draw)
    // vs the targeted selector's scored argmax. Both are O(candidates)
    // with zero queue access — the decoy queue stays untouched no
    // matter how many picks run (asserted below). Epsilon 0 makes the
    // targeted pick fully deterministic work, no exploration branch.
    println!("== victim selection ==");
    let decoy = fill();
    let decoy_len = decoy.len();
    for n in [8usize, 64] {
        let mut rng = thief_rng(0xBE7C, 0);
        b.bench(&format!("pick uniform ({n} nodes)"), || {
            rng.pick_other(n, 0)
        });
        let mut sel = VictimSelector::new(0, n, thief_rng(0xBE7C, 0))
            .with_link(5.0, 1e4)
            .with_epsilon(0.0);
        for v in 1..n {
            let outcome = if v % 3 == 0 {
                VictimOutcome::Granted
            } else {
                VictimOutcome::DeniedWaitingTime
            };
            sel.record(v, outcome, Some(100.0 * v as f64));
        }
        b.bench(&format!("pick targeted ({n} nodes)"), || {
            let pick = sel.pick(250.0);
            assert!(pick < n && pick != 0);
            pick
        });
    }
    assert_eq!(decoy.len(), decoy_len, "victim picks never touch a queue");
}
