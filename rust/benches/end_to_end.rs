//! End-to-end benches mirroring the paper's Table 1 rows: sparse
//! Cholesky makespan per tile size, No-Steal vs Single, on the DES with
//! the calibrated cost model — plus one real-mode (threaded) run to
//! check the coordinator itself is not the bottleneck.

use std::sync::Arc;
use std::time::Instant;

use parsteal::migrate::MigrateConfig;
use parsteal::node::{Cluster, ClusterConfig, NullExecutor};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn sim_run(tiles: u32, tile_size: u32, steal: bool) -> (f64, f64) {
    let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles,
        tile_size,
        nodes: 4,
        ..Default::default()
    }));
    let migrate = if steal {
        MigrateConfig::default()
    } else {
        MigrateConfig::disabled()
    };
    let cost = CostModel::load_or_default(std::path::Path::new("artifacts/costmodel.json"));
    let t0 = Instant::now();
    let report = Simulator::new(
        graph,
        SimConfig::default()
            .with_workers_per_node(8)
            .with_seed(3)
            .with_record_polls(false),
        cost,
        migrate,
        tile_size,
    )
    .run();
    (report.makespan_us / 1e6, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("== end to end (Table 1 shape: virtual makespan per tile size) ==");
    println!(
        "{:<10} {:>12} {:>12} {:>9} {:>10}",
        "tile", "No-Steal(s)", "Single(s)", "speedup", "bench-wall"
    );
    for tile_size in [10u32, 20, 30, 40, 50] {
        let (base, w1) = sim_run(48, tile_size, false);
        let (single, w2) = sim_run(48, tile_size, true);
        println!(
            "{:<10} {:>12.3} {:>12.3} {:>9.3} {:>9.1}s",
            format!("{tile_size}x{tile_size}"),
            base,
            single,
            base / single,
            w1 + w2
        );
    }

    println!("\n== real-mode coordinator overhead (NullExecutor, protocol only) ==");
    let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles: 24,
        tile_size: 8,
        nodes: 4,
        ..Default::default()
    }));
    let t0 = Instant::now();
    let report = Cluster::run(
        graph,
        ClusterConfig::default()
            .with_workers_per_node(2)
            .with_record_polls(false),
        Arc::new(NullExecutor),
    );
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} tasks through the full runtime in {:.3}s ({:.0} tasks/s incl. termination detection)",
        report.tasks_total_executed(),
        wall,
        report.tasks_total_executed() as f64 / wall
    );
}
