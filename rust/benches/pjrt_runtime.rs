//! PJRT execution benches: per-op tile-kernel latency across tile sizes
//! (the numbers the DES cost model is calibrated from) plus the
//! kernel-service dispatch overhead.

use std::path::PathBuf;

use parsteal::dataflow::data::Tile;
use parsteal::runtime::{KernelService, TileEngine};
use parsteal::util::bench::Bencher;
use parsteal::util::rng::Rng;

fn rand_tile(n: usize, seed: u64) -> Tile {
    let mut rng = Rng::new(seed);
    let mut t = Tile::zeros(n);
    for v in &mut t.data {
        *v = rng.normal() * 0.1;
    }
    for i in 0..n {
        let d = t.at(i, i).abs() + n as f64;
        t.set(i, i, d);
    }
    t
}

fn main() {
    println!("== pjrt runtime ==");
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built; run `make artifacts` first — skipping");
        return;
    }
    let sizes = vec![10u32, 30, 50];
    let engine = TileEngine::load(&dir, Some(&sizes)).expect("load artifacts");
    let mut b = Bencher::default();

    for &n in &sizes {
        let a = rand_tile(n as usize, 1);
        let c = rand_tile(n as usize, 2);
        let x = rand_tile(n as usize, 3);
        b.bench(&format!("gemm n={n}"), || {
            engine
                .execute("gemm", n, &[c.clone(), a.clone(), x.clone()])
                .unwrap()
        });
        b.bench(&format!("potrf n={n}"), || {
            engine.execute("potrf", n, &[a.clone()]).unwrap()
        });
    }

    // Service dispatch overhead vs direct engine call.
    let svc = KernelService::start(dir, Some(vec![10]), 1).unwrap();
    let a = rand_tile(10, 4);
    let c = rand_tile(10, 5);
    b.bench("service dispatch syrk n=10", || {
        svc.execute("syrk", 10, vec![c.clone(), a.clone()]).unwrap()
    });
    svc.shutdown();
}
