//! Scheduler benches.
//!
//! Part 1 — hot-path microbenches (`insert`, `select`, steal extraction)
//! at queue depths seen in the headline workload. L3 perf target:
//! select < 1 µs so the scheduler is never the bottleneck (§Perf).
//!
//! Part 2 — the §4.4 contention benchmark: N worker threads hammer one
//! node queue (select+insert pairs) for a fixed window, with and without
//! a concurrent migrate thread extracting steal candidates, across the
//! full backend matrix (central / sharded / workassist) up to 80
//! workers. This is the experiment the sharded and lock-free backends
//! exist for: at 40 workers with concurrent steal extraction sharded
//! should beat the central single-lock queue by ≥ 2× aggregate
//! throughput, and workassist must do all of it with zero mutex
//! acquisitions.
//!
//! Part 3 — the steal-decision microbench: one full victim-side
//! `decide_steal` poll (O(1) census + waiting-time gate + index-based
//! extraction) at 1/8/40 workers on every backend, in two denial
//! regimes: *payload-certain* (the min-payload bound proves the denial
//! without extracting — the poll is pure accounting reads) and
//! *payload-weighing* (a light outlier forces extract-and-reinsert —
//! the PR 3 steady state). Each cell reports the feedback telemetry.
//!
//! Part 4 — the activation-batching microbench: 1000 ready activations
//! entering a queue per task vs as ready-set batches, with the
//! queue-lock acquisition counts read back from the scheduler's own
//! counters.
//!
//! Part 5 — the estimate-sharing microbench: a full `--share-estimates`
//! digest merged into a cold thief table (all adoptions) and a warm one
//! (all sample-weighted blends).
//!
//! `--json PATH` writes medians + telemetry for CI (the stable
//! `BENCH.json` artifact — per-class gate waiting-time comparison,
//! digest-merge counters, exact-min-payload hits);
//! `--steal-decision-only` skips the slower parts.
//!
//!     cargo bench --bench scheduler [-- [--steal-decision-only] [--json PATH]]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parsteal::comm::LinkModel;
use parsteal::dataflow::task::{NodeId, TaskClass, TaskDesc};
use parsteal::dataflow::ttg::{DynGraph, TtgBuilder};
use parsteal::faults::FaultPlan;
use parsteal::migrate::{
    protocol::decide_steal, waiting_time_per_class_us, waiting_time_us, EstimateDigest,
    ExecSnapshot, MigrateConfig, VictimPolicy, VictimSelect,
};
use parsteal::sched::{
    BatchSite, SPILL_THRESHOLD, SchedBackend, SchedQueue, SchedStats, Scheduler, TaskMeta,
};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::topology::{StealDomains, Topology, TIER_NAMES};
use parsteal::util::bench::Bencher;
use parsteal::util::json::Json;
use parsteal::workloads::{UtsGraph, UtsParams};

fn filled(n: u32) -> SchedQueue {
    let q = SchedQueue::new();
    for i in 0..n {
        q.insert(
            TaskDesc::indexed(TaskClass::Gemm, i, i / 2, i / 4),
            (i % 97) as i64,
        );
    }
    q
}

fn hot_path_benches() {
    let mut b = Bencher::default();
    println!("== scheduler hot paths (central) ==");

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("insert+select depth={depth}"),
            || filled(depth),
            |q| {
                q.insert(TaskDesc::indexed(TaskClass::Trsm, 1, 2, 3), 50);
                let r = q.select();
                (q, r) // return q so its Drop is outside the timed region
            },
        );
    }

    b.bench_with_setup(
        "select drain 1k",
        || filled(1_000),
        |q| {
            while q.select().is_some() {}
            q
        },
    );

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("steal extract 20 of depth={depth}"),
            || filled(depth),
            |q| {
                let stolen = q.extract_for_steal(20, |t| t.i % 2 == 0);
                (q, stolen)
            },
        );
    }

    b.bench_with_setup(
        "count_matching depth=10k",
        || filled(10_000),
        |q| q.count_matching(|t| t.i % 2 == 0),
    );
}

/// One contention cell: `workers` threads doing select+insert pairs on a
/// shared queue for `window`, optionally with a migrate thread running
/// steal extraction against the same queue. Returns aggregate worker
/// ops/second.
fn contention_run(
    backend: SchedBackend,
    workers: usize,
    with_steal: bool,
    window: Duration,
) -> f64 {
    let queue: Arc<dyn Scheduler> = Arc::from(backend.build(workers));
    // Steady-state depth comparable to the headline workload's queues.
    for i in 0..(workers as u32 * 256) {
        queue.insert(
            TaskDesc::indexed(TaskClass::Gemm, i, 0, 0),
            (i % 97) as i64,
        );
    }
    let stop = Arc::new(AtomicBool::new(false));
    let ops = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for w in 0..workers {
        let queue = queue.clone();
        let stop = stop.clone();
        let ops = ops.clone();
        handles.push(std::thread::spawn(move || {
            // Distinct index streams per worker; uid collisions are fine
            // (the queue keys on priority+seq, not uid).
            let mut i = w as u32;
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let got = queue.select(w);
                queue.insert(
                    TaskDesc::indexed(TaskClass::Gemm, i, 0, 0),
                    (i % 97) as i64,
                );
                i = i.wrapping_add(workers as u32);
                local += 1 + got.is_some() as u64;
            }
            ops.fetch_add(local, Ordering::Relaxed);
        }));
    }
    let stealer = with_steal.then(|| {
        let queue = queue.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut extracted = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // The migrate thread's census + extraction, as in
                // decide_steal: O(1) stealable count, then a batch of
                // the lowest-priority stealable tasks, handed back (a
                // remote thief would requeue them after the wire hop).
                let _census = queue.stealable_count();
                let batch = queue.extract_stealable(20);
                extracted += batch.len() as u64;
                for t in batch {
                    queue.insert(t, (t.i % 97) as i64);
                }
            }
            extracted
        })
    });
    let t0 = Instant::now();
    std::thread::sleep(window);
    stop.store(true, Ordering::Relaxed);
    let elapsed = t0.elapsed().as_secs_f64();
    for h in handles {
        h.join().unwrap();
    }
    if let Some(s) = stealer {
        let _ = s.join().unwrap();
    }
    ops.load(Ordering::Relaxed) as f64 / elapsed
}

fn contention_benches() {
    println!();
    println!("== contention: N workers × (select+insert), ± concurrent steal extraction ==");
    println!(
        "{:<9} {:>7}   {:>14} {:>14} {:>14} {:>9} {:>9}",
        "steal", "workers", "central", "sharded", "workassist", "shd/cen", "wa/cen"
    );
    let window = Duration::from_millis(400);
    for with_steal in [false, true] {
        for workers in [1usize, 8, 40, 80] {
            // One warm run to stabilize allocator state, then measure.
            for backend in SchedBackend::ALL {
                contention_run(backend, workers, with_steal, Duration::from_millis(50));
            }
            let central = contention_run(SchedBackend::Central, workers, with_steal, window);
            let sharded = contention_run(SchedBackend::Sharded, workers, with_steal, window);
            let assist = contention_run(SchedBackend::Workassist, workers, with_steal, window);
            println!(
                "{:<9} {:>7}   {:>11.2}M/s {:>11.2}M/s {:>11.2}M/s {:>8.2}x {:>8.2}x",
                if with_steal { "+steal" } else { "-" },
                workers,
                central / 1e6,
                sharded / 1e6,
                assist / 1e6,
                sharded / central,
                assist / central
            );
        }
    }
    println!(
        "\n(acceptance: sharded ≥ 2x central at 40 workers with concurrent steal extraction)"
    );
}

fn bench_graph(payload: impl Fn(TaskDesc) -> u64 + Send + Sync + 'static) -> DynGraph {
    TtgBuilder::new("bench", 2)
        .wrap_g(
            "c",
            |t| t.i % 2 == 0, // half the tasks stealable
            |_| vec![],
            |_| 1,
            |_| NodeId(0),
            |_| 1.0,
        )
        .with_payload(payload)
        .build()
}

/// One full victim-side steal poll per iteration, in steady state, in
/// two denial regimes. *Certain*: uniform 1 GiB payloads, so the
/// min-payload bound proves every denial from the O(1) accounting —
/// the poll never extracts, never reinserts, never pays the sharded
/// fallback walk. *Weighing*: one 64 B outlier keeps the bound low, so
/// every poll extracts, weighs the concrete batch, and returns it in
/// one gate-denial batch — the PR 3 steady state. Each cell reports
/// the feedback telemetry: denials fed back and the sharded watermark
/// after the run (denial-heavy -> it must have risen).
fn steal_decision_benches() -> Vec<(String, f64, SchedStats)> {
    println!();
    println!("== steal decision: one decide_steal poll (gated, steady-state) ==");
    let mut b = Bencher::default();
    let mut medians = Vec::new();
    let certain = bench_graph(|_| 1 << 30);
    let weighing = bench_graph(|t| if t.i == 2 { 64 } else { 1 << 30 });
    let mc = MigrateConfig::default()
        .with_victim(VictimPolicy::Single)
        .with_use_waiting_time(true);
    const DEPTH: u32 = 2048;
    for backend in SchedBackend::ALL {
        for workers in [1usize, 8, 40] {
            for (kind, graph) in [("certain", &certain), ("weighing", &weighing)] {
                let q = backend.build(workers);
                for i in 0..DEPTH {
                    let t = TaskDesc::indexed(TaskClass::Gemm, i, 0, 0);
                    q.insert_meta(t, (i % 97) as i64, TaskMeta::of(graph, t));
                }
                let est = ExecSnapshot::uniform(10.0);
                let name = format!(
                    "decide_steal {} {kind:<8} {workers:>2} workers depth={DEPTH}",
                    backend.label()
                );
                let r = b.bench(&name, || {
                    decide_steal(&mc, graph, q.as_ref(), workers, &est, 5.0, 1e3)
                });
                let stats = q.stats();
                medians.push((name, r.median_ns(), stats));
                assert_eq!(q.len() as u32, DEPTH, "gate denial must restore the queue");
                assert_eq!(
                    stats.scans,
                    0,
                    "steal polls must not scan ({})",
                    backend.label()
                );
                assert_eq!(
                    stats.min_payload_resets, 0,
                    "the exact min-payload multiset never resets ({})",
                    backend.label()
                );
                if kind == "certain" {
                    assert_eq!(
                        stats.steal_extracted, 0,
                        "payload-certain polls must not extract ({})",
                        backend.label()
                    );
                    assert_eq!(
                        stats.extract_fallback_walks, 0,
                        "payload-certain polls must not walk the shards ({})",
                        backend.label()
                    );
                    assert_eq!(stats.batch_inserts(), 0, "nothing to reinsert");
                } else {
                    assert!(stats.steal_extracted > 0, "weighing polls extract");
                    assert_eq!(
                        stats.site(BatchSite::GateDenial).batches,
                        stats.feedback_wt_denials,
                        "one batched reinsert per denial ({})",
                        backend.label()
                    );
                }
                if backend == SchedBackend::Sharded {
                    assert!(
                        stats.watermark as usize > SPILL_THRESHOLD,
                        "denial-heavy steady state must raise the watermark \
                         ({} <= {SPILL_THRESHOLD})",
                        stats.watermark
                    );
                }
                if backend == SchedBackend::Workassist {
                    // The poll must be lock-free end to end, and an
                    // uncontended poll never even retries a CAS.
                    assert_eq!(
                        stats.lock_acquisitions, 0,
                        "the lock-free backend's steal poll took a lock"
                    );
                    assert_eq!(
                        stats.cas_retries, 0,
                        "an uncontended steal poll must not retry a CAS"
                    );
                }
            }
        }
    }
    medians
}

/// Satellite microbench: the activation pipeline's lock traffic. 1000
/// ready activations enter a queue either per task (one queue-lock
/// acquisition each) or as ready-set batches of 8 through the
/// activation-site batched insert. The lock counts are read back from
/// the scheduler's own counters, not assumed.
fn activation_batch_benches() -> Vec<(String, f64, u64)> {
    println!();
    println!("== activation batching: 1000 ready activations, per-task vs batched(8) ==");
    let mut b = Bencher::default();
    let mut out = Vec::new();
    const TASKS: u32 = 1000;
    const SET: usize = 8; // ready-set size (Cholesky-like fan-out)
    let workers = 8;
    let mk_batch = || -> Vec<(TaskDesc, i64, TaskMeta)> {
        (0..TASKS)
            .map(|i| {
                let t = TaskDesc::indexed(TaskClass::Gemm, i, 0, 0);
                let meta = TaskMeta {
                    stealable: true,
                    payload_bytes: 0,
                    class: t.class,
                };
                (t, (i % 97) as i64, meta)
            })
            .collect()
    };
    let run = |q: &dyn Scheduler, tasks: &[(TaskDesc, i64, TaskMeta)], batched: bool| {
        if batched {
            for set in tasks.chunks(SET) {
                q.insert_batch_at(BatchSite::Activation, set);
            }
        } else {
            for &(t, p, m) in tasks {
                q.insert_meta(t, p, m);
            }
        }
    };
    for backend in SchedBackend::ALL {
        for batched in [false, true] {
            // Lock count from the counter contract: per-task inserts
            // acquire once per insert, batches once per batch.
            let probe = backend.build(workers);
            run(probe.as_ref(), &mk_batch(), batched);
            let stats = probe.stats();
            let locks = if batched {
                stats.site(BatchSite::Activation).batches
            } else {
                stats.inserts
            };
            let name = format!(
                "activations {} {}",
                backend.label(),
                if batched { "batched(8)" } else { "per-task " }
            );
            let r = b.bench_with_setup(
                &name,
                || (backend.build(workers), mk_batch()),
                |(q, tasks)| {
                    run(q.as_ref(), &tasks, batched);
                    q
                },
            );
            println!("    -> {locks} queue-lock acquisitions per {TASKS} activations");
            out.push((name, r.median_ns(), locks));
        }
    }
    out
}

/// Satellite microbench: the `--share-estimates` digest merge. A full
/// victim digest (every class seeded) merges into a *cold* thief table
/// (every entry an adoption — the first-steal case) and into a *warm*
/// one (every entry a sample-weighted blend). The per-merge latencies
/// plus the adoption/blend counters go to `BENCH.json` so the perf
/// trajectory of the sharing path is comparable across PRs.
fn estimate_sharing_benches() -> Json {
    println!();
    println!("== estimate sharing: full-digest merge, cold vs warm thief table ==");
    let mut b = Bencher::default();
    // Built through the shared sample-capping constructor and merged
    // through the shared `EstimateDigest::merge_into` loop — the bench
    // exercises the exact code the DES runs per reply (the threaded
    // runtime's CAS merge is its atomic twin).
    let digest = EstimateDigest::snapshot(
        500.0,
        64,
        std::array::from_fn(|c| 10.0 * (c as f64 + 1.0)),
        [8; TaskClass::COUNT],
    );
    let cold_ns = b
        .bench_with_setup(
            "digest merge cold (all adoptions)",
            || ([0.0f64; TaskClass::COUNT], [0u64; TaskClass::COUNT]),
            |(mut table, mut samples)| {
                let adoptions = digest.merge_into(&mut table, &mut samples);
                (table, samples, adoptions)
            },
        )
        .median_ns();
    let warm_ns = b
        .bench_with_setup(
            "digest merge warm (all blends)",
            || ([42.0f64; TaskClass::COUNT], [16u64; TaskClass::COUNT]),
            |(mut table, mut samples)| {
                let adoptions = digest.merge_into(&mut table, &mut samples);
                (table, samples, adoptions)
            },
        )
        .median_ns();
    // Counter semantics, asserted once outside the timed loops.
    let mut table = [0.0f64; TaskClass::COUNT];
    let mut samples = [0u64; TaskClass::COUNT];
    let first = digest.merge_into(&mut table, &mut samples);
    let second = digest.merge_into(&mut table, &mut samples);
    assert_eq!(
        first as usize,
        TaskClass::COUNT,
        "cold merge adopts every class"
    );
    assert_eq!(second, 0, "warm merge blends, never adopts");
    Json::obj(vec![
        ("digest_merges", Json::Num(2.0)),
        ("cold_class_adoptions", Json::Num(first as f64)),
        ("warm_class_adoptions", Json::Num(second as f64)),
        ("digest_wire_bytes", Json::Num(digest.wire_bytes() as f64)),
        ("merge_cold_median_ns", Json::Num(cold_ns)),
        ("merge_warm_median_ns", Json::Num(warm_ns)),
    ])
}

/// The composition-aware gate's telemetry for `BENCH.json`: the
/// same half-POTRF/half-GEMM queue seen by the node-wide formula and by
/// the per-class one (`--exec-per-class`), whose estimates differ by
/// Table 1's orders of magnitude.
fn per_class_gate_telemetry() -> Json {
    let mut counts = [0usize; TaskClass::COUNT];
    counts[TaskClass::Potrf.idx()] = 512;
    counts[TaskClass::Gemm.idx()] = 512;
    let mut est = [0.0f64; TaskClass::COUNT];
    est[TaskClass::Potrf.idx()] = 10.0;
    est[TaskClass::Gemm.idx()] = 1000.0;
    let avg = 505.0; // what a node-wide mean of the same history reads
    let workers = 40;
    Json::obj(vec![
        ("queued_potrf", Json::Num(counts[TaskClass::Potrf.idx()] as f64)),
        ("queued_gemm", Json::Num(counts[TaskClass::Gemm.idx()] as f64)),
        ("est_potrf_us", Json::Num(est[TaskClass::Potrf.idx()])),
        ("est_gemm_us", Json::Num(est[TaskClass::Gemm.idx()])),
        (
            "waiting_node_wide_us",
            Json::Num(waiting_time_us(1024, workers, avg)),
        ),
        (
            "waiting_per_class_us",
            Json::Num(waiting_time_per_class_us(&counts, &est, workers, avg)),
        ),
    ])
}

/// The PR 6 victim-selection telemetry for `BENCH.json`: the same
/// denial-skewed UTS tree (bursty subtree weights -> many requests land
/// on poor or gate-closed victims) run through the DES twice at one
/// seed — uniform victim choice vs the targeted selector — reporting
/// each arm's grant rate and the makespan delta. Estimate sharing is on
/// in both arms so the only difference is *which* victim each starving
/// node asks. Cheap enough to run in the CI `--steal-decision-only`
/// pass, so the grant-rate trajectory is comparable across PRs.
fn victim_selection_telemetry() -> Json {
    println!();
    println!("== victim selection: uniform vs targeted on denial-skewed UTS (DES) ==");
    let run = |select: VictimSelect| {
        let graph = Arc::new(UtsGraph::new(UtsParams {
            b0: 32,
            m: 4,
            q: 0.3,
            g: 50_000.0,
            seed: 5,
            nodes: 4,
            max_depth: 24,
        }));
        let mc = MigrateConfig::default()
            .with_poll_interval_us(20.0)
            .with_share_estimates(true)
            .with_victim_select(select);
        let cfg = SimConfig::default()
            .with_workers_per_node(4)
            .with_seed(7)
            .with_max_events(50_000_000);
        Simulator::new(graph, cfg, CostModel::default_calibrated(), mc, 20).run()
    };
    let uniform = run(VictimSelect::Uniform);
    let targeted = run(VictimSelect::Targeted);
    let (u_pct, t_pct) = (
        uniform.total_steals().success_pct(),
        targeted.total_steals().success_pct(),
    );
    let delta_pct =
        100.0 * (targeted.makespan_us - uniform.makespan_us) / uniform.makespan_us;
    println!(
        "    uniform  grant rate {u_pct:>5.1}%  makespan {:>10.0}µs",
        uniform.makespan_us
    );
    println!(
        "    targeted grant rate {t_pct:>5.1}%  makespan {:>10.0}µs  (delta {delta_pct:+.2}%)",
        targeted.makespan_us
    );
    Json::obj(vec![
        ("scenario", Json::Str("uts_denial_skewed_4n".into())),
        ("uniform_grant_pct", Json::Num(u_pct)),
        ("targeted_grant_pct", Json::Num(t_pct)),
        ("uniform_makespan_us", Json::Num(uniform.makespan_us)),
        ("targeted_makespan_us", Json::Num(targeted.makespan_us)),
        ("makespan_delta_pct", Json::Num(delta_pct)),
    ])
}

/// The fault-tolerance telemetry for `BENCH.json`: the same steal-heavy
/// UTS tree at one seed, run with the fabric reliable, with the
/// protocol hardening armed but no injected faults (`--faults on` —
/// measures the pure ledger/timeout overhead, which should be ~0), and
/// across a reply-drop sweep (measures how makespan inflates as the
/// retransmit machinery works harder). Deterministic DES at fixed
/// seeds, so the block is comparable across PRs.
fn fault_tolerance_telemetry() -> Json {
    println!();
    println!("== fault tolerance: ledger overhead + makespan vs reply-drop rate (DES) ==");
    let run = |faults: FaultPlan| {
        let graph = Arc::new(UtsGraph::new(UtsParams {
            b0: 32,
            m: 4,
            q: 0.3,
            g: 50_000.0,
            seed: 5,
            nodes: 4,
            max_depth: 24,
        }));
        let mc = MigrateConfig::default().with_poll_interval_us(20.0);
        let cfg = SimConfig::default()
            .with_workers_per_node(4)
            .with_seed(7)
            .with_max_events(50_000_000)
            .with_record_polls(false)
            .with_faults(faults);
        Simulator::new(graph, cfg, CostModel::default_calibrated(), mc, 20).run()
    };
    let baseline = run(FaultPlan::default());
    let hardened = run("on".parse().unwrap());
    let overhead_pct =
        100.0 * (hardened.makespan_us - baseline.makespan_us) / baseline.makespan_us;
    println!(
        "    reliable fabric       makespan {:>10.0}µs",
        baseline.makespan_us
    );
    println!(
        "    hardened, no faults   makespan {:>10.0}µs  (ledger overhead {overhead_pct:+.3}%)",
        hardened.makespan_us
    );
    let mut sweep = Vec::new();
    for drop in [0.1, 0.25, 0.4] {
        let r = run(format!("drop-reply={drop}").parse().unwrap());
        let inflation_pct =
            100.0 * (r.makespan_us - baseline.makespan_us) / baseline.makespan_us;
        println!(
            "    drop-reply={drop:<4}       makespan {:>10.0}µs  ({inflation_pct:+.2}%, \
             {} timeouts, {} retries, {} reclaims)",
            r.makespan_us,
            r.steal_timeouts_total(),
            r.steal_retries_total(),
            r.ledger_reclaims_total()
        );
        sweep.push(Json::obj(vec![
            ("drop_reply", Json::Num(drop)),
            ("makespan_us", Json::Num(r.makespan_us)),
            ("makespan_inflation_pct", Json::Num(inflation_pct)),
            ("replies_dropped", Json::Num(r.faults_dropped as f64)),
            ("steal_timeouts", Json::Num(r.steal_timeouts_total() as f64)),
            ("steal_retries", Json::Num(r.steal_retries_total() as f64)),
            ("ledger_reclaims", Json::Num(r.ledger_reclaims_total() as f64)),
            (
                "dup_replies_suppressed",
                Json::Num(r.dup_replies_suppressed_total() as f64),
            ),
        ]));
    }
    // Crash-stop recovery on the same scenario: node 2 dies a third of
    // the way through the baseline makespan; the survivors re-home its
    // work. The deterministic DES makes the whole sub-block stable
    // across runs, so the recovery cost is comparable across PRs.
    let crash_at = baseline.makespan_us / 3.0;
    let crash_spec = format!("crash-node=2,crash-at-us={crash_at:.0}");
    let crashed = run(crash_spec.parse().unwrap());
    let crash_inflation_pct =
        100.0 * (crashed.makespan_us - baseline.makespan_us) / baseline.makespan_us;
    println!(
        "    crash-node=2 @ T/3    makespan {:>10.0}µs  ({crash_inflation_pct:+.2}%, \
         {} recovered, detect {:.0}µs)",
        crashed.makespan_us, crashed.recovery.tasks_recovered, crashed.recovery.detect_latency_us
    );
    Json::obj(vec![
        ("scenario", Json::Str("uts_steal_heavy_4n".into())),
        ("baseline_makespan_us", Json::Num(baseline.makespan_us)),
        ("hardened_makespan_us", Json::Num(hardened.makespan_us)),
        ("ledger_overhead_pct", Json::Num(overhead_pct)),
        ("drop_sweep", Json::Arr(sweep)),
        (
            "crash_recovery",
            Json::obj(vec![
                ("crash_at_us", Json::Num(crash_at)),
                ("makespan_us", Json::Num(crashed.makespan_us)),
                ("makespan_inflation_pct", Json::Num(crash_inflation_pct)),
                (
                    "nodes_crashed",
                    Json::Num(crashed.recovery.nodes_crashed as f64),
                ),
                (
                    "tasks_recovered",
                    Json::Num(crashed.recovery.tasks_recovered as f64),
                ),
                (
                    "ring_repairs",
                    Json::Num(crashed.recovery.ring_repairs as f64),
                ),
                (
                    "detect_latency_us",
                    Json::Num(crashed.recovery.detect_latency_us),
                ),
            ]),
        ),
    ])
}

/// The PR 10 topology telemetry for `BENCH.json`: the same root-heavy
/// UTS tree on a two-tier topology (4 sockets of 4 nodes), run through
/// the deterministic DES at one seed with flat vs hierarchical steal
/// domains. Reports each arm's makespan, per-tier steal-request counts
/// and cross-tier request/byte totals, so the cross-tier traffic
/// trajectory is comparable across PRs.
fn topology_telemetry() -> Json {
    println!();
    println!("== steal domains: flat vs hierarchical on a two-tier topology (DES) ==");
    let topo = Topology::two_tier(
        4,
        LinkModel {
            latency_us: 1.0,
            bw_bytes_per_us: 20_000.0,
        },
        LinkModel {
            latency_us: 30.0,
            bw_bytes_per_us: 1_500.0,
        },
    );
    let run = |domains: StealDomains| {
        let graph = Arc::new(UtsGraph::new(UtsParams {
            b0: 32,
            m: 4,
            q: 0.3,
            g: 50_000.0,
            seed: 5,
            nodes: 16,
            max_depth: 24,
        }));
        let mc = MigrateConfig::default().with_poll_interval_us(20.0);
        let cfg = SimConfig::default()
            .with_workers_per_node(4)
            .with_seed(7)
            .with_max_events(50_000_000)
            .with_record_polls(false)
            .with_topology(topo)
            .with_steal_domains(domains);
        Simulator::new(graph, cfg, CostModel::default_calibrated(), mc, 20).run()
    };
    let mut arms = Vec::new();
    for domains in [StealDomains::Flat, StealDomains::Hierarchical] {
        let r = run(domains);
        let tiers = r.tier_steal_totals();
        let per_tier = TIER_NAMES
            .iter()
            .zip(tiers)
            .map(|(name, (req, _, _))| format!("{name} {req}"))
            .collect::<Vec<_>>()
            .join(", ");
        println!(
            "    {:<12} makespan {:>10.0}µs  cross-tier {:>6} requests / {:>12} bytes  ({per_tier})",
            domains.label(),
            r.makespan_us,
            r.cross_tier_steal_requests(),
            r.cross_tier_steal_bytes()
        );
        arms.push(Json::obj(vec![
            ("domains", Json::from(domains.label())),
            ("makespan_us", Json::Num(r.makespan_us)),
            (
                "tier_requests",
                Json::Arr(
                    tiers
                        .iter()
                        .map(|(req, _, _)| Json::Num(*req as f64))
                        .collect(),
                ),
            ),
            (
                "cross_tier_requests",
                Json::Num(r.cross_tier_steal_requests() as f64),
            ),
            (
                "cross_tier_bytes",
                Json::Num(r.cross_tier_steal_bytes() as f64),
            ),
        ]));
    }
    Json::obj(vec![
        ("scenario", Json::Str("uts_two_tier_16n".into())),
        ("topology", Json::Str(topo.label())),
        ("arms", Json::Arr(arms)),
    ])
}

fn write_json(
    path: &str,
    medians: &[(String, f64, SchedStats)],
    activations: &[(String, f64, u64)],
    estimate_sharing: Json,
    victim_selection: Json,
    fault_tolerance: Json,
    topology: Json,
) {
    let steal_entries: Vec<Json> = medians
        .iter()
        .map(|(name, ns, stats)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("median_ns_per_poll", Json::Num(*ns)),
                (
                    "wt_denials_fed",
                    Json::Num(stats.feedback_wt_denials as f64),
                ),
                ("batch_inserts", Json::Num(stats.batch_inserts() as f64)),
                (
                    "batch_saved_locks",
                    Json::Num(stats.batch_saved_locks() as f64),
                ),
                ("steal_extracted", Json::Num(stats.steal_extracted as f64)),
                (
                    "fallback_walks",
                    Json::Num(stats.extract_fallback_walks as f64),
                ),
                ("watermark_after", Json::Num(stats.watermark as f64)),
                (
                    "min_payload_resets",
                    Json::Num(stats.min_payload_resets as f64),
                ),
                (
                    "lock_acquisitions",
                    Json::Num(stats.lock_acquisitions as f64),
                ),
                ("cas_retries", Json::Num(stats.cas_retries as f64)),
            ])
        })
        .collect();
    // Every payload-certain denial was proven by the exact min-payload
    // floor alone — the multiset's O(1) read replacing an extraction.
    let exact_min_hits: u64 = medians
        .iter()
        .filter(|(name, _, _)| name.contains("certain"))
        .map(|(_, _, stats)| stats.feedback_wt_denials)
        .sum();
    let reset_total: u64 = medians.iter().map(|(_, _, s)| s.min_payload_resets).sum();
    let activation_entries: Vec<Json> = activations
        .iter()
        .map(|(name, ns, locks)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("median_ns_per_1k_activations", Json::Num(*ns)),
                ("locks_per_1k_activations", Json::Num(*locks as f64)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::Str("scheduler".into())),
        ("steal_decision", Json::Arr(steal_entries)),
        ("activation_batching", Json::Arr(activation_entries)),
        ("per_class_gate", per_class_gate_telemetry()),
        ("estimate_sharing", estimate_sharing),
        ("victim_selection", victim_selection),
        ("fault_tolerance", fault_tolerance),
        ("topology", topology),
        (
            "exact_min_payload",
            Json::obj(vec![
                ("certain_denial_hits", Json::Num(exact_min_hits as f64)),
                ("stale_bound_resets", Json::Num(reset_total as f64)),
            ]),
        ),
    ]);
    match std::fs::write(path, j.pretty()) {
        Ok(()) => println!("\n(scheduler bench telemetry -> {path})"),
        Err(e) => eprintln!("\n(could not write {path}: {e})"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steal_only = args.iter().any(|a| a == "--steal-decision-only");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|ix| args.get(ix + 1))
        .cloned();
    if !steal_only {
        hot_path_benches();
        contention_benches();
    }
    let medians = steal_decision_benches();
    let activations = activation_batch_benches();
    let estimate_sharing = estimate_sharing_benches();
    let victim_selection = victim_selection_telemetry();
    let fault_tolerance = fault_tolerance_telemetry();
    let topology = topology_telemetry();
    if let Some(path) = json_path {
        write_json(
            &path,
            &medians,
            &activations,
            estimate_sharing,
            victim_selection,
            fault_tolerance,
            topology,
        );
    }
}
