//! Scheduler hot-path benches: `insert`, `select`, and steal extraction
//! under queue depths seen in the headline workload. L3 perf target:
//! select < 1 µs so the scheduler is never the bottleneck (§Perf).

use parsteal::dataflow::task::{TaskClass, TaskDesc};
use parsteal::sched::SchedQueue;
use parsteal::util::bench::Bencher;

fn filled(n: u32) -> SchedQueue {
    let mut q = SchedQueue::new();
    for i in 0..n {
        q.insert(
            TaskDesc::indexed(TaskClass::Gemm, i, i / 2, i / 4),
            (i % 97) as i64,
        );
    }
    q
}

fn main() {
    let mut b = Bencher::default();
    println!("== scheduler ==");

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("insert+select depth={depth}"),
            || filled(depth),
            |mut q| {
                q.insert(TaskDesc::indexed(TaskClass::Trsm, 1, 2, 3), 50);
                let r = q.select();
                (q, r) // return q so its Drop is outside the timed region
            },
        );
    }

    b.bench_with_setup(
        "select drain 1k",
        || filled(1_000),
        |mut q| {
            while q.select().is_some() {}
            q
        },
    );

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("steal extract 20 of depth={depth}"),
            || filled(depth),
            |mut q| {
                let stolen = q.extract_for_steal(20, |t| t.i % 2 == 0);
                (q, stolen)
            },
        );
    }

    b.bench_with_setup(
        "count_matching depth=10k",
        || filled(10_000),
        |q| q.count_matching(|t| t.i % 2 == 0),
    );
}
