//! Scheduler benches.
//!
//! Part 1 — hot-path microbenches (`insert`, `select`, steal extraction)
//! at queue depths seen in the headline workload. L3 perf target:
//! select < 1 µs so the scheduler is never the bottleneck (§Perf).
//!
//! Part 2 — the §4.4 contention benchmark: N worker threads hammer one
//! node queue (select+insert pairs) for a fixed window, with and without
//! a concurrent migrate thread extracting steal candidates, across both
//! backends. This is the experiment the sharded backend exists for: at
//! 40 workers with concurrent steal extraction it should beat the
//! central single-lock queue by ≥ 2× aggregate throughput.
//!
//! Part 3 — the steal-decision microbench: one full victim-side
//! `decide_steal` poll (O(1) census + waiting-time gate + index-based
//! extraction) at 1/8/40 workers on both backends. Steady state is
//! denial-heavy (huge payloads), so the run also exercises the feedback
//! loop: each cell reports the denials fed back and the sharded spill
//! watermark after the run. `--json PATH` writes medians + telemetry
//! for CI (`BENCH_PR3.json`); `--steal-decision-only` skips the slower
//! parts.
//!
//!     cargo bench --bench scheduler [-- [--steal-decision-only] [--json PATH]]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use parsteal::dataflow::task::{NodeId, TaskClass, TaskDesc};
use parsteal::dataflow::ttg::TtgBuilder;
use parsteal::migrate::{protocol::decide_steal, MigrateConfig, VictimPolicy};
use parsteal::sched::{SPILL_THRESHOLD, SchedBackend, SchedQueue, SchedStats, Scheduler, TaskMeta};
use parsteal::util::bench::Bencher;
use parsteal::util::json::Json;

fn filled(n: u32) -> SchedQueue {
    let q = SchedQueue::new();
    for i in 0..n {
        q.insert(
            TaskDesc::indexed(TaskClass::Gemm, i, i / 2, i / 4),
            (i % 97) as i64,
        );
    }
    q
}

fn hot_path_benches() {
    let mut b = Bencher::default();
    println!("== scheduler hot paths (central) ==");

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("insert+select depth={depth}"),
            || filled(depth),
            |q| {
                q.insert(TaskDesc::indexed(TaskClass::Trsm, 1, 2, 3), 50);
                let r = q.select();
                (q, r) // return q so its Drop is outside the timed region
            },
        );
    }

    b.bench_with_setup(
        "select drain 1k",
        || filled(1_000),
        |q| {
            while q.select().is_some() {}
            q
        },
    );

    for depth in [100u32, 10_000] {
        b.bench_with_setup(
            &format!("steal extract 20 of depth={depth}"),
            || filled(depth),
            |q| {
                let stolen = q.extract_for_steal(20, |t| t.i % 2 == 0);
                (q, stolen)
            },
        );
    }

    b.bench_with_setup(
        "count_matching depth=10k",
        || filled(10_000),
        |q| q.count_matching(|t| t.i % 2 == 0),
    );
}

/// One contention cell: `workers` threads doing select+insert pairs on a
/// shared queue for `window`, optionally with a migrate thread running
/// steal extraction against the same queue. Returns aggregate worker
/// ops/second.
fn contention_run(
    backend: SchedBackend,
    workers: usize,
    with_steal: bool,
    window: Duration,
) -> f64 {
    let queue: Arc<dyn Scheduler> = Arc::from(backend.build(workers));
    // Steady-state depth comparable to the headline workload's queues.
    for i in 0..(workers as u32 * 256) {
        queue.insert(
            TaskDesc::indexed(TaskClass::Gemm, i, 0, 0),
            (i % 97) as i64,
        );
    }
    let stop = Arc::new(AtomicBool::new(false));
    let ops = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for w in 0..workers {
        let queue = queue.clone();
        let stop = stop.clone();
        let ops = ops.clone();
        handles.push(std::thread::spawn(move || {
            // Distinct index streams per worker; uid collisions are fine
            // (the queue keys on priority+seq, not uid).
            let mut i = w as u32;
            let mut local = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let got = queue.select(w);
                queue.insert(
                    TaskDesc::indexed(TaskClass::Gemm, i, 0, 0),
                    (i % 97) as i64,
                );
                i = i.wrapping_add(workers as u32);
                local += 1 + got.is_some() as u64;
            }
            ops.fetch_add(local, Ordering::Relaxed);
        }));
    }
    let stealer = with_steal.then(|| {
        let queue = queue.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut extracted = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // The migrate thread's census + extraction, as in
                // decide_steal: O(1) stealable count, then a batch of
                // the lowest-priority stealable tasks, handed back (a
                // remote thief would requeue them after the wire hop).
                let _census = queue.stealable_count();
                let batch = queue.extract_stealable(20);
                extracted += batch.len() as u64;
                for t in batch {
                    queue.insert(t, (t.i % 97) as i64);
                }
            }
            extracted
        })
    });
    let t0 = Instant::now();
    std::thread::sleep(window);
    stop.store(true, Ordering::Relaxed);
    let elapsed = t0.elapsed().as_secs_f64();
    for h in handles {
        h.join().unwrap();
    }
    if let Some(s) = stealer {
        let _ = s.join().unwrap();
    }
    ops.load(Ordering::Relaxed) as f64 / elapsed
}

fn contention_benches() {
    println!();
    println!("== contention: N workers × (select+insert), ± concurrent steal extraction ==");
    println!(
        "{:<9} {:>7} {:>7}   {:>14} {:>14} {:>9}",
        "steal", "workers", "", "central", "sharded", "speedup"
    );
    let window = Duration::from_millis(400);
    for with_steal in [false, true] {
        for workers in [1usize, 8, 40] {
            // One warm run to stabilize allocator state, then measure.
            for backend in SchedBackend::ALL {
                contention_run(backend, workers, with_steal, Duration::from_millis(50));
            }
            let central = contention_run(SchedBackend::Central, workers, with_steal, window);
            let sharded = contention_run(SchedBackend::Sharded, workers, with_steal, window);
            println!(
                "{:<9} {:>7} {:>7}   {:>11.2}M/s {:>11.2}M/s {:>8.2}x",
                if with_steal { "+steal" } else { "-" },
                workers,
                "",
                central / 1e6,
                sharded / 1e6,
                sharded / central
            );
        }
    }
    println!(
        "\n(acceptance: sharded ≥ 2x central at 40 workers with concurrent steal extraction)"
    );
}

/// One full victim-side steal poll per iteration, in steady state: the
/// graph's payloads are large enough that the waiting-time gate denies
/// every request, so the extracted task is re-inserted (one batched
/// insert per denial) and the queue depth never drifts. Measures
/// exactly what a migrate thread pays per poll: O(1) census + gate +
/// index extraction + batched re-insert + feedback. Each cell also
/// reports the feedback telemetry: denials fed back and the sharded
/// watermark after the run (denial-heavy -> it must have risen).
fn steal_decision_benches() -> Vec<(String, f64, SchedStats)> {
    println!();
    println!("== steal decision: one decide_steal poll (gated, steady-state) ==");
    let mut b = Bencher::default();
    let mut medians = Vec::new();
    let graph = TtgBuilder::new("bench", 2)
        .wrap_g(
            "c",
            |t| t.i % 2 == 0, // half the tasks stealable
            |_| vec![],
            |_| 1,
            |_| NodeId(0),
            |_| 1.0,
        )
        .with_payload(|_| 1 << 30) // 1 GiB -> gate always denies
        .build();
    let mc = MigrateConfig {
        victim: VictimPolicy::Single,
        use_waiting_time: true,
        ..Default::default()
    };
    const DEPTH: u32 = 2048;
    for backend in SchedBackend::ALL {
        for workers in [1usize, 8, 40] {
            let q = backend.build(workers);
            for i in 0..DEPTH {
                let t = TaskDesc::indexed(TaskClass::Gemm, i, 0, 0);
                q.insert_meta(t, (i % 97) as i64, TaskMeta::of(&graph, t));
            }
            let name = format!(
                "decide_steal {}  {workers:>2} workers  depth={DEPTH}",
                backend.label()
            );
            let r = b.bench(&name, || {
                decide_steal(&mc, &graph, q.as_ref(), workers, 10.0, 5.0, 1e3)
            });
            let stats = q.stats();
            medians.push((name, r.median_ns(), stats));
            assert_eq!(q.len() as u32, DEPTH, "gate denial must restore the queue");
            assert_eq!(
                stats.scans,
                0,
                "steal polls must not scan ({})",
                backend.label()
            );
            assert_eq!(
                stats.batch_inserts, stats.feedback_wt_denials,
                "one batched reinsert per denial ({})",
                backend.label()
            );
            if backend == SchedBackend::Sharded {
                assert!(
                    stats.watermark as usize > SPILL_THRESHOLD,
                    "denial-heavy steady state must raise the watermark ({} <= {SPILL_THRESHOLD})",
                    stats.watermark
                );
            }
        }
    }
    medians
}

fn write_json(path: &str, medians: &[(String, f64, SchedStats)]) {
    let entries: Vec<Json> = medians
        .iter()
        .map(|(name, ns, stats)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("median_ns_per_poll", Json::Num(*ns)),
                (
                    "wt_denials_fed",
                    Json::Num(stats.feedback_wt_denials as f64),
                ),
                ("batch_inserts", Json::Num(stats.batch_inserts as f64)),
                (
                    "batch_saved_locks",
                    Json::Num(stats.batch_saved_locks as f64),
                ),
                ("watermark_after", Json::Num(stats.watermark as f64)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::Str("steal_decision".into())),
        ("results", Json::Arr(entries)),
    ]);
    match std::fs::write(path, j.pretty()) {
        Ok(()) => println!("\n(steal-decision medians -> {path})"),
        Err(e) => eprintln!("\n(could not write {path}: {e})"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steal_only = args.iter().any(|a| a == "--steal-decision-only");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|ix| args.get(ix + 1))
        .cloned();
    if !steal_only {
        hot_path_benches();
        contention_benches();
    }
    let medians = steal_decision_benches();
    if let Some(path) = json_path {
        write_json(&path, &medians);
    }
}
