//! DES throughput: events/second on the headline Cholesky workload.
//! §Perf target: ≥ ~1M events/s so `figure all` stays in minutes.

use std::sync::Arc;
use std::time::Instant;

use parsteal::migrate::MigrateConfig;
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::util::bench::fmt_ns;
use parsteal::workloads::{CholeskyGraph, CholeskyParams};

fn run_once(tiles: u32, steal: bool, record_polls: bool) -> (u64, f64) {
    let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles,
        tile_size: 50,
        nodes: 4,
        ..Default::default()
    }));
    let migrate = if steal {
        MigrateConfig::default()
    } else {
        MigrateConfig::disabled()
    };
    let t0 = Instant::now();
    let report = Simulator::new(
        graph,
        SimConfig::default()
            .with_workers_per_node(8)
            .with_record_polls(record_polls),
        CostModel::default_calibrated(),
        migrate,
        50,
    )
    .run();
    (report.events, t0.elapsed().as_secs_f64())
}

fn main() {
    println!("== DES engine ==");
    for (tiles, steal, polls) in [
        (32u32, false, false),
        (32, true, false),
        (32, true, true),
        (64, true, false),
    ] {
        // a couple of warm runs then measure
        run_once(tiles, steal, polls);
        let (events, secs) = run_once(tiles, steal, polls);
        let rate = events as f64 / secs;
        println!(
            "tiles={tiles:<3} steal={steal:<5} polls={polls:<5}  {events:>9} events in {}  ({:.2}M events/s)",
            fmt_ns(secs * 1e9),
            rate / 1e6
        );
    }
}
