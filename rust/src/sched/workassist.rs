//! The lock-free backend: per-worker publish chains + CAS-claimed
//! entries, in the work-assisting style (`--sched workassist`).
//!
//! Both existing backends serialize every hot-path op on a mutex — the
//! §4.4 contention structure the paper measures. This backend removes
//! the mutex entirely: ready tasks are published as immutable *blocks*
//! (one block per insert event, so a batched activation set is one
//! allocation and one CAS, the work-assisting analogue of advertising a
//! whole chunk of remaining work at once), and consumers — worker
//! `select`, the migrate thread's `extract_stealable`, `drain` — *claim*
//! individual entries with a single `compare_exchange` on the entry's
//! claim flag. Whoever wins the CAS owns the task; everyone else moves
//! on. There is no lock to convoy on, so a stalled thread can never
//! block another (lock-freedom: every failed claim CAS means some other
//! thread made progress).
//!
//! # Ordering
//!
//! `select` claims the globally best unclaimed entry (highest priority,
//! then oldest), and extraction claims the globally worst stealable one
//! (lowest priority, then newest) — the exact order the central queue's
//! `BTreeMap` yields. Single-threaded, this backend is therefore
//! *order-identical* to `central` (property-tested in
//! `tests/sched_backends.rs`), which is also what makes the DES runs on
//! it deterministic. Candidates are found via per-block summaries (the
//! best/worst unclaimed entry of each block, recomputed by the claiming
//! thread), so a `select` walks `O(blocks)` summaries plus one block's
//! entries instead of every queued task. Under concurrency a summary
//! can be momentarily stale; the claim rescan is the authority, so
//! staleness costs candidate quality, never correctness.
//!
//! # The accounting contract, without a lock
//!
//! `len` / `stealable_count` / `stealable_payload_bytes` /
//! `class_counts` are plain atomic counters: bumped *before* a block is
//! published and decremented *after* an entry is claimed, so at every
//! quiesce point they are exact, and mid-flight they are the same
//! best-effort census any concurrent reader of the locked backends
//! observes between its own lock acquisitions.
//!
//! The one structure that cannot be a counter — the *exact*
//! min-stealable-payload multiset — uses mutex-free flat combining:
//! every insert/claim pushes an add/remove delta onto a Treiber stack,
//! and a reader CASes an epoch counter from even to odd to become the
//! *combiner*, draining the stack into the private [`PayloadMultiset`]
//! and refreshing the cached minimum. If the epoch CAS fails, another
//! thread is combining at this instant and the reader returns the last
//! combined minimum instead of waiting — bounded staleness under
//! contention, exactness whenever the read is not racing a writer
//! (every single-threaded read, every quiesce point, and in particular
//! every DES `decide_steal` poll). No path here ever takes a mutex:
//! [`SchedStats::lock_acquisitions`] is hard-wired to zero, and
//! [`SchedStats::cas_retries`] counts every failed CAS so the bench and
//! e2e gates can assert the hot path is both scan-free and lock-free.
//!
//! # Memory
//!
//! Blocks are unlinked from the traversal chains opportunistically once
//! every entry is claimed, but the allocations are retained on a
//! separate all-blocks chain until the queue drops (the unlink CAS can
//! momentarily resurrect an exhausted block, which is harmless exactly
//! because nothing is freed early). That trades a run's peak block
//! count in heap for not needing an epoch/hazard reclamation scheme;
//! queues live for one run and are dropped whole.

use std::fmt;
use std::ptr;

use crate::dataflow::task::{TaskClass, TaskDesc};

use self::sync::{AtomicBool, AtomicI64, AtomicPtr, AtomicU64, AtomicUsize, Ordering, UnsafeCell};
use super::{
    BatchCounter, BatchSite, PayloadMultiset, SchedStats, Scheduler, StealOutcome, TaskMeta,
};

/// Atomic and cell shims: the std types normally, loom's checked twins
/// under `--cfg loom`, so the model-checking suite
/// (`tests/loom_workassist.rs`) explores the owner-pop / thief-claim /
/// accounting-read interleavings of this exact code, not a copy.
mod sync {
    #[cfg(not(loom))]
    pub(super) use std::sync::atomic::{
        AtomicBool, AtomicI64, AtomicPtr, AtomicU64, AtomicUsize, Ordering,
    };

    #[cfg(loom)]
    pub(super) use loom::sync::atomic::{
        AtomicBool, AtomicI64, AtomicPtr, AtomicU64, AtomicUsize, Ordering,
    };

    /// `UnsafeCell` with loom's closure API (`with_mut`) so the
    /// flat-combining body is identical under std and loom.
    #[cfg(not(loom))]
    pub(super) struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    #[cfg(not(loom))]
    impl<T> UnsafeCell<T> {
        pub(super) fn new(value: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(value))
        }

        pub(super) fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }

    #[cfg(loom)]
    pub(super) use loom::cell::UnsafeCell;
}

/// `n` fresh values in a boxed slice (the per-shard, per-class and
/// per-site atomic arrays).
fn filled<T>(n: usize, make: impl Fn() -> T) -> Box<[T]> {
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(make());
    }
    v.into_boxed_slice()
}

/// One queued task inside a published block. Immutable except for the
/// claim flag: the winning `compare_exchange(false, true)` transfers
/// ownership of `task` to the claimer.
struct Entry {
    task: TaskDesc,
    prio: i64,
    meta: TaskMeta,
    claimed: AtomicBool,
}

/// One immutable block of entries, published by a single insert event
/// (a plain insert is a 1-entry block; a batch is one block — the
/// work-assisting "advertise the whole chunk at once").
struct Node {
    /// Sequence number of `entries[0]`; entry `k` is `seq0 + k`, so the
    /// global priority-then-FIFO order needs no per-entry storage.
    seq0: u64,
    /// Unclaimed entries left (monotone to zero). A zero block is
    /// exhausted and eligible for opportunistic unlinking.
    remaining: AtomicUsize,
    /// Traversal chain within a shard; mutated only by unlink CASes.
    next: AtomicPtr<Node>,
    /// Retention chain over every block ever published. Written before
    /// publication, read only by `Drop`, so deferred reclamation can
    /// never double-free or race a walker.
    all_next: *mut Node,
    /// Block summary: best unclaimed entry (highest priority, then
    /// oldest), recomputed by each claiming thread. `i64::MIN` means
    /// "none known" — a reader then rescans the block itself, so a
    /// genuine `i64::MIN` priority degrades speed, never correctness.
    best_prio: AtomicI64,
    best_seq: AtomicU64,
    /// Block summary: worst *stealable* unclaimed entry (lowest
    /// priority, then newest); `i64::MAX` means "none known".
    worst_prio: AtomicI64,
    worst_seq: AtomicU64,
    entries: Box<[Entry]>,
}

/// One pending payload-multiset mutation on the flat-combining stack.
struct Delta {
    payload: u64,
    add: bool,
    next: *mut Delta,
}

/// The lock-free work-assisting queue (`--sched workassist`). See the
/// module docs for the claim protocol and the accounting contract.
pub struct WorkAssistQueue {
    /// Per-worker publish chains: inserts are spread across shards by
    /// sequence number so concurrent publishers rarely contend on one
    /// head CAS. Consumers walk all shards (the claim order is global).
    shards: Box<[AtomicPtr<Node>]>,
    /// Retention list head (see [`Node::all_next`]).
    all_head: AtomicPtr<Node>,
    seq: AtomicU64,
    /// Queued entries (published minus claimed).
    count: AtomicUsize,
    steal_count: AtomicUsize,
    steal_payload: AtomicU64,
    class_counts: Box<[AtomicUsize]>,
    /// Flat-combining state for the exact payload multiset: pending
    /// deltas (Treiber stack), the combiner epoch (odd = someone is
    /// combining), the multiset itself (touched only by the combiner)
    /// and the last combined minimum / resets.
    deltas: AtomicPtr<Delta>,
    combine_epoch: AtomicU64,
    multiset: UnsafeCell<PayloadMultiset>,
    min_cache: AtomicU64,
    resets_cache: AtomicU64,
    // stats
    inserts: AtomicU64,
    selects: AtomicU64,
    select_len_sum: AtomicU64,
    steal_extracted: AtomicU64,
    scans: AtomicU64,
    batch_batches: Box<[AtomicU64]>,
    batch_tasks: Box<[AtomicU64]>,
    feedback_grants: AtomicU64,
    feedback_wt_denials: AtomicU64,
    feedback_timeouts: AtomicU64,
    cas_retries: AtomicU64,
}

// SAFETY: the only non-Sync field is the flat-combining multiset cell,
// which is mutated exclusively by the thread that won the (even -> odd)
// combiner-epoch CAS and read by nobody else; blocks behind the raw
// pointers are immutable after publication except through their atomics
// and are freed only by `Drop` (`&mut self`).
unsafe impl Send for WorkAssistQueue {}
unsafe impl Sync for WorkAssistQueue {}

impl WorkAssistQueue {
    /// Build the queue for a node with `workers` worker threads (one
    /// publish shard per worker; at least one).
    pub fn new(workers: usize) -> Self {
        let n_shards = workers.max(1);
        WorkAssistQueue {
            shards: filled(n_shards, || AtomicPtr::new(ptr::null_mut())),
            all_head: AtomicPtr::new(ptr::null_mut()),
            seq: AtomicU64::new(0),
            count: AtomicUsize::new(0),
            steal_count: AtomicUsize::new(0),
            steal_payload: AtomicU64::new(0),
            class_counts: filled(TaskClass::COUNT, || AtomicUsize::new(0)),
            deltas: AtomicPtr::new(ptr::null_mut()),
            combine_epoch: AtomicU64::new(0),
            multiset: UnsafeCell::new(PayloadMultiset::default()),
            min_cache: AtomicU64::new(u64::MAX),
            resets_cache: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            selects: AtomicU64::new(0),
            select_len_sum: AtomicU64::new(0),
            steal_extracted: AtomicU64::new(0),
            scans: AtomicU64::new(0),
            batch_batches: filled(BatchSite::COUNT, || AtomicU64::new(0)),
            batch_tasks: filled(BatchSite::COUNT, || AtomicU64::new(0)),
            feedback_grants: AtomicU64::new(0),
            feedback_wt_denials: AtomicU64::new(0),
            feedback_timeouts: AtomicU64::new(0),
            cas_retries: AtomicU64::new(0),
        }
    }

    fn bump_retry(&self) {
        self.cas_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// One CAS attempt on a block-chain link; a failed attempt is
    /// counted as a retry so the lock-freedom gates can see contention.
    fn cas_node(&self, link: &AtomicPtr<Node>, cur: *mut Node, new: *mut Node) -> bool {
        let r = link.compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire);
        if r.is_err() {
            self.bump_retry();
        }
        r.is_ok()
    }

    /// One CAS attempt on the delta stack head; failures count as above.
    fn cas_delta(&self, link: &AtomicPtr<Delta>, cur: *mut Delta, new: *mut Delta) -> bool {
        let r = link.compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire);
        if r.is_err() {
            self.bump_retry();
        }
        r.is_ok()
    }

    /// Publish one block of tasks: accounting first (a reader that can
    /// already see the block must never under-count), then the block
    /// itself via a head CAS on its shard chain.
    fn publish(&self, batch: &[(TaskDesc, i64, TaskMeta)]) {
        debug_assert!(!batch.is_empty());
        let n = batch.len();
        let seq0 = self.seq.fetch_add(n as u64, Ordering::Relaxed);
        self.inserts.fetch_add(n as u64, Ordering::Relaxed);
        self.count.fetch_add(n, Ordering::SeqCst);
        let mut best: Option<(i64, u64)> = None;
        let mut worst: Option<(i64, u64)> = None;
        for (k, &(task, prio, meta)) in batch.iter().enumerate() {
            let seq = seq0 + k as u64;
            let class = task.class.idx();
            self.class_counts[class].fetch_add(1, Ordering::Relaxed);
            if meta.stealable {
                self.steal_count.fetch_add(1, Ordering::SeqCst);
                self.steal_payload
                    .fetch_add(meta.payload_bytes, Ordering::SeqCst);
                self.push_delta(meta.payload_bytes, true);
                if worst.is_none_or(|(p, s)| prio < p || (prio == p && seq > s)) {
                    worst = Some((prio, seq));
                }
            }
            if best.is_none_or(|(p, s)| prio > p || (prio == p && seq < s)) {
                best = Some((prio, seq));
            }
        }
        let mut entries = Vec::with_capacity(n);
        for &(task, prio, meta) in batch {
            entries.push(Entry {
                task,
                prio,
                meta,
                claimed: AtomicBool::new(false),
            });
        }
        let (bp, bs) = best.unwrap_or((i64::MIN, 0));
        let (wp, ws) = worst.unwrap_or((i64::MAX, 0));
        let node = Box::into_raw(Box::new(Node {
            seq0,
            remaining: AtomicUsize::new(n),
            next: AtomicPtr::new(ptr::null_mut()),
            all_next: ptr::null_mut(),
            best_prio: AtomicI64::new(bp),
            best_seq: AtomicU64::new(bs),
            worst_prio: AtomicI64::new(wp),
            worst_seq: AtomicU64::new(ws),
            entries: entries.into_boxed_slice(),
        }));
        // Retention chain first (Drop must see every allocation even if
        // a panic lands between the two pushes).
        loop {
            let head = self.all_head.load(Ordering::Relaxed);
            // SAFETY: `node` is unpublished — this thread still owns it.
            unsafe { (*node).all_next = head };
            if self.cas_node(&self.all_head, head, node) {
                break;
            }
        }
        let shard = &self.shards[seq0 as usize % self.shards.len()];
        loop {
            let head = shard.load(Ordering::Acquire);
            // SAFETY: `node` stays valid until Drop; the store is made
            // visible by the release CAS inside `cas_node`.
            unsafe { (*node).next.store(head, Ordering::Relaxed) };
            if self.cas_node(shard, head, node) {
                return;
            }
        }
    }

    /// Push one pending multiset mutation onto the flat-combining stack.
    fn push_delta(&self, payload: u64, add: bool) {
        let delta = Box::into_raw(Box::new(Delta {
            payload,
            add,
            next: ptr::null_mut(),
        }));
        loop {
            let head = self.deltas.load(Ordering::Acquire);
            // SAFETY: `delta` is unpublished — this thread still owns it.
            unsafe { (*delta).next = head };
            if self.cas_delta(&self.deltas, head, delta) {
                return;
            }
        }
    }

    /// Become the combiner (epoch CAS even -> odd) and fold every
    /// pending delta into the multiset, refreshing the cached minimum.
    /// Returns false when another thread holds the combiner role right
    /// now — that thread is installing an up-to-date minimum, so the
    /// caller reads the cache instead of waiting.
    fn try_combine(&self) -> bool {
        let epoch = self.combine_epoch.load(Ordering::Acquire);
        if epoch % 2 == 1 {
            return false;
        }
        let ctr = &self.combine_epoch;
        let won = ctr.compare_exchange(epoch, epoch + 1, Ordering::AcqRel, Ordering::Acquire);
        if won.is_err() {
            self.bump_retry();
            return false;
        }
        let mut segment = self.deltas.swap(ptr::null_mut(), Ordering::AcqRel);
        // Reverse the drained segment to push order: an entry's add is
        // always pushed before its remove (the claim happens after the
        // block — and therefore the add — was published), so applying
        // in push order can never remove before adding.
        let mut ordered: *mut Delta = ptr::null_mut();
        while !segment.is_null() {
            // SAFETY: the swap above transferred the whole segment to
            // this thread exclusively.
            let next = unsafe { (*segment).next };
            unsafe { (*segment).next = ordered };
            ordered = segment;
            segment = next;
        }
        self.multiset.with_mut(|multiset| {
            // SAFETY: the odd epoch makes this thread the only one
            // touching the multiset until the store below.
            let multiset = unsafe { &mut *multiset };
            let mut cur = ordered;
            while !cur.is_null() {
                // SAFETY: exclusive ownership of the drained segment.
                let delta = unsafe { Box::from_raw(cur) };
                if delta.add {
                    multiset.add(delta.payload);
                } else {
                    multiset.remove(delta.payload);
                }
                cur = delta.next;
            }
            self.min_cache.store(multiset.min(), Ordering::Release);
            let resets = multiset.resets();
            self.resets_cache.store(resets, Ordering::Release);
        });
        self.combine_epoch.store(epoch + 2, Ordering::Release);
        true
    }

    /// Visit every live block in every shard, opportunistically
    /// unlinking exhausted blocks along the way (failed unlink CASes
    /// are abandoned, not retried — a later walk gets them).
    fn walk_blocks(&self, visit: &mut dyn FnMut(&Node)) {
        for shard in self.shards.iter() {
            let mut prev: *mut Node = ptr::null_mut();
            let mut cur = shard.load(Ordering::Acquire);
            while !cur.is_null() {
                // SAFETY: published blocks stay allocated until Drop.
                let node = unsafe { &*cur };
                let next = node.next.load(Ordering::Acquire);
                if node.remaining.load(Ordering::Acquire) == 0 {
                    // Bypass the exhausted block. Only exhausted blocks
                    // are ever bypassed, and none is freed before Drop,
                    // so a racing stale CAS can at worst relink an
                    // exhausted block — harmless, a later walk skips it.
                    let link: &AtomicPtr<Node> = if prev.is_null() {
                        shard
                    } else {
                        // SAFETY: `prev` is a previously visited block.
                        unsafe { &(*prev).next }
                    };
                    if !self.cas_node(link, cur, next) {
                        prev = cur;
                    }
                    cur = next;
                    continue;
                }
                visit(node);
                prev = cur;
                cur = next;
            }
        }
    }

    /// Visit every unclaimed entry (the O(n) walk behind the oracle
    /// paths and `drain`).
    fn walk_entries(&self, visit: &mut dyn FnMut(&Node, usize, &Entry, u64)) {
        self.walk_blocks(&mut |node| {
            for (k, e) in node.entries.iter().enumerate() {
                if !e.claimed.load(Ordering::Acquire) {
                    visit(node, k, e, node.seq0 + k as u64);
                }
            }
        });
    }

    /// Recompute a block's best/worst summaries from its claim flags
    /// (run by every claiming thread after its claim; racing recomputes
    /// can leave the summary stale, which readers self-heal by
    /// rescanning — the claim CAS is the authority).
    fn recompute(node: &Node) {
        let mut best: Option<(i64, u64)> = None;
        let mut worst: Option<(i64, u64)> = None;
        for (k, e) in node.entries.iter().enumerate() {
            if e.claimed.load(Ordering::Acquire) {
                continue;
            }
            let seq = node.seq0 + k as u64;
            if best.is_none_or(|(p, s)| e.prio > p || (e.prio == p && seq < s)) {
                best = Some((e.prio, seq));
            }
            if e.meta.stealable
                && worst.is_none_or(|(p, s)| e.prio < p || (e.prio == p && seq > s))
            {
                worst = Some((e.prio, seq));
            }
        }
        let (bp, bs) = best.unwrap_or((i64::MIN, 0));
        node.best_prio.store(bp, Ordering::Release);
        node.best_seq.store(bs, Ordering::Release);
        let (wp, ws) = worst.unwrap_or((i64::MAX, 0));
        node.worst_prio.store(wp, Ordering::Release);
        node.worst_seq.store(ws, Ordering::Release);
    }

    /// A block's best unclaimed candidate: the summary when it is
    /// fresh, a direct rescan when the summary reads as the sentinel.
    fn block_best(node: &Node) -> Option<(i64, u64)> {
        if node.remaining.load(Ordering::Acquire) == 0 {
            return None;
        }
        let p = node.best_prio.load(Ordering::Acquire);
        if p != i64::MIN {
            return Some((p, node.best_seq.load(Ordering::Acquire)));
        }
        let mut best: Option<(i64, u64)> = None;
        for (k, e) in node.entries.iter().enumerate() {
            if e.claimed.load(Ordering::Acquire) {
                continue;
            }
            let seq = node.seq0 + k as u64;
            if best.is_none_or(|(p, s)| e.prio > p || (e.prio == p && seq < s)) {
                best = Some((e.prio, seq));
            }
        }
        best
    }

    /// A block's worst stealable unclaimed candidate (extraction end).
    fn block_worst(node: &Node) -> Option<(i64, u64)> {
        if node.remaining.load(Ordering::Acquire) == 0 {
            return None;
        }
        let p = node.worst_prio.load(Ordering::Acquire);
        if p != i64::MAX {
            return Some((p, node.worst_seq.load(Ordering::Acquire)));
        }
        let mut worst: Option<(i64, u64)> = None;
        for (k, e) in node.entries.iter().enumerate() {
            if e.claimed.load(Ordering::Acquire) || !e.meta.stealable {
                continue;
            }
            let seq = node.seq0 + k as u64;
            if worst.is_none_or(|(p, s)| e.prio < p || (e.prio == p && seq > s)) {
                worst = Some((e.prio, seq));
            }
        }
        worst
    }

    /// Claim entry `k` of `node`. On the winning CAS, decrement the
    /// block's remaining count, refresh its summaries and book the
    /// removal in the accounting counters.
    fn claim(&self, node: &Node, k: usize) -> bool {
        let e = &node.entries[k];
        let flag = &e.claimed;
        let won = flag.compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire);
        if won.is_err() {
            self.bump_retry();
            return false;
        }
        node.remaining.fetch_sub(1, Ordering::AcqRel);
        Self::recompute(node);
        self.count.fetch_sub(1, Ordering::SeqCst);
        let class = e.task.class.idx();
        self.class_counts[class].fetch_sub(1, Ordering::Relaxed);
        if e.meta.stealable {
            self.steal_count.fetch_sub(1, Ordering::SeqCst);
            self.steal_payload
                .fetch_sub(e.meta.payload_bytes, Ordering::SeqCst);
            self.push_delta(e.meta.payload_bytes, false);
        }
        true
    }

    /// Rescan `node` for its actual best unclaimed entry (select end).
    fn pick_best(node: &Node) -> Option<usize> {
        let mut pick: Option<(usize, i64, u64)> = None;
        for (k, e) in node.entries.iter().enumerate() {
            if e.claimed.load(Ordering::Acquire) {
                continue;
            }
            let seq = node.seq0 + k as u64;
            if pick.is_none_or(|(_, p, s)| e.prio > p || (e.prio == p && seq < s)) {
                pick = Some((k, e.prio, seq));
            }
        }
        pick.map(|(k, _, _)| k)
    }

    /// Rescan `node` for its actual worst stealable unclaimed entry.
    fn pick_worst(node: &Node) -> Option<usize> {
        let mut pick: Option<(usize, i64, u64)> = None;
        for (k, e) in node.entries.iter().enumerate() {
            if e.claimed.load(Ordering::Acquire) || !e.meta.stealable {
                continue;
            }
            let seq = node.seq0 + k as u64;
            if pick.is_none_or(|(_, p, s)| e.prio < p || (e.prio == p && seq > s)) {
                pick = Some((k, e.prio, seq));
            }
        }
        pick.map(|(k, _, _)| k)
    }

    /// Live (non-exhausted, still-linked) blocks — exposed for the unit
    /// tests asserting exhausted blocks actually leave the chains.
    #[cfg(all(test, not(loom)))]
    fn live_blocks(&self) -> usize {
        let mut n = 0;
        self.walk_blocks(&mut |_| n += 1);
        n
    }
}

impl Scheduler for WorkAssistQueue {
    fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta) {
        self.publish(&[(task, priority, meta)]);
    }

    fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]) {
        if batch.is_empty() {
            return;
        }
        self.batch_batches[site.idx()].fetch_add(1, Ordering::Relaxed);
        self.batch_tasks[site.idx()]
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.publish(batch);
    }

    /// Outcome counters only: there is no watermark to adapt (nothing
    /// spills — thieves claim from the same blocks workers do).
    fn feedback(&self, outcome: StealOutcome) {
        match outcome {
            StealOutcome::Granted => {
                self.feedback_grants.fetch_add(1, Ordering::Relaxed);
            }
            StealOutcome::DeniedWaitingTime => {
                self.feedback_wt_denials.fetch_add(1, Ordering::Relaxed);
            }
            StealOutcome::DeniedEmpty => {}
            StealOutcome::TimedOut => {
                self.feedback_timeouts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn select(&self, _worker: usize) -> Option<TaskDesc> {
        loop {
            let mut cand: Option<(*const Node, i64, u64)> = None;
            self.walk_blocks(&mut |node| {
                if let Some((p, s)) = Self::block_best(node) {
                    if cand.is_none_or(|(_, cp, cs)| p > cp || (p == cp && s < cs)) {
                        cand = Some((node as *const Node, p, s));
                    }
                }
            });
            let (node, _, _) = cand?;
            // SAFETY: published blocks stay allocated until Drop.
            let node = unsafe { &*node };
            let Some(k) = Self::pick_best(node) else {
                // Stale summary (every entry was claimed meanwhile):
                // heal it and re-walk.
                Self::recompute(node);
                continue;
            };
            if self.claim(node, k) {
                self.selects.fetch_add(1, Ordering::Relaxed);
                let len_after = self.count.load(Ordering::Relaxed) as u64;
                self.select_len_sum.fetch_add(len_after, Ordering::Relaxed);
                return Some(node.entries[k].task);
            }
        }
    }

    fn len(&self) -> usize {
        self.count.load(Ordering::SeqCst)
    }

    fn stealable_count(&self) -> usize {
        self.steal_count.load(Ordering::SeqCst)
    }

    fn stealable_payload_bytes(&self) -> u64 {
        self.steal_payload.load(Ordering::SeqCst)
    }

    fn min_stealable_payload_bytes(&self) -> u64 {
        self.try_combine();
        self.min_cache.load(Ordering::Acquire)
    }

    fn class_counts(&self) -> [usize; TaskClass::COUNT] {
        let mut counts = [0usize; TaskClass::COUNT];
        for (ix, c) in counts.iter_mut().enumerate() {
            *c = self.class_counts[ix].load(Ordering::Relaxed);
        }
        counts
    }

    fn extract_stealable(&self, max: usize) -> Vec<TaskDesc> {
        let mut out = Vec::new();
        while out.len() < max {
            let mut cand: Option<(*const Node, i64, u64)> = None;
            self.walk_blocks(&mut |node| {
                if let Some((p, s)) = Self::block_worst(node) {
                    if cand.is_none_or(|(_, cp, cs)| p < cp || (p == cp && s > cs)) {
                        cand = Some((node as *const Node, p, s));
                    }
                }
            });
            let Some((node, _, _)) = cand else { break };
            // SAFETY: published blocks stay allocated until Drop.
            let node = unsafe { &*node };
            let Some(k) = Self::pick_worst(node) else {
                Self::recompute(node);
                continue;
            };
            if self.claim(node, k) {
                out.push(node.entries[k].task);
            }
        }
        self.steal_extracted
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    }

    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize {
        self.scans.fetch_add(1, Ordering::Relaxed);
        let mut n = 0;
        self.walk_entries(&mut |_, _, e, _| {
            if filter(&e.task) {
                n += 1;
            }
        });
        n
    }

    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc> {
        self.scans.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        while out.len() < max {
            let mut pick: Option<(*const Node, usize, i64, u64)> = None;
            self.walk_entries(&mut |node, k, e, seq| {
                if !filter(&e.task) {
                    return;
                }
                if pick.is_none_or(|(_, _, p, s)| e.prio < p || (e.prio == p && seq > s)) {
                    pick = Some((node as *const Node, k, e.prio, seq));
                }
            });
            let Some((node, k, _, _)) = pick else { break };
            // SAFETY: published blocks stay allocated until Drop.
            let node = unsafe { &*node };
            if self.claim(node, k) {
                out.push(node.entries[k].task);
            }
        }
        self.steal_extracted
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    }

    fn max_priority(&self) -> Option<i64> {
        let mut best: Option<i64> = None;
        self.walk_entries(&mut |_, _, e, _| {
            if best.is_none_or(|p| e.prio > p) {
                best = Some(e.prio);
            }
        });
        best
    }

    fn stats(&self) -> SchedStats {
        // Fold pending deltas so `min_payload_resets` is current.
        self.try_combine();
        let mut batches = [BatchCounter::default(); BatchSite::COUNT];
        for (ix, b) in batches.iter_mut().enumerate() {
            b.batches = self.batch_batches[ix].load(Ordering::Relaxed);
            b.tasks = self.batch_tasks[ix].load(Ordering::Relaxed);
        }
        SchedStats {
            inserts: self.inserts.load(Ordering::Relaxed),
            selects: self.selects.load(Ordering::Relaxed),
            steal_extracted: self.steal_extracted.load(Ordering::Relaxed),
            select_len_sum: self.select_len_sum.load(Ordering::Relaxed),
            scans: self.scans.load(Ordering::Relaxed),
            batches,
            feedback_grants: self.feedback_grants.load(Ordering::Relaxed),
            feedback_wt_denials: self.feedback_wt_denials.load(Ordering::Relaxed),
            feedback_timeouts: self.feedback_timeouts.load(Ordering::Relaxed),
            watermark: 0,
            extract_fallback_walks: 0,
            min_payload_resets: self.resets_cache.load(Ordering::Acquire),
            lock_acquisitions: 0,
            cas_retries: self.cas_retries.load(Ordering::Relaxed),
        }
    }

    fn drain(&self) -> Vec<TaskDesc> {
        let mut all: Vec<(*const Node, usize, i64, u64)> = Vec::new();
        self.walk_entries(&mut |node, k, e, seq| {
            all.push((node as *const Node, k, e.prio, seq));
        });
        // The central queue's drain order: ascending (priority, age) =
        // priority ascending, newest first among equals.
        all.sort_by(|a, b| a.2.cmp(&b.2).then(b.3.cmp(&a.3)));
        let mut out = Vec::with_capacity(all.len());
        for (node, k, _, _) in all {
            // SAFETY: published blocks stay allocated until Drop.
            let node = unsafe { &*node };
            if self.claim(node, k) {
                out.push(node.entries[k].task);
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "workassist"
    }
}

impl fmt::Debug for WorkAssistQueue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let shards = self.shards.len();
        let len = self.count.load(Ordering::Relaxed);
        let stealable = self.steal_count.load(Ordering::Relaxed);
        write!(f, "WorkAssistQueue {{ shards: {shards}, len: {len}, stealable: {stealable} }}")
    }
}

impl Drop for WorkAssistQueue {
    fn drop(&mut self) {
        // Deferred reclamation happens here, and only here: walk the
        // retention chain (every block ever published, linked or not)
        // and the pending-delta stack.
        let mut cur = self.all_head.swap(ptr::null_mut(), Ordering::AcqRel);
        while !cur.is_null() {
            // SAFETY: `&mut self` — no other thread can hold a
            // reference into the queue anymore.
            let node = unsafe { Box::from_raw(cur) };
            cur = node.all_next;
        }
        let mut delta = self.deltas.swap(ptr::null_mut(), Ordering::AcqRel);
        while !delta.is_null() {
            // SAFETY: as above.
            let d = unsafe { Box::from_raw(delta) };
            delta = d.next;
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::super::CentralQueue;
    use super::*;

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    fn meta(stealable: bool, payload: u64) -> TaskMeta {
        TaskMeta {
            stealable,
            payload_bytes: payload,
            class: TaskClass::Synthetic,
        }
    }

    /// Single-threaded, the claim order is *identical* to the central
    /// queue: select = priority-then-FIFO, extraction = lowest priority
    /// newest-first, drain = central's map order.
    #[test]
    fn order_identical_to_central_single_threaded() {
        let wa = WorkAssistQueue::new(4);
        let central = CentralQueue::new();
        let prios = [5i64, 9, 5, -3, 9, 0, 7, 5, -3, 2];
        for (i, &p) in prios.iter().enumerate() {
            let m = meta(i % 3 != 0, 10 * i as u64);
            wa.insert_meta(t(i as u32), p, m);
            central.insert_meta(t(i as u32), p, m);
        }
        assert_eq!(wa.select(0), central.select());
        assert_eq!(wa.select(1), central.select());
        assert_eq!(wa.extract_stealable(3), central.extract_stealable(3));
        assert_eq!(wa.select(2), central.select());
        assert_eq!(
            Scheduler::drain(&wa),
            Scheduler::drain(&central),
            "drain preserves central's (priority asc, newest-first) order"
        );
        assert!(wa.is_empty());
    }

    /// The full single-threaded hot path performs zero lock
    /// acquisitions and zero CAS retries — the lock-freedom claim the
    /// bench and e2e gates assert.
    #[test]
    fn hot_path_is_lock_free_single_threaded() {
        let q = WorkAssistQueue::new(2);
        let mut batch = Vec::new();
        for i in 0..8u32 {
            batch.push((t(i), i as i64, meta(true, 64)));
        }
        q.insert_batch_at(BatchSite::Activation, &batch);
        for i in 8..16u32 {
            q.insert_meta(t(i), i as i64, meta(i % 2 == 0, 32));
        }
        while q.select(0).is_some() {}
        let _ = q.extract_stealable(4);
        let _ = q.min_stealable_payload_bytes();
        let s = q.stats();
        assert_eq!(s.lock_acquisitions, 0, "no mutex anywhere on this backend");
        assert_eq!(s.cas_retries, 0, "single-threaded CASes never fail");
        assert_eq!(s.scans, 0, "accounting paths never scan");
    }

    /// The flat-combined multiset minimum is exact at every
    /// single-threaded read, including duplicate payloads and
    /// interleaved removals (mirrors the central backend's test).
    #[test]
    fn min_payload_is_exact_through_the_combiner() {
        let q = WorkAssistQueue::new(2);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        for (i, payload) in [(0u32, 200u64), (1, 200), (2, 500), (4, 900)] {
            q.insert_meta(t(i), i as i64, meta(true, payload));
        }
        q.insert_meta(t(3), 3, meta(false, 1));
        assert_eq!(q.min_stealable_payload_bytes(), 200);
        assert_eq!(q.extract_stealable(1), vec![t(0)]);
        assert_eq!(q.min_stealable_payload_bytes(), 200, "duplicate survives");
        assert_eq!(q.extract_stealable(1), vec![t(1)]);
        assert_eq!(q.min_stealable_payload_bytes(), 500);
        assert_eq!(q.extract_stealable(1), vec![t(2)]);
        assert_eq!(q.min_stealable_payload_bytes(), 900);
        let _ = q.extract_stealable(1);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        assert_eq!(q.len(), 1, "non-stealable task remains");
        assert_eq!(q.stats().min_payload_resets, 0);
    }

    /// Exhausted blocks leave the traversal chains: after a claim-all,
    /// a subsequent walk unlinks every block, so chain length tracks
    /// the live queue, not the insert history.
    #[test]
    fn exhausted_blocks_are_unlinked() {
        let q = WorkAssistQueue::new(2);
        for round in 0..10u32 {
            for i in 0..20u32 {
                q.insert(t(round * 20 + i), i as i64);
            }
            while q.select(0).is_some() {}
            // The drain-walk above already pruned what it traversed;
            // one more walk reaches a fully unlinked state.
            assert_eq!(q.live_blocks(), 0, "round {round}");
        }
    }

    /// Per-class counts and batch-site accounting flow through the
    /// lock-free paths exactly as on the locked backends.
    #[test]
    fn class_counts_and_batches_track() {
        let q = WorkAssistQueue::new(2);
        let potrf = TaskDesc::indexed(TaskClass::Potrf, 0, 0, 0);
        let mp = TaskMeta {
            stealable: true,
            payload_bytes: 100,
            class: TaskClass::Potrf,
        };
        let gemm = TaskDesc::indexed(TaskClass::Gemm, 1, 0, 0);
        let mg = TaskMeta {
            stealable: true,
            payload_bytes: 300,
            class: TaskClass::Gemm,
        };
        let batch = vec![(potrf, 3, mp), (gemm, 1, mg)];
        q.insert_batch_at(BatchSite::StealReply, &batch);
        assert_eq!(q.class_counts()[TaskClass::Potrf.idx()], 1);
        assert_eq!(q.class_counts()[TaskClass::Gemm.idx()], 1);
        assert_eq!(q.stats().site(BatchSite::StealReply).batches, 1);
        assert_eq!(q.stats().site(BatchSite::StealReply).tasks, 2);
        assert_eq!(q.stealable_payload_bytes(), 400);
        // Extraction takes the lowest priority: the GEMM.
        let stolen = q.extract_stealable(1);
        assert_eq!(stolen[0].class, TaskClass::Gemm);
        assert_eq!(q.class_counts()[TaskClass::Gemm.idx()], 0);
        assert_eq!(q.min_stealable_payload_bytes(), 100);
    }

    /// Real threads hammering every op conserve tasks: nothing is lost,
    /// nothing claimed twice, and the quiesced accounting is exact.
    #[test]
    #[cfg_attr(miri, ignore)] // threads + raw-pointer walks: minutes under miri
    fn threaded_claims_conserve_tasks() {
        use std::collections::HashSet;
        use std::sync::Arc;

        let q = Arc::new(WorkAssistQueue::new(4));
        let per_thread = 200u32;
        let mut writers = Vec::new();
        for w in 0..3u32 {
            let q = Arc::clone(&q);
            writers.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let id = w * per_thread + i;
                    q.insert_meta(t(id), (id % 7) as i64, meta(id % 2 == 0, id as u64));
                }
            }));
        }
        let mut takers = Vec::new();
        for w in 0..3usize {
            let q = Arc::clone(&q);
            takers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for round in 0..per_thread {
                    if w == 0 && round % 8 == 0 {
                        got.extend(q.extract_stealable(2));
                    } else if let Some(task) = q.select(w) {
                        got.push(task);
                    }
                }
                got
            }));
        }
        for h in writers {
            h.join().unwrap();
        }
        let mut removed: Vec<TaskDesc> = Vec::new();
        for h in takers {
            removed.extend(h.join().unwrap());
        }
        removed.extend(Scheduler::drain(&*q));
        assert_eq!(removed.len(), 3 * per_thread as usize, "conservation");
        let distinct: HashSet<u32> = removed.iter().map(|d| d.i).collect();
        assert_eq!(distinct.len(), removed.len(), "no task claimed twice");
        assert_eq!(q.len(), 0);
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        assert_eq!(q.stats().min_payload_resets, 0);
        assert_eq!(q.stats().lock_acquisitions, 0);
    }
}
