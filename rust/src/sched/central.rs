//! The reference backend: one priority map behind one lock.
//!
//! This is the queue the paper's No-Steal variance analysis (§4.4) is
//! about: every worker, the comm thread and the migrate thread serialize
//! on the same mutex. It stays the default because it is deterministic
//! (single global priority-then-FIFO order) and is the semantic oracle
//! the sharded backend is property-tested against.
//!
//! Steal accounting is incremental: a `BTreeSet` of the stealable
//! entries' keys rides alongside the map, kept in sync on every
//! insert/select/extract, so the victim-side census
//! (`stealable_count`/`stealable_payload_bytes`) is an O(1) read and
//! `extract_stealable` removes lowest-priority stealable tasks without
//! filtering the queue.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::dataflow::task::{TaskClass, TaskDesc};

use super::{BatchSite, PayloadMultiset, QKey, SchedStats, Scheduler, StealOutcome, TaskMeta};

#[derive(Debug, Default)]
struct Central {
    map: BTreeMap<QKey, (TaskDesc, TaskMeta)>,
    /// Keys of entries whose meta marks them stealable (same ordering as
    /// `map`, so `iter().take(k)` is "k lowest-priority stealable").
    steal_idx: BTreeSet<QKey>,
    steal_payload: u64,
    /// Exact multiset of the queued stealable payloads (shared
    /// [`PayloadMultiset`]), maintained on every insert/select/extract.
    steal_payloads: PayloadMultiset,
    /// Queued tasks per class (keyed on `task.class`).
    class_counts: [usize; TaskClass::COUNT],
    seq: u64,
    stats: SchedStats,
}

impl Central {
    /// Bookkeeping for one removed entry: steal index/payload (incl. the
    /// exact payload multiset) and the per-class count.
    fn forget(&mut self, key: QKey, task: &TaskDesc, meta: TaskMeta) {
        if meta.stealable {
            self.steal_idx.remove(&key);
            self.steal_payload -= meta.payload_bytes;
            self.steal_payloads.remove(meta.payload_bytes);
        }
        self.class_counts[task.class.idx()] -= 1;
    }
}

/// A node's ready-task queue: `BTreeMap` keyed by `(priority,
/// insertion-seq)` so both ends are O(log n) (`select` = pop-max, steal
/// extraction = pop-min) and iteration order is deterministic.
#[derive(Debug, Default)]
pub struct CentralQueue {
    inner: Mutex<Central>,
    /// Feedback counters live outside the mutex: `feedback` must not
    /// add a third acquisition of the §4.4-contended lock to every
    /// steal poll just to bump a counter.
    feedback_grants: AtomicU64,
    feedback_wt_denials: AtomicU64,
    feedback_timeouts: AtomicU64,
    /// Every acquisition of the queue mutex, feeding
    /// [`SchedStats::lock_acquisitions`] — the §4.4 contention metric
    /// the lock-free backend's zero is compared against.
    lock_acquisitions: AtomicU64,
}

impl CentralQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// The one way in to the queue state: every caller goes through
    /// here, so the acquisition counter can never undercount.
    fn locked(&self) -> MutexGuard<'_, Central> {
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.locked().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&self, task: TaskDesc, priority: i64) {
        self.insert_meta(task, priority, TaskMeta::for_task(task));
    }

    pub fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta) {
        let mut q = self.locked();
        Self::insert_locked(&mut q, task, priority, meta);
    }

    fn insert_locked(q: &mut Central, task: TaskDesc, priority: i64, meta: TaskMeta) {
        q.seq += 1;
        q.stats.inserts += 1;
        let key = QKey {
            prio: priority,
            age: u64::MAX - q.seq,
        };
        if meta.stealable {
            q.steal_idx.insert(key);
            q.steal_payload += meta.payload_bytes;
            q.steal_payloads.add(meta.payload_bytes);
        }
        q.class_counts[task.class.idx()] += 1;
        q.map.insert(key, (task, meta));
    }

    /// Batched insert: the whole batch enters under one lock
    /// acquisition, booked against `site` (steal-reply re-enqueue,
    /// gate-denial reinsert, activation ready set).
    pub fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]) {
        if batch.is_empty() {
            return;
        }
        let mut q = self.locked();
        q.stats.batches[site.idx()].batches += 1;
        q.stats.batches[site.idx()].tasks += batch.len() as u64;
        for &(task, priority, meta) in batch {
            Self::insert_locked(&mut q, task, priority, meta);
        }
    }

    /// [`CentralQueue::insert_batch_at`] without a protocol role.
    pub fn insert_batch_meta(&self, batch: &[(TaskDesc, i64, TaskMeta)]) {
        self.insert_batch_at(BatchSite::Other, batch);
    }

    /// Steal-decision feedback: the central backend has no watermark to
    /// adapt, so the outcome is only recorded (keeps both backends
    /// observable under the same protocol) — in lock-free atomics, so a
    /// steal poll never takes the §4.4-contended queue lock just to
    /// bump a counter.
    pub fn feedback(&self, outcome: StealOutcome) {
        match outcome {
            StealOutcome::Granted => {
                self.feedback_grants.fetch_add(1, Ordering::Relaxed);
            }
            StealOutcome::DeniedWaitingTime => {
                self.feedback_wt_denials.fetch_add(1, Ordering::Relaxed);
            }
            StealOutcome::DeniedEmpty => {}
            StealOutcome::TimedOut => {
                self.feedback_timeouts.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Worker-side `select`: highest-priority ready task.
    pub fn select(&self) -> Option<TaskDesc> {
        let mut q = self.locked();
        let entry = q.map.pop_last();
        if let Some((key, (task, meta))) = entry {
            q.stats.selects += 1;
            q.stats.select_len_sum += q.map.len() as u64;
            q.forget(key, &task, meta);
            Some(task)
        } else {
            None
        }
    }

    /// Queued stealable tasks — O(1), no scan.
    pub fn stealable_count(&self) -> usize {
        self.locked().steal_idx.len()
    }

    /// Payload bytes of the queued stealable tasks — O(1), no scan.
    pub fn stealable_payload_bytes(&self) -> u64 {
        self.locked().steal_payload
    }

    /// The *exact* minimum queued stealable payload — O(1) read of the
    /// cached multiset minimum (`u64::MAX` when nothing stealable is
    /// queued), no scan.
    pub fn min_stealable_payload_bytes(&self) -> u64 {
        self.locked().steal_payloads.min()
    }

    /// Queued tasks per class — O(1) copy of the incremental counters.
    pub fn class_counts(&self) -> [usize; TaskClass::COUNT] {
        self.locked().class_counts
    }

    /// Migrate-thread extraction of up to `max` stealable tasks, lowest
    /// priority first, via the stealable index — no filtering of the
    /// queue. Still *competes* with `select` on the one lock: the §4.4
    /// contention is the backend's structure, not the extraction's cost.
    pub fn extract_stealable(&self, max: usize) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let mut q = self.locked();
        let keys: Vec<QKey> = q.steal_idx.iter().take(max).copied().collect();
        let mut out = Vec::with_capacity(keys.len());
        for k in keys {
            let (task, meta) = q.map.remove(&k).expect("indexed key vanished");
            q.forget(k, &task, meta);
            out.push(task);
        }
        q.stats.steal_extracted += out.len() as u64;
        out
    }

    /// Count tasks satisfying `filter` (O(n) oracle; counted as a scan).
    pub fn count_matching(&self, filter: impl Fn(&TaskDesc) -> bool) -> usize {
        let mut q = self.locked();
        q.stats.scans += 1;
        q.map.values().filter(|(t, _)| filter(t)).count()
    }

    /// Scan-based extraction: up to `max` tasks satisfying `filter`,
    /// lowest priority first (O(n) oracle; counted as a scan).
    pub fn extract_for_steal(
        &self,
        max: usize,
        filter: impl Fn(&TaskDesc) -> bool,
    ) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let mut q = self.locked();
        q.stats.scans += 1;
        // Collect keys only for matches: the scan itself allocates
        // nothing per non-matching task and never copies a TaskDesc.
        let keys: Vec<QKey> = q
            .map
            .iter()
            .filter(|(_, (t, _))| filter(t))
            .take(max)
            .map(|(k, _)| *k)
            .collect();
        let mut out = Vec::with_capacity(keys.len());
        for k in keys {
            let (task, meta) = q.map.remove(&k).expect("key vanished");
            q.forget(k, &task, meta);
            out.push(task);
        }
        q.stats.steal_extracted += out.len() as u64;
        out
    }

    /// Peek the highest priority value (scheduling diagnostics).
    pub fn max_priority(&self) -> Option<i64> {
        let q = self.locked();
        q.map.last_key_value().map(|(k, _)| k.prio)
    }

    pub fn stats(&self) -> SchedStats {
        let mut stats = {
            let q = self.locked();
            let mut stats = q.stats;
            stats.min_payload_resets = q.steal_payloads.resets();
            stats
        };
        stats.feedback_grants = self.feedback_grants.load(Ordering::Relaxed);
        stats.feedback_wt_denials = self.feedback_wt_denials.load(Ordering::Relaxed);
        stats.feedback_timeouts = self.feedback_timeouts.load(Ordering::Relaxed);
        stats.lock_acquisitions = self.lock_acquisitions.load(Ordering::Relaxed);
        stats
    }

    /// Drain everything (shutdown paths in tests).
    pub fn drain(&self) -> Vec<TaskDesc> {
        let mut q = self.locked();
        let out = q.map.values().map(|(t, _)| *t).collect();
        q.map.clear();
        q.steal_idx.clear();
        q.steal_payload = 0;
        q.steal_payloads.clear();
        q.class_counts = [0; TaskClass::COUNT];
        out
    }
}

impl Scheduler for CentralQueue {
    fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta) {
        CentralQueue::insert_meta(self, task, priority, meta)
    }

    fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]) {
        CentralQueue::insert_batch_at(self, site, batch)
    }

    fn feedback(&self, outcome: StealOutcome) {
        CentralQueue::feedback(self, outcome)
    }

    fn select(&self, _worker: usize) -> Option<TaskDesc> {
        CentralQueue::select(self)
    }

    fn len(&self) -> usize {
        CentralQueue::len(self)
    }

    fn stealable_count(&self) -> usize {
        CentralQueue::stealable_count(self)
    }

    fn stealable_payload_bytes(&self) -> u64 {
        CentralQueue::stealable_payload_bytes(self)
    }

    fn min_stealable_payload_bytes(&self) -> u64 {
        CentralQueue::min_stealable_payload_bytes(self)
    }

    fn class_counts(&self) -> [usize; TaskClass::COUNT] {
        CentralQueue::class_counts(self)
    }

    fn extract_stealable(&self, max: usize) -> Vec<TaskDesc> {
        CentralQueue::extract_stealable(self, max)
    }

    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize {
        CentralQueue::count_matching(self, filter)
    }

    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc> {
        CentralQueue::extract_for_steal(self, max, filter)
    }

    fn max_priority(&self) -> Option<i64> {
        CentralQueue::max_priority(self)
    }

    fn stats(&self) -> SchedStats {
        CentralQueue::stats(self)
    }

    fn drain(&self) -> Vec<TaskDesc> {
        CentralQueue::drain(self)
    }

    fn name(&self) -> &'static str {
        "central"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn select_is_priority_then_fifo() {
        let q = CentralQueue::new();
        q.insert(t(1), 5);
        q.insert(t(2), 9);
        q.insert(t(3), 5);
        assert_eq!(q.select(), Some(t(2)));
        assert_eq!(q.select(), Some(t(1)), "FIFO among equal priorities");
        assert_eq!(q.select(), Some(t(3)));
        assert_eq!(q.select(), None);
    }

    #[test]
    fn steal_takes_lowest_priority_first() {
        let q = CentralQueue::new();
        for (i, p) in [(1, 10), (2, 1), (3, 5), (4, 2)] {
            q.insert(t(i), p);
        }
        let stolen = q.extract_for_steal(2, |_| true);
        assert_eq!(stolen, vec![t(2), t(4)], "two lowest priorities");
        assert_eq!(q.len(), 2);
        assert_eq!(q.select(), Some(t(1)), "high-priority work untouched");
    }

    #[test]
    fn steal_respects_filter_and_max() {
        let q = CentralQueue::new();
        for i in 0..10 {
            q.insert(t(i), i as i64);
        }
        let stolen = q.extract_for_steal(3, |task| task.i % 2 == 0);
        assert_eq!(stolen.len(), 3);
        assert!(stolen.iter().all(|s| s.i % 2 == 0));
        assert_eq!(q.len(), 7);
        assert_eq!(q.count_matching(|task| task.i % 2 == 0), 2);
    }

    #[test]
    fn stats_accumulate() {
        let q = CentralQueue::new();
        q.insert(t(0), 0);
        q.insert(t(1), 1);
        let _ = q.select();
        let _ = q.extract_for_steal(1, |_| true);
        let s = q.stats();
        assert_eq!((s.inserts, s.selects, s.steal_extracted), (2, 1, 1));
        assert_eq!(s.select_len_sum, 1);
        assert_eq!(s.scans, 1, "filter-based extraction is a scan");
    }

    #[test]
    fn extract_zero_is_noop() {
        let q = CentralQueue::new();
        q.insert(t(0), 0);
        assert!(q.extract_for_steal(0, |_| true).is_empty());
        assert!(q.extract_stealable(0).is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn accounting_is_exact_under_mixed_ops() {
        let q = CentralQueue::new();
        for i in 0..12u32 {
            q.insert_meta(
                t(i),
                i as i64,
                TaskMeta {
                    stealable: i % 3 != 0,
                    payload_bytes: (i as u64) * 10,
                    class: TaskClass::Synthetic,
                },
            );
        }
        // stealable: i = 1,2,4,5,7,8,10,11 -> 8 tasks, payload 480
        assert_eq!(q.stealable_count(), 8);
        assert_eq!(q.stealable_payload_bytes(), 480);
        // select takes the highest priority (i=11, stealable)
        assert_eq!(q.select(), Some(t(11)));
        assert_eq!(q.stealable_count(), 7);
        assert_eq!(q.stealable_payload_bytes(), 370);
        // extraction takes the two lowest-priority stealable (i=1,2)
        let stolen = q.extract_stealable(2);
        assert_eq!(stolen, vec![t(1), t(2)]);
        assert_eq!(q.stealable_count(), 5);
        assert_eq!(q.stealable_payload_bytes(), 340);
        assert_eq!(q.stats().scans, 0, "no scan on the accounting path");
        // non-stealable tasks are invisible to extract_stealable
        let rest = q.extract_stealable(100);
        assert_eq!(rest.len(), 5);
        assert!(rest.iter().all(|s| s.i % 3 != 0));
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
        assert_eq!(q.len(), 4, "non-stealable tasks remain queued");
    }

    #[test]
    fn drain_resets_accounting() {
        let q = CentralQueue::new();
        let stealable = TaskMeta {
            stealable: true,
            payload_bytes: 64,
            class: TaskClass::Synthetic,
        };
        q.insert_meta(t(0), 0, stealable);
        q.insert_meta(
            t(1),
            1,
            TaskMeta {
                stealable: false,
                ..stealable
            },
        );
        assert_eq!(q.drain().len(), 2);
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        assert_eq!(q.class_counts(), [0; TaskClass::COUNT]);
    }

    /// The payload minimum is exact under any removal order: when the
    /// lightest stealable task leaves, the bound rises to the true next
    /// minimum instead of going stale-low, and it returns to the
    /// sentinel when the stealable set empties.
    #[test]
    fn min_payload_is_exact_under_removals() {
        let q = CentralQueue::new();
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        for (i, payload) in [(0u32, 200u64), (1, 200), (2, 500), (4, 900)] {
            q.insert_meta(
                t(i),
                i as i64,
                TaskMeta {
                    stealable: true,
                    payload_bytes: payload,
                    class: TaskClass::Synthetic,
                },
            );
        }
        // Non-stealable payloads never feed the bound.
        q.insert_meta(
            t(3),
            3,
            TaskMeta {
                stealable: false,
                payload_bytes: 1,
                class: TaskClass::Synthetic,
            },
        );
        assert_eq!(q.min_stealable_payload_bytes(), 200);
        // One of the two 200-byte tasks leaves (extraction is lowest
        // priority first = i=0): the duplicate keeps the min at 200.
        assert_eq!(q.extract_stealable(1), vec![t(0)]);
        assert_eq!(q.min_stealable_payload_bytes(), 200, "duplicate survives");
        // The last 200-byte task leaves: the min rises to the *true*
        // next minimum — the exactness the old monotone bound lost.
        assert_eq!(q.extract_stealable(1), vec![t(1)]);
        assert_eq!(q.min_stealable_payload_bytes(), 500);
        assert_eq!(q.extract_stealable(1), vec![t(2)]);
        assert_eq!(q.min_stealable_payload_bytes(), 900);
        let _ = q.extract_stealable(1); // removes i=4: stealable set empty
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
        assert_eq!(q.len(), 1, "non-stealable task remains");
        assert_eq!(q.stats().min_payload_resets, 0, "never a stale reset");
    }
}
