//! The reference backend: one priority map behind one lock.
//!
//! This is the queue the paper's No-Steal variance analysis (§4.4) is
//! about: every worker, the comm thread and the migrate thread serialize
//! on the same mutex. It stays the default because it is deterministic
//! (single global priority-then-FIFO order) and is the semantic oracle
//! the sharded backend is property-tested against.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::dataflow::task::TaskDesc;

use super::{QKey, SchedStats, Scheduler};

#[derive(Debug, Default)]
struct Central {
    map: BTreeMap<QKey, TaskDesc>,
    seq: u64,
    stats: SchedStats,
}

/// A node's ready-task queue: `BTreeMap` keyed by `(priority,
/// insertion-seq)` so both ends are O(log n) (`select` = pop-max, steal
/// extraction = pop-min) and iteration order is deterministic.
#[derive(Debug, Default)]
pub struct CentralQueue {
    inner: Mutex<Central>,
}

impl CentralQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&self, task: TaskDesc, priority: i64) {
        let mut q = self.inner.lock().unwrap();
        q.seq += 1;
        q.stats.inserts += 1;
        let key = QKey {
            prio: priority,
            age: u64::MAX - q.seq,
        };
        q.map.insert(key, task);
    }

    /// Worker-side `select`: highest-priority ready task.
    pub fn select(&self) -> Option<TaskDesc> {
        let mut q = self.inner.lock().unwrap();
        let entry = q.map.pop_last();
        if entry.is_some() {
            q.stats.selects += 1;
            q.stats.select_len_sum += q.map.len() as u64;
        }
        entry.map(|(_, t)| t)
    }

    /// Count tasks satisfying `filter` (victim-side stealable census).
    pub fn count_matching(&self, filter: impl Fn(&TaskDesc) -> bool) -> usize {
        let q = self.inner.lock().unwrap();
        q.map.values().filter(|t| filter(t)).count()
    }

    /// Migrate-thread extraction: up to `max` tasks satisfying `filter`,
    /// lowest priority first. This *competes* with `select` — the caller
    /// path holds the same lock workers use, exactly the contention the
    /// paper describes; the allowance is an upper bound, not a guarantee.
    pub fn extract_for_steal(
        &self,
        max: usize,
        filter: impl Fn(&TaskDesc) -> bool,
    ) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let mut q = self.inner.lock().unwrap();
        // Collect keys only for matches: the scan itself allocates
        // nothing per non-matching task and never copies a TaskDesc.
        let keys: Vec<QKey> = q
            .map
            .iter()
            .filter(|(_, t)| filter(t))
            .take(max)
            .map(|(k, _)| *k)
            .collect();
        let out: Vec<TaskDesc> = keys
            .iter()
            .map(|k| q.map.remove(k).expect("key vanished"))
            .collect();
        q.stats.steal_extracted += out.len() as u64;
        out
    }

    /// Peek the highest priority value (scheduling diagnostics).
    pub fn max_priority(&self) -> Option<i64> {
        let q = self.inner.lock().unwrap();
        q.map.last_key_value().map(|(k, _)| k.prio)
    }

    pub fn stats(&self) -> SchedStats {
        self.inner.lock().unwrap().stats
    }

    /// Drain everything (shutdown paths in tests).
    pub fn drain(&self) -> Vec<TaskDesc> {
        let mut q = self.inner.lock().unwrap();
        let out = q.map.values().copied().collect();
        q.map.clear();
        out
    }
}

impl Scheduler for CentralQueue {
    fn insert(&self, task: TaskDesc, priority: i64) {
        CentralQueue::insert(self, task, priority)
    }

    fn select(&self, _worker: usize) -> Option<TaskDesc> {
        CentralQueue::select(self)
    }

    fn len(&self) -> usize {
        CentralQueue::len(self)
    }

    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize {
        CentralQueue::count_matching(self, filter)
    }

    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc> {
        CentralQueue::extract_for_steal(self, max, filter)
    }

    fn max_priority(&self) -> Option<i64> {
        CentralQueue::max_priority(self)
    }

    fn stats(&self) -> SchedStats {
        CentralQueue::stats(self)
    }

    fn drain(&self) -> Vec<TaskDesc> {
        CentralQueue::drain(self)
    }

    fn name(&self) -> &'static str {
        "central"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn select_is_priority_then_fifo() {
        let q = CentralQueue::new();
        q.insert(t(1), 5);
        q.insert(t(2), 9);
        q.insert(t(3), 5);
        assert_eq!(q.select(), Some(t(2)));
        assert_eq!(q.select(), Some(t(1)), "FIFO among equal priorities");
        assert_eq!(q.select(), Some(t(3)));
        assert_eq!(q.select(), None);
    }

    #[test]
    fn steal_takes_lowest_priority_first() {
        let q = CentralQueue::new();
        for (i, p) in [(1, 10), (2, 1), (3, 5), (4, 2)] {
            q.insert(t(i), p);
        }
        let stolen = q.extract_for_steal(2, |_| true);
        assert_eq!(stolen, vec![t(2), t(4)], "two lowest priorities");
        assert_eq!(q.len(), 2);
        assert_eq!(q.select(), Some(t(1)), "high-priority work untouched");
    }

    #[test]
    fn steal_respects_filter_and_max() {
        let q = CentralQueue::new();
        for i in 0..10 {
            q.insert(t(i), i as i64);
        }
        let stolen = q.extract_for_steal(3, |task| task.i % 2 == 0);
        assert_eq!(stolen.len(), 3);
        assert!(stolen.iter().all(|s| s.i % 2 == 0));
        assert_eq!(q.len(), 7);
        assert_eq!(q.count_matching(|task| task.i % 2 == 0), 2);
    }

    #[test]
    fn stats_accumulate() {
        let q = CentralQueue::new();
        q.insert(t(0), 0);
        q.insert(t(1), 1);
        let _ = q.select();
        let _ = q.extract_for_steal(1, |_| true);
        let s = q.stats();
        assert_eq!((s.inserts, s.selects, s.steal_extracted), (2, 1, 1));
        assert_eq!(s.select_len_sum, 1);
    }

    #[test]
    fn extract_zero_is_noop() {
        let q = CentralQueue::new();
        q.insert(t(0), 0);
        assert!(q.extract_for_steal(0, |_| true).is_empty());
        assert_eq!(q.len(), 1);
    }
}
