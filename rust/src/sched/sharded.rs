//! The sharded backend: per-worker priority shards + a low-priority
//! steal pool.
//!
//! Khatiri et al. ("Work Stealing with latency") show steal-path latency
//! dominates when victim-side extraction serializes with execution;
//! Fernandes et al. ("Adaptive Asynchronous Work-Stealing") make the
//! same point for distributed runtimes. This backend decouples the two
//! paths:
//!
//! * **Inserts** spread round-robin across per-worker shards, each its
//!   own `BTreeMap` behind its own mutex.
//! * **Workers** `select` from their own shard (priority-then-FIFO),
//!   fall back to the steal pool, and finally rebalance one task from a
//!   neighbor shard — so the hot path touches one uncontended lock.
//! * **Shards over the spill watermark** shed their lowest-priority task
//!   into the steal pool on insert: the pool accumulates exactly the
//!   tasks that would wait longest locally — §3's cheapest to give away.
//! * **Victims** (`extract_for_steal`) drain the pool, only falling back
//!   to scanning shards when the pool cannot satisfy the allowance, so a
//!   steal request normally never blocks a worker `select`.
//!
//! At most one lock is ever held at a time (a spilled task is popped,
//! the shard unlocked, then the pool locked), so the backend is
//! deadlock-free by construction. The global task count lives in an
//! atomic that is incremented *before* a task becomes visible and
//! decremented only when one is handed out, so `is_empty()` never
//! under-reports — the property Safra-style passivity checks rely on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::dataflow::task::TaskDesc;

use super::{QKey, SchedStats, Scheduler};

/// A shard larger than this sheds its lowest-priority task into the
/// steal pool on insert (20 ≈ half the paper's 40 workers, the same
/// constant PaRSEC uses for chunked victim policies).
pub const SPILL_THRESHOLD: usize = 20;

type Shard = BTreeMap<QKey, TaskDesc>;

/// Per-worker sharded ready queue with a low-priority steal pool.
#[derive(Debug)]
pub struct ShardedQueue {
    shards: Vec<Mutex<Shard>>,
    pool: Mutex<Shard>,
    /// Global insertion sequence: FIFO tie-breaking is consistent across
    /// shards and with the central backend.
    seq: AtomicU64,
    /// Round-robin insert cursor.
    rr: AtomicU64,
    /// Tasks currently queued (shards + pool). See module doc for the
    /// visibility contract.
    count: AtomicUsize,
    inserts: AtomicU64,
    selects: AtomicU64,
    steal_extracted: AtomicU64,
    select_len_sum: AtomicU64,
}

impl ShardedQueue {
    /// One shard per worker thread of the owning node.
    pub fn new(workers: usize) -> Self {
        let n = workers.max(1);
        ShardedQueue {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            pool: Mutex::new(Shard::new()),
            seq: AtomicU64::new(0),
            rr: AtomicU64::new(0),
            count: AtomicUsize::new(0),
            inserts: AtomicU64::new(0),
            selects: AtomicU64::new(0),
            steal_extracted: AtomicU64::new(0),
            select_len_sum: AtomicU64::new(0),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Tasks currently waiting in the steal pool (diagnostics).
    pub fn pool_len(&self) -> usize {
        self.pool.lock().unwrap().len()
    }

    pub fn len(&self) -> usize {
        self.count.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&self, task: TaskDesc, priority: i64) {
        // `seq`/`rr`/stat counters only need uniqueness, not ordering
        // guarantees (a thread's own RMWs on one atomic stay in program
        // order), so Relaxed keeps them off the coherence hot path.
        // `count` is the exception: it SeqCst-pairs with the threaded
        // runtime's parked-worker protocol and Safra passivity checks.
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let key = QKey {
            prio: priority,
            age: u64::MAX - seq,
        };
        // Count up BEFORE the task becomes selectable: a concurrent
        // passivity check must never see empty while a task exists.
        self.count.fetch_add(1, Ordering::SeqCst);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        let shard_ix =
            (self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len() as u64) as usize;
        let spilled = {
            let mut shard = self.shards[shard_ix].lock().unwrap();
            shard.insert(key, task);
            if shard.len() > SPILL_THRESHOLD {
                shard.pop_first()
            } else {
                None
            }
        };
        if let Some((k, t)) = spilled {
            self.pool.lock().unwrap().insert(k, t);
        }
    }

    fn book_select(&self) {
        self.selects.fetch_add(1, Ordering::Relaxed);
        let remaining = self.count.fetch_sub(1, Ordering::SeqCst) - 1;
        self.select_len_sum
            .fetch_add(remaining as u64, Ordering::Relaxed);
    }

    /// Worker-side `select` for worker `worker`: own shard first
    /// (priority-then-FIFO), then the steal pool, then one task
    /// rebalanced from the first non-empty neighbor shard.
    pub fn select(&self, worker: usize) -> Option<TaskDesc> {
        let n = self.shards.len();
        let own = worker % n;
        if let Some((_, t)) = self.shards[own].lock().unwrap().pop_last() {
            self.book_select();
            return Some(t);
        }
        if let Some((_, t)) = self.pool.lock().unwrap().pop_last() {
            self.book_select();
            return Some(t);
        }
        for offset in 1..n {
            let ix = (own + offset) % n;
            if let Some((_, t)) = self.shards[ix].lock().unwrap().pop_last() {
                self.book_select();
                return Some(t);
            }
        }
        None
    }

    pub fn count_matching(&self, filter: impl Fn(&TaskDesc) -> bool) -> usize {
        let mut n = self.pool.lock().unwrap().values().filter(|t| filter(t)).count();
        for shard in &self.shards {
            n += shard.lock().unwrap().values().filter(|t| filter(t)).count();
        }
        n
    }

    /// Remove up to `max` matching tasks from one locked map, lowest
    /// priority first, appending to `out`.
    fn extract_from(
        map: &mut Shard,
        max: usize,
        filter: &dyn Fn(&TaskDesc) -> bool,
        out: &mut Vec<TaskDesc>,
    ) {
        if out.len() >= max {
            return;
        }
        let keys: Vec<QKey> = map
            .iter()
            .filter(|(_, t)| filter(t))
            .take(max - out.len())
            .map(|(k, _)| *k)
            .collect();
        for k in keys {
            out.push(map.remove(&k).expect("key vanished"));
        }
    }

    /// Victim-side extraction: drain the steal pool (lowest priority
    /// first); only when the pool cannot satisfy the allowance does the
    /// scan fall back to the shards — the contended path is the
    /// exception, not the rule.
    pub fn extract_for_steal(
        &self,
        max: usize,
        filter: impl Fn(&TaskDesc) -> bool,
    ) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        Self::extract_from(&mut self.pool.lock().unwrap(), max, &filter, &mut out);
        if out.len() < max {
            // Fallback must honor the same contract as the central
            // backend: globally lowest priority first, not shard order.
            // Snapshot matching keys one lock at a time, sort, then
            // remove smallest-first (best-effort: a worker may race a
            // key away between snapshot and removal — skip it).
            let mut candidates: Vec<(QKey, usize)> = Vec::new();
            for (ix, shard) in self.shards.iter().enumerate() {
                let guard = shard.lock().unwrap();
                candidates.extend(guard.iter().filter(|(_, t)| filter(t)).map(|(k, _)| (*k, ix)));
            }
            candidates.sort_unstable();
            for (key, ix) in candidates {
                if out.len() >= max {
                    break;
                }
                if let Some(task) = self.shards[ix].lock().unwrap().remove(&key) {
                    out.push(task);
                }
            }
        }
        self.steal_extracted
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        self.count.fetch_sub(out.len(), Ordering::SeqCst);
        out
    }

    pub fn max_priority(&self) -> Option<i64> {
        let mut best: Option<i64> = self
            .pool
            .lock()
            .unwrap()
            .last_key_value()
            .map(|(k, _)| k.prio);
        for shard in &self.shards {
            if let Some((k, _)) = shard.lock().unwrap().last_key_value() {
                best = Some(best.map_or(k.prio, |b| b.max(k.prio)));
            }
        }
        best
    }

    pub fn stats(&self) -> SchedStats {
        SchedStats {
            inserts: self.inserts.load(Ordering::Relaxed),
            selects: self.selects.load(Ordering::Relaxed),
            steal_extracted: self.steal_extracted.load(Ordering::Relaxed),
            select_len_sum: self.select_len_sum.load(Ordering::Relaxed),
        }
    }

    /// Drain everything (shutdown paths in tests). Not atomic against
    /// concurrent inserts: a task mid-spill can be missed, so only call
    /// once the node is quiescent.
    pub fn drain(&self) -> Vec<TaskDesc> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            out.extend(s.values().copied());
            s.clear();
        }
        let mut p = self.pool.lock().unwrap();
        out.extend(p.values().copied());
        p.clear();
        self.count.fetch_sub(out.len(), Ordering::SeqCst);
        out
    }
}

impl Scheduler for ShardedQueue {
    fn insert(&self, task: TaskDesc, priority: i64) {
        ShardedQueue::insert(self, task, priority)
    }

    fn select(&self, worker: usize) -> Option<TaskDesc> {
        ShardedQueue::select(self, worker)
    }

    fn len(&self) -> usize {
        ShardedQueue::len(self)
    }

    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize {
        ShardedQueue::count_matching(self, filter)
    }

    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc> {
        ShardedQueue::extract_for_steal(self, max, filter)
    }

    fn max_priority(&self) -> Option<i64> {
        ShardedQueue::max_priority(self)
    }

    fn stats(&self) -> SchedStats {
        ShardedQueue::stats(self)
    }

    fn drain(&self) -> Vec<TaskDesc> {
        ShardedQueue::drain(self)
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn single_shard_is_priority_then_fifo() {
        let q = ShardedQueue::new(1);
        q.insert(t(1), 5);
        q.insert(t(2), 9);
        q.insert(t(3), 5);
        assert_eq!(q.select(0), Some(t(2)));
        assert_eq!(q.select(0), Some(t(1)), "FIFO among equal priorities");
        assert_eq!(q.select(0), Some(t(3)));
        assert_eq!(q.select(0), None);
    }

    #[test]
    fn round_robin_spreads_and_rebalances() {
        let q = ShardedQueue::new(4);
        for i in 0..8 {
            q.insert(t(i), 0);
        }
        // worker 0's shard got tasks 0 and 4 (round-robin), FIFO order.
        assert_eq!(q.select(0), Some(t(0)));
        assert_eq!(q.select(0), Some(t(4)));
        // own shard empty, pool empty -> rebalance from neighbors.
        assert!(q.select(0).is_some());
        let mut drained = 3;
        while q.select(0).is_some() {
            drained += 1;
        }
        assert_eq!(drained, 8, "every task reachable from one worker");
        assert!(q.is_empty());
    }

    #[test]
    fn overfull_shard_spills_lowest_priority_to_pool() {
        let q = ShardedQueue::new(1);
        for i in 0..(SPILL_THRESHOLD as u32 + 5) {
            q.insert(t(i), i as i64);
        }
        assert_eq!(q.pool_len(), 5, "5 inserts beyond the watermark");
        assert_eq!(q.len(), SPILL_THRESHOLD + 5);
        // Spilled tasks are the lowest priorities at spill time.
        let stolen = q.extract_for_steal(5, |_| true);
        assert_eq!(stolen.len(), 5);
        assert!(stolen.iter().all(|s| (s.i as i64) < 5), "lowest prios pooled: {stolen:?}");
        assert_eq!(q.pool_len(), 0);
        assert_eq!(q.len(), SPILL_THRESHOLD);
    }

    #[test]
    fn steal_falls_back_to_shards_when_pool_dry() {
        let q = ShardedQueue::new(2);
        for (i, p) in [(1, 10), (2, 1), (3, 5), (4, 2)] {
            q.insert(t(i), p);
        }
        assert_eq!(q.pool_len(), 0, "under the watermark, nothing pooled");
        let stolen = q.extract_for_steal(2, |_| true);
        assert_eq!(
            stolen,
            vec![t(2), t(4)],
            "globally lowest priorities, regardless of shard"
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn pool_tasks_are_selectable_when_shards_empty() {
        let q = ShardedQueue::new(1);
        for i in 0..(SPILL_THRESHOLD as u32 + 3) {
            q.insert(t(i), i as i64);
        }
        let mut seen = 0;
        while q.select(0).is_some() {
            seen += 1;
        }
        assert_eq!(seen, SPILL_THRESHOLD + 3, "pooled tasks not lost");
        assert!(q.is_empty());
    }

    #[test]
    fn stats_and_conservation() {
        let q = ShardedQueue::new(3);
        for i in 0..30 {
            q.insert(t(i), (i % 7) as i64);
        }
        let stolen = q.extract_for_steal(4, |task| task.i % 2 == 0);
        let mut selected = 0;
        for w in 0..3 {
            while q.select(w).is_some() {
                selected += 1;
            }
        }
        let s = q.stats();
        assert_eq!(s.inserts, 30);
        assert_eq!(s.steal_extracted, stolen.len() as u64);
        assert_eq!(s.selects, selected);
        assert_eq!(stolen.len() as u64 + selected, 30, "conservation");
        assert!(q.is_empty());
    }

    #[test]
    fn concurrent_workers_and_stealer_conserve_tasks() {
        use std::sync::Arc;
        let q = Arc::new(ShardedQueue::new(4));
        let total = 4_000u32;
        let taken = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for w in 0..4u32 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.insert(t(w * 10_000 + i), (i % 13) as i64);
                }
            }));
        }
        for h in handles.drain(..) {
            h.join().unwrap();
        }
        for w in 0..4 {
            let q = q.clone();
            let taken = taken.clone();
            handles.push(std::thread::spawn(move || {
                while q.select(w).is_some() {
                    taken.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        {
            let q = q.clone();
            let taken = taken.clone();
            handles.push(std::thread::spawn(move || loop {
                let got = q.extract_for_steal(8, &|_| true);
                if got.is_empty() {
                    break;
                }
                taken.fetch_add(got.len(), Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(taken.load(Ordering::SeqCst), total as usize);
        assert!(q.is_empty());
    }
}
