//! The sharded backend: per-worker priority shards + a low-priority
//! steal pool, with an adaptive spill watermark.
//!
//! Khatiri et al. ("Work Stealing with latency") show steal-path latency
//! dominates when victim-side extraction serializes with execution;
//! Fernandes et al. ("Adaptive Asynchronous Work-Stealing") make the
//! same point for distributed runtimes. This backend decouples the two
//! paths:
//!
//! * **Inserts** spread round-robin across per-worker shards, each its
//!   own `BTreeMap` behind its own mutex.
//! * **Workers** `select` from their own shard (priority-then-FIFO),
//!   fall back to the steal pool, and finally rebalance a *batch* — half
//!   of the richest neighbor shard — into their own shard, so one empty
//!   worker amortizes the neighbor-lock traffic over many tasks instead
//!   of paying it once per task.
//! * **Shards over the spill watermark** shed their lowest-priority task
//!   into the steal pool on insert: the pool accumulates exactly the
//!   tasks that would wait longest locally — §3's cheapest to give away.
//! * **Victims** (`extract_stealable`) drain the pool, only falling back
//!   to the shards' stealable indices when the pool cannot satisfy the
//!   allowance, so a steal request normally never blocks a worker
//!   `select`.
//!
//! The spill watermark **adapts to the gate's observed verdicts** (AIMD,
//! clamped to `[WATERMARK_MIN, WATERMARK_MAX]`): the victim-side
//! decision reports back through [`ShardedQueue::feedback`]
//! ([`StealOutcome`]), closing the §3 waiting-time loop. A granted steal
//! means thieves are being fed, so the watermark drops multiplicatively
//! (shards spill earlier, filling the pool for the next request); a
//! waiting-time denial means queued tasks will run locally sooner than
//! they could migrate, so the watermark rises additively (keep tasks in
//! the shards). A worker that has to take work *back* from the pool
//! also raises it — spilling was too eager. [`SPILL_THRESHOLD`] is the
//! initial value. (Before the feedback hook, only pool pressure fed the
//! watermark and the gate's denial signal was thrown away.)
//!
//! Steal accounting (`stealable_count`/`stealable_payload_bytes` and
//! the per-class queued counts) lives in atomics maintained on
//! insert/select/extract — an O(1) read for the victim policy — and
//! each shard keeps a `BTreeSet` index of its stealable keys so
//! `extract_stealable` never filters a map. The minimum stealable
//! payload is *exact*: a shared payload multiset behind a short mutex,
//! with the current minimum cached in an atomic so the payload-certain
//! denial fast path reads it in O(1).
//!
//! Two mechanisms keep sustained denial off the all-shards fallback
//! walk. First, a *pool floor* ([`POOL_FLOOR`], `--pool-floor`): when a
//! pool-miss does force the walk, it extracts up to `floor` extra
//! lowest-priority stealable tasks and banks them in the pool, so the
//! next request is served from the pool again — one walk restocks,
//! instead of one walk per request. Second, gate-denial reinserts
//! ([`super::BatchSite::GateDenial`]) return their batch to the *pool*
//! rather than a shard: the batch was extracted from the pool (or paid
//! the walk already), and sending it back to a shard would drain the
//! pool one task per denied poll at a maxed watermark. The walks that
//! do happen are counted in [`SchedStats::extract_fallback_walks`].
//!
//! At most one lock is ever held at a time (a spilled task is popped,
//! the shard unlocked, then the pool locked), so the backend is
//! deadlock-free by construction. The global task count lives in an
//! atomic that is incremented *before* a task becomes visible and
//! decremented only when one is handed out, so `is_empty()` never
//! under-reports — the property Safra-style passivity checks rely on.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::dataflow::task::{TaskClass, TaskDesc};

use super::{
    BatchCounter, BatchSite, PayloadMultiset, QKey, SchedStats, Scheduler, StealOutcome, TaskMeta,
};

/// Initial spill watermark (20 ≈ half the paper's 40 workers, the same
/// constant PaRSEC uses for chunked victim policies). The live value
/// adapts per queue — see [`ShardedQueue::watermark`].
pub const SPILL_THRESHOLD: usize = 20;

/// Default steal-pool floor (`--pool-floor`): how many extra tasks a
/// pool-miss fallback walk banks in the pool so the next extraction is
/// served without another walk. 0 disables restocking.
pub const POOL_FLOOR: usize = 2;

/// Adaptive watermark floor: below this, shards spill almost everything
/// and local FIFO order degrades to pool order.
const WATERMARK_MIN: usize = 4;

/// Adaptive watermark ceiling (8× the initial value): above this a
/// shard can starve the pool for the entire run.
const WATERMARK_MAX: usize = 8 * SPILL_THRESHOLD;

/// One priority map plus the index of its stealable keys.
#[derive(Debug, Default)]
struct Shard {
    map: BTreeMap<QKey, (TaskDesc, TaskMeta)>,
    steal_idx: BTreeSet<QKey>,
}

impl Shard {
    fn insert(&mut self, key: QKey, task: TaskDesc, meta: TaskMeta) {
        if meta.stealable {
            self.steal_idx.insert(key);
        }
        self.map.insert(key, (task, meta));
    }

    fn pop_last(&mut self) -> Option<(QKey, (TaskDesc, TaskMeta))> {
        let entry = self.map.pop_last();
        if let Some((k, _)) = &entry {
            self.steal_idx.remove(k);
        }
        entry
    }

    fn pop_first(&mut self) -> Option<(QKey, (TaskDesc, TaskMeta))> {
        let entry = self.map.pop_first();
        if let Some((k, _)) = &entry {
            self.steal_idx.remove(k);
        }
        entry
    }

    fn remove(&mut self, key: QKey) -> Option<(TaskDesc, TaskMeta)> {
        let entry = self.map.remove(&key);
        if entry.is_some() {
            self.steal_idx.remove(&key);
        }
        entry
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Per-worker sharded ready queue with a low-priority steal pool.
#[derive(Debug)]
pub struct ShardedQueue {
    shards: Vec<Mutex<Shard>>,
    pool: Mutex<Shard>,
    /// Global insertion sequence: FIFO tie-breaking is consistent across
    /// shards and with the central backend.
    seq: AtomicU64,
    /// Round-robin insert cursor.
    rr: AtomicU64,
    /// Tasks currently queued (shards + pool). See module doc for the
    /// visibility contract.
    count: AtomicUsize,
    /// Queued stealable tasks (same visibility contract as `count`).
    stealable_cnt: AtomicUsize,
    /// Payload bytes of the queued stealable tasks.
    stealable_bytes: AtomicU64,
    /// Exact multiset of the queued stealable payloads (shared
    /// [`PayloadMultiset`]), one for shards and pool together. Mutated
    /// under its own short mutex on every stealable
    /// insert/select/extract; the critical section is one `BTreeMap`
    /// update plus refreshing the cached minimum below. This replaced
    /// the PR 4 monotone-min bound, whose empty-set reset could race an
    /// insert and leave the fast path gating on a stale value — the
    /// minimum is now exact, at the cost of one short shared lock per
    /// stealable-task mutation.
    steal_payloads: Mutex<PayloadMultiset>,
    /// Cached copy of the multiset minimum (`u64::MAX` = none),
    /// refreshed under the multiset mutex so reads stay O(1) atomic
    /// loads off the steal-decision hot path.
    min_steal_bytes: AtomicU64,
    /// Queued tasks per class (keyed on `task.class`).
    class_counts: [AtomicUsize; TaskClass::COUNT],
    /// Pool floor: extra tasks a fallback walk banks into the pool.
    pool_floor: usize,
    /// Adaptive spill watermark (see module docs).
    watermark: AtomicUsize,
    inserts: AtomicU64,
    selects: AtomicU64,
    steal_extracted: AtomicU64,
    select_len_sum: AtomicU64,
    scans: AtomicU64,
    /// Per-[`BatchSite`] batched-insert calls / tasks.
    batch_batches: [AtomicU64; BatchSite::COUNT],
    batch_tasks: [AtomicU64; BatchSite::COUNT],
    feedback_grants: AtomicU64,
    feedback_wt_denials: AtomicU64,
    feedback_timeouts: AtomicU64,
    /// `extract_stealable` pool-misses that walked the shard indices.
    fallback_walks: AtomicU64,
    /// Shard-empty batch rebalances performed (diagnostics).
    rebalances: AtomicU64,
    /// Every mutex acquisition (shards, pool, payload multiset),
    /// feeding [`SchedStats::lock_acquisitions`] — the §4.4 contention
    /// metric the lock-free backend's zero is compared against.
    lock_acquisitions: AtomicU64,
}

impl ShardedQueue {
    /// One shard per worker thread of the owning node.
    pub fn new(workers: usize) -> Self {
        let n = workers.max(1);
        ShardedQueue {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            pool: Mutex::new(Shard::default()),
            seq: AtomicU64::new(0),
            rr: AtomicU64::new(0),
            count: AtomicUsize::new(0),
            stealable_cnt: AtomicUsize::new(0),
            stealable_bytes: AtomicU64::new(0),
            steal_payloads: Mutex::new(PayloadMultiset::default()),
            min_steal_bytes: AtomicU64::new(u64::MAX),
            class_counts: std::array::from_fn(|_| AtomicUsize::new(0)),
            pool_floor: POOL_FLOOR,
            watermark: AtomicUsize::new(SPILL_THRESHOLD),
            inserts: AtomicU64::new(0),
            selects: AtomicU64::new(0),
            steal_extracted: AtomicU64::new(0),
            select_len_sum: AtomicU64::new(0),
            scans: AtomicU64::new(0),
            batch_batches: std::array::from_fn(|_| AtomicU64::new(0)),
            batch_tasks: std::array::from_fn(|_| AtomicU64::new(0)),
            feedback_grants: AtomicU64::new(0),
            feedback_wt_denials: AtomicU64::new(0),
            feedback_timeouts: AtomicU64::new(0),
            fallback_walks: AtomicU64::new(0),
            rebalances: AtomicU64::new(0),
            lock_acquisitions: AtomicU64::new(0),
        }
    }

    /// Acquire `m`, counting the acquisition toward
    /// [`SchedStats::lock_acquisitions`]. Every mutex in this backend
    /// (shards, pool, payload multiset) is taken through here, so the
    /// contention metric can never undercount.
    fn locked<'a, T>(&self, m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        self.lock_acquisitions.fetch_add(1, Ordering::Relaxed);
        m.lock().unwrap()
    }

    /// Set the steal-pool floor (`--pool-floor`; see [`POOL_FLOOR`]).
    pub fn with_pool_floor(mut self, floor: usize) -> Self {
        self.pool_floor = floor;
        self
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Tasks currently waiting in the steal pool (diagnostics).
    pub fn pool_len(&self) -> usize {
        self.locked(&self.pool).len()
    }

    /// `extract_stealable` calls that missed the pool and walked the
    /// shard indices (diagnostics; also in [`SchedStats`]).
    pub fn fallback_walks(&self) -> u64 {
        self.fallback_walks.load(Ordering::Relaxed)
    }

    /// Current adaptive spill watermark.
    pub fn watermark(&self) -> usize {
        self.watermark.load(Ordering::Relaxed)
    }

    /// Batch rebalances performed by empty workers (diagnostics).
    pub fn rebalances(&self) -> u64 {
        self.rebalances.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.count.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stealable_count(&self) -> usize {
        self.stealable_cnt.load(Ordering::SeqCst)
    }

    pub fn stealable_payload_bytes(&self) -> u64 {
        self.stealable_bytes.load(Ordering::Relaxed)
    }

    /// The *exact* minimum queued stealable payload — an O(1) atomic
    /// read of the multiset's cached minimum (`u64::MAX` when nothing
    /// stealable is queued).
    pub fn min_stealable_payload_bytes(&self) -> u64 {
        self.min_steal_bytes.load(Ordering::Relaxed)
    }

    /// Add stealable payloads to the exact multiset and refresh the
    /// cached minimum — one lock acquisition per call (batch callers
    /// pass the whole batch).
    fn payload_counts_insert(&self, payloads: &[u64]) {
        if payloads.is_empty() {
            return;
        }
        let mut counts = self.locked(&self.steal_payloads);
        for &p in payloads {
            counts.add(p);
        }
        self.min_steal_bytes.store(counts.min(), Ordering::Relaxed);
    }

    /// Remove stealable payloads from the exact multiset and refresh
    /// the cached minimum.
    fn payload_counts_remove(&self, payloads: &[u64]) {
        if payloads.is_empty() {
            return;
        }
        let mut counts = self.locked(&self.steal_payloads);
        for &p in payloads {
            counts.remove(p);
        }
        self.min_steal_bytes.store(counts.min(), Ordering::Relaxed);
    }

    /// Queued tasks per class — O(1) copies of the incremental counters.
    pub fn class_counts(&self) -> [usize; TaskClass::COUNT] {
        std::array::from_fn(|i| self.class_counts[i].load(Ordering::Relaxed))
    }

    /// Additive raise, fired by both "keep tasks local" signals: a
    /// waiting-time denial fed back through [`ShardedQueue::feedback`],
    /// or a worker having to take work back from the pool (spilling was
    /// too eager).
    fn raise_watermark(&self) {
        let w = self.watermark.load(Ordering::Relaxed);
        if w < WATERMARK_MAX {
            self.watermark.store(w + 1, Ordering::Relaxed);
        }
    }

    /// Multiplicative lower: a granted steal says thieves are being
    /// fed, so shards should spill earlier (AIMD keeps the two
    /// pressures from oscillating).
    fn lower_watermark(&self) {
        let w = self.watermark.load(Ordering::Relaxed);
        let next = w.saturating_sub(1 + w / 8).max(WATERMARK_MIN);
        self.watermark.store(next, Ordering::Relaxed);
    }

    /// Gate-outcome feedback from the victim-side steal decision (the
    /// closed loop of the module docs): waiting-time denials raise the
    /// spill watermark — the gate just measured that queued tasks reach
    /// a local worker faster than they migrate — and grants lower it so
    /// the pool stays stocked for the next thief.
    pub fn feedback(&self, outcome: StealOutcome) {
        match outcome {
            StealOutcome::Granted => {
                self.feedback_grants.fetch_add(1, Ordering::Relaxed);
                self.lower_watermark();
            }
            StealOutcome::DeniedWaitingTime => {
                self.feedback_wt_denials.fetch_add(1, Ordering::Relaxed);
                self.raise_watermark();
            }
            StealOutcome::DeniedEmpty => {}
            // A thief-side timeout (`--faults`) is a denial-flavored
            // signal: migration over this fabric just cost a whole
            // timeout and delivered nothing, so keep tasks local.
            StealOutcome::TimedOut => {
                self.feedback_timeouts.fetch_add(1, Ordering::Relaxed);
                self.raise_watermark();
            }
        }
    }

    pub fn insert(&self, task: TaskDesc, priority: i64) {
        self.insert_meta(task, priority, TaskMeta::for_task(task));
    }

    /// Next queue key. `seq` only needs uniqueness, not ordering (a
    /// thread's own RMWs on one atomic stay in program order), so
    /// Relaxed keeps it off the coherence hot path; the global sequence
    /// makes FIFO tie-breaking consistent across shards and with the
    /// central backend.
    fn key_for(&self, priority: i64) -> QKey {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        QKey {
            prio: priority,
            age: u64::MAX - seq,
        }
    }

    /// Shed everything over the watermark from a locked shard, lowest
    /// priority first. The caller moves the result into the pool
    /// *after* unlocking the shard — at most one lock is ever held.
    fn drain_spill(shard: &mut Shard, watermark: usize) -> Vec<(QKey, (TaskDesc, TaskMeta))> {
        let mut spilled = Vec::new();
        while shard.len() > watermark {
            match shard.pop_first() {
                Some(entry) => spilled.push(entry),
                None => break,
            }
        }
        spilled
    }

    fn pool_insert(&self, spilled: Vec<(QKey, (TaskDesc, TaskMeta))>) {
        if spilled.is_empty() {
            return;
        }
        let mut pool = self.locked(&self.pool);
        for (k, (t, m)) in spilled {
            pool.insert(k, t, m);
        }
    }

    /// Book the arrival of `n` tasks carrying `stealable_payloads` (one
    /// entry per stealable task in the batch) — shared by the single
    /// and batched insert paths. `count`/`stealable_cnt` and the exact
    /// payload multiset go up BEFORE the tasks become selectable — the
    /// visibility contract of the module docs.
    fn book_insert(&self, n: usize, stealable_payloads: &[u64]) {
        self.count.fetch_add(n, Ordering::SeqCst);
        if !stealable_payloads.is_empty() {
            self.stealable_cnt
                .fetch_add(stealable_payloads.len(), Ordering::SeqCst);
            self.stealable_bytes
                .fetch_add(stealable_payloads.iter().sum::<u64>(), Ordering::Relaxed);
            self.payload_counts_insert(stealable_payloads);
        }
        self.inserts.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// O(1) per-class queued-count maintenance (keyed on the task's own
    /// class, so a mismatched meta can never make the counts drift).
    fn class_inc(&self, class: TaskClass) {
        self.class_counts[class.idx()].fetch_add(1, Ordering::Relaxed);
    }

    fn class_dec(&self, class: TaskClass) {
        self.class_counts[class.idx()].fetch_sub(1, Ordering::Relaxed);
    }

    pub fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta) {
        // `rr`/stat counters only need uniqueness, so Relaxed; `count`/
        // `stealable_cnt` are the exception: they SeqCst-pair with the
        // threaded runtime's parked-worker protocol and Safra passivity
        // checks, and count up BEFORE the task becomes selectable — a
        // concurrent passivity check must never see empty while a task
        // exists.
        if meta.stealable {
            self.book_insert(1, &[meta.payload_bytes]);
        } else {
            self.book_insert(1, &[]);
        }
        self.class_inc(task.class);
        let shard_ix =
            (self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len() as u64) as usize;
        let watermark = self.watermark.load(Ordering::Relaxed);
        let spilled = {
            let mut shard = self.locked(&self.shards[shard_ix]);
            shard.insert(self.key_for(priority), task, meta);
            Self::drain_spill(&mut shard, watermark)
        };
        self.pool_insert(spilled);
    }

    /// Batched insert: the whole batch lands in one shard under one
    /// shard-lock acquisition (plus at most one pool lock for spill),
    /// instead of `len` round-robin single-lock inserts, booked against
    /// `site`. Used by the bulk-arrival paths — steal-reply re-enqueue,
    /// gate-denial reinsert and the activation ready set — where the
    /// tasks arrive together anyway; a thief was starving when it
    /// asked, so concentrating the batch in one shard costs nothing
    /// (neighbor rebalancing redistributes on demand). Gate-denial
    /// batches return to the *pool* instead: they were extracted from
    /// it, and a sustained denial stream must not drain the pool into
    /// the all-shards fallback walk.
    pub fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]) {
        if batch.is_empty() {
            return;
        }
        // Same visibility contract as insert_meta (counts up BEFORE the
        // tasks become selectable), aggregated into one RMW per counter
        // and one payload-multiset lock for the whole batch.
        let stealable_payloads: Vec<u64> = batch
            .iter()
            .filter(|(_, _, m)| m.stealable)
            .map(|(_, _, m)| m.payload_bytes)
            .collect();
        self.book_insert(batch.len(), &stealable_payloads);
        for (task, _, _) in batch {
            self.class_inc(task.class);
        }
        self.batch_batches[site.idx()].fetch_add(1, Ordering::Relaxed);
        self.batch_tasks[site.idx()]
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        if site == BatchSite::GateDenial {
            let mut pool = self.locked(&self.pool);
            for &(task, priority, meta) in batch {
                pool.insert(self.key_for(priority), task, meta);
            }
            return;
        }
        let shard_ix =
            (self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len() as u64) as usize;
        let watermark = self.watermark.load(Ordering::Relaxed);
        let spilled = {
            let mut shard = self.locked(&self.shards[shard_ix]);
            for &(task, priority, meta) in batch {
                shard.insert(self.key_for(priority), task, meta);
            }
            Self::drain_spill(&mut shard, watermark)
        };
        self.pool_insert(spilled);
    }

    /// [`ShardedQueue::insert_batch_at`] without a protocol role.
    pub fn insert_batch_meta(&self, batch: &[(TaskDesc, i64, TaskMeta)]) {
        self.insert_batch_at(BatchSite::Other, batch);
    }

    /// Book the removal of stealable tasks carrying `payloads` (one
    /// entry per removed stealable task): the shared stealable-count
    /// decrement plus the exact payload-multiset removal — the multiset
    /// *is* the bound, so there is no empty-set reset (and no reset
    /// race) any more.
    fn book_stealable_removed(&self, payloads: &[u64]) {
        if payloads.is_empty() {
            return;
        }
        self.stealable_cnt
            .fetch_sub(payloads.len(), Ordering::SeqCst);
        self.stealable_bytes
            .fetch_sub(payloads.iter().sum::<u64>(), Ordering::Relaxed);
        self.payload_counts_remove(payloads);
    }

    /// Book the removal of one selected task (and its steal accounting).
    fn book_select(&self, task: &TaskDesc, meta: TaskMeta) {
        self.selects.fetch_add(1, Ordering::Relaxed);
        let remaining = self.count.fetch_sub(1, Ordering::SeqCst) - 1;
        self.select_len_sum
            .fetch_add(remaining as u64, Ordering::Relaxed);
        self.class_dec(task.class);
        if meta.stealable {
            self.book_stealable_removed(&[meta.payload_bytes]);
        }
    }

    /// Worker-side `select` for worker `worker`: own shard first
    /// (priority-then-FIFO), then the steal pool, then a half-shard
    /// batch rebalanced from the richest neighbor.
    pub fn select(&self, worker: usize) -> Option<TaskDesc> {
        let n = self.shards.len();
        let own = worker % n;
        if let Some((_, (t, m))) = self.locked(&self.shards[own]).pop_last() {
            self.book_select(&t, m);
            return Some(t);
        }
        if let Some((_, (t, m))) = self.locked(&self.pool).pop_last() {
            // A local worker reclaiming pooled work: spill was too
            // eager — nudge the watermark up.
            self.raise_watermark();
            self.book_select(&t, m);
            return Some(t);
        }
        // Own shard and pool empty: batch-rebalance half of the richest
        // neighbor shard instead of one task per visit, so the next
        // selects stay on the own-shard fast path.
        let mut richest: Option<(usize, usize)> = None; // (len, ix)
        for offset in 1..n {
            let ix = (own + offset) % n;
            let len = self.locked(&self.shards[ix]).len();
            if len > richest.map_or(0, |(l, _)| l) {
                richest = Some((len, ix));
            }
        }
        if let Some((_, ix)) = richest {
            let batch = {
                let mut donor = self.locked(&self.shards[ix]);
                let take = donor.len().div_ceil(2);
                let mut batch = Vec::with_capacity(take);
                for _ in 0..take {
                    match donor.pop_last() {
                        Some(entry) => batch.push(entry),
                        None => break,
                    }
                }
                batch
            };
            // First popped = highest priority: hand it to the caller,
            // keep the rest locally (keys preserved, so priority/FIFO
            // order is unchanged).
            let mut entries = batch.into_iter();
            if let Some((_, (t, m))) = entries.next() {
                {
                    let mut own_shard = self.locked(&self.shards[own]);
                    for (k, (task, meta)) in entries {
                        own_shard.insert(k, task, meta);
                    }
                }
                self.rebalances.fetch_add(1, Ordering::Relaxed);
                self.book_select(&t, m);
                return Some(t);
            }
        }
        // Races can empty the richest shard between the census and the
        // take; last resort is the old one-task neighbor walk.
        for offset in 1..n {
            let ix = (own + offset) % n;
            if let Some((_, (t, m))) = self.locked(&self.shards[ix]).pop_last() {
                self.book_select(&t, m);
                return Some(t);
            }
        }
        None
    }

    /// Book the removal of the extracted tasks in `out` (all stealable)
    /// carrying the per-task `payloads`.
    fn book_extract(&self, out: &[TaskDesc], payloads: &[u64]) {
        self.steal_extracted
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        self.count.fetch_sub(out.len(), Ordering::SeqCst);
        for task in out {
            self.class_dec(task.class);
        }
        self.book_stealable_removed(payloads);
    }

    /// Victim-side extraction via the stealable indices: drain the pool
    /// (lowest priority first); only when the pool cannot satisfy the
    /// allowance does the walk visit the shards' indices — and that
    /// walk extracts up to `pool_floor` *extra* lowest-priority
    /// stealable tasks and banks them in the pool, so one walk restocks
    /// instead of every subsequent request paying it again. Watermark
    /// adaptation happens in [`ShardedQueue::feedback`], driven by the
    /// gate's verdict on the extracted batch — a pool near-miss on a
    /// request the gate was going to deny anyway is *not* a reason to
    /// spill more.
    pub fn extract_stealable(&self, max: usize) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut payloads = Vec::new();
        {
            let mut pool = self.locked(&self.pool);
            let keys: Vec<QKey> = pool.steal_idx.iter().take(max).copied().collect();
            for k in keys {
                if let Some((t, m)) = pool.remove(k) {
                    payloads.push(m.payload_bytes);
                    out.push(t);
                }
            }
        }
        if out.len() < max {
            // Fallback honors the same contract as the central backend:
            // globally lowest priority first, not shard order. Snapshot
            // the stealable indices one lock at a time, sort, then
            // remove smallest-first (best-effort: a worker may race a
            // key away between snapshot and removal — skip it).
            self.fallback_walks.fetch_add(1, Ordering::Relaxed);
            let mut candidates: Vec<(QKey, usize)> = Vec::new();
            for (ix, shard) in self.shards.iter().enumerate() {
                let guard = self.locked(shard);
                candidates.extend(guard.steal_idx.iter().map(|k| (*k, ix)));
            }
            candidates.sort_unstable();
            // The walk also banks `pool_floor` extra tasks in the pool
            // (keys preserved — they stay queued, just pool-resident).
            let mut restock: Vec<(QKey, (TaskDesc, TaskMeta))> = Vec::new();
            for (key, ix) in candidates {
                if out.len() >= max && restock.len() >= self.pool_floor {
                    break;
                }
                if let Some((t, m)) = self.locked(&self.shards[ix]).remove(key) {
                    if out.len() < max {
                        payloads.push(m.payload_bytes);
                        out.push(t);
                    } else {
                        restock.push((key, (t, m)));
                    }
                }
            }
            self.pool_insert(restock);
        }
        self.book_extract(&out, &payloads);
        out
    }

    pub fn count_matching(&self, filter: impl Fn(&TaskDesc) -> bool) -> usize {
        self.scans.fetch_add(1, Ordering::Relaxed);
        let mut n = {
            let pool = self.locked(&self.pool);
            pool.map.values().filter(|(t, _)| filter(t)).count()
        };
        for shard in &self.shards {
            let guard = self.locked(shard);
            n += guard.map.values().filter(|(t, _)| filter(t)).count();
        }
        n
    }

    /// Remove up to `max` matching tasks from one locked shard, lowest
    /// priority first, appending to `out` (and each removed stealable
    /// payload to `stealable_payloads`).
    fn extract_from(
        shard: &mut Shard,
        max: usize,
        filter: &dyn Fn(&TaskDesc) -> bool,
        out: &mut Vec<TaskDesc>,
        stealable_payloads: &mut Vec<u64>,
    ) {
        if out.len() >= max {
            return;
        }
        let keys: Vec<QKey> = shard
            .map
            .iter()
            .filter(|(_, (t, _))| filter(t))
            .take(max - out.len())
            .map(|(k, _)| *k)
            .collect();
        for k in keys {
            let (t, m) = shard.remove(k).expect("key vanished");
            if m.stealable {
                stealable_payloads.push(m.payload_bytes);
            }
            out.push(t);
        }
    }

    /// Scan-based extraction (the O(n) oracle): up to `max` tasks
    /// satisfying `filter`, pool first, then globally lowest priority
    /// across the shards.
    pub fn extract_for_steal(
        &self,
        max: usize,
        filter: impl Fn(&TaskDesc) -> bool,
    ) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        self.scans.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        let mut stealable_payloads = Vec::new();
        {
            let mut pool = self.locked(&self.pool);
            Self::extract_from(&mut pool, max, &filter, &mut out, &mut stealable_payloads);
        }
        if out.len() < max {
            let mut candidates: Vec<(QKey, usize)> = Vec::new();
            for (ix, shard) in self.shards.iter().enumerate() {
                let guard = self.locked(shard);
                candidates.extend(
                    guard
                        .map
                        .iter()
                        .filter(|(_, (t, _))| filter(t))
                        .map(|(k, _)| (*k, ix)),
                );
            }
            candidates.sort_unstable();
            for (key, ix) in candidates {
                if out.len() >= max {
                    break;
                }
                if let Some((t, m)) = self.locked(&self.shards[ix]).remove(key) {
                    if m.stealable {
                        stealable_payloads.push(m.payload_bytes);
                    }
                    out.push(t);
                }
            }
        }
        self.steal_extracted
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        self.count.fetch_sub(out.len(), Ordering::SeqCst);
        for task in &out {
            self.class_dec(task.class);
        }
        self.book_stealable_removed(&stealable_payloads);
        out
    }

    pub fn max_priority(&self) -> Option<i64> {
        let mut best: Option<i64> = {
            let pool = self.locked(&self.pool);
            pool.map.last_key_value().map(|(k, _)| k.prio)
        };
        for shard in &self.shards {
            if let Some((k, _)) = self.locked(shard).map.last_key_value() {
                best = Some(best.map_or(k.prio, |b| b.max(k.prio)));
            }
        }
        best
    }

    pub fn stats(&self) -> SchedStats {
        let mut batches = [BatchCounter::default(); BatchSite::COUNT];
        for (i, b) in batches.iter_mut().enumerate() {
            b.batches = self.batch_batches[i].load(Ordering::Relaxed);
            b.tasks = self.batch_tasks[i].load(Ordering::Relaxed);
        }
        SchedStats {
            inserts: self.inserts.load(Ordering::Relaxed),
            selects: self.selects.load(Ordering::Relaxed),
            steal_extracted: self.steal_extracted.load(Ordering::Relaxed),
            select_len_sum: self.select_len_sum.load(Ordering::Relaxed),
            scans: self.scans.load(Ordering::Relaxed),
            batches,
            feedback_grants: self.feedback_grants.load(Ordering::Relaxed),
            feedback_wt_denials: self.feedback_wt_denials.load(Ordering::Relaxed),
            feedback_timeouts: self.feedback_timeouts.load(Ordering::Relaxed),
            watermark: self.watermark.load(Ordering::Relaxed) as u64,
            extract_fallback_walks: self.fallback_walks.load(Ordering::Relaxed),
            min_payload_resets: self.locked(&self.steal_payloads).resets(),
            lock_acquisitions: self.lock_acquisitions.load(Ordering::Relaxed),
            cas_retries: 0,
        }
    }

    /// Drain everything (shutdown paths in tests). Not atomic against
    /// concurrent inserts: a task mid-spill can be missed, so only call
    /// once the node is quiescent.
    pub fn drain(&self) -> Vec<TaskDesc> {
        let mut out = Vec::new();
        let mut stealable_payloads = Vec::new();
        let mut clear = |shard: &mut Shard| {
            for (t, m) in shard.map.values() {
                if m.stealable {
                    stealable_payloads.push(m.payload_bytes);
                }
                out.push(*t);
            }
            shard.map.clear();
            shard.steal_idx.clear();
        };
        for shard in &self.shards {
            clear(&mut self.locked(shard));
        }
        clear(&mut self.locked(&self.pool));
        self.count.fetch_sub(out.len(), Ordering::SeqCst);
        for task in &out {
            self.class_dec(task.class);
        }
        self.book_stealable_removed(&stealable_payloads);
        out
    }
}

impl Scheduler for ShardedQueue {
    fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta) {
        ShardedQueue::insert_meta(self, task, priority, meta)
    }

    fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]) {
        ShardedQueue::insert_batch_at(self, site, batch)
    }

    fn feedback(&self, outcome: StealOutcome) {
        ShardedQueue::feedback(self, outcome)
    }

    fn select(&self, worker: usize) -> Option<TaskDesc> {
        ShardedQueue::select(self, worker)
    }

    fn len(&self) -> usize {
        ShardedQueue::len(self)
    }

    fn stealable_count(&self) -> usize {
        ShardedQueue::stealable_count(self)
    }

    fn stealable_payload_bytes(&self) -> u64 {
        ShardedQueue::stealable_payload_bytes(self)
    }

    fn min_stealable_payload_bytes(&self) -> u64 {
        ShardedQueue::min_stealable_payload_bytes(self)
    }

    fn class_counts(&self) -> [usize; TaskClass::COUNT] {
        ShardedQueue::class_counts(self)
    }

    fn extract_stealable(&self, max: usize) -> Vec<TaskDesc> {
        ShardedQueue::extract_stealable(self, max)
    }

    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize {
        ShardedQueue::count_matching(self, filter)
    }

    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc> {
        ShardedQueue::extract_for_steal(self, max, filter)
    }

    fn max_priority(&self) -> Option<i64> {
        ShardedQueue::max_priority(self)
    }

    fn stats(&self) -> SchedStats {
        ShardedQueue::stats(self)
    }

    fn drain(&self) -> Vec<TaskDesc> {
        ShardedQueue::drain(self)
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn single_shard_is_priority_then_fifo() {
        let q = ShardedQueue::new(1);
        q.insert(t(1), 5);
        q.insert(t(2), 9);
        q.insert(t(3), 5);
        assert_eq!(q.select(0), Some(t(2)));
        assert_eq!(q.select(0), Some(t(1)), "FIFO among equal priorities");
        assert_eq!(q.select(0), Some(t(3)));
        assert_eq!(q.select(0), None);
    }

    #[test]
    fn round_robin_spreads_and_rebalances() {
        let q = ShardedQueue::new(4);
        for i in 0..8 {
            q.insert(t(i), 0);
        }
        // worker 0's shard got tasks 0 and 4 (round-robin), FIFO order.
        assert_eq!(q.select(0), Some(t(0)));
        assert_eq!(q.select(0), Some(t(4)));
        // own shard empty, pool empty -> batch rebalance from neighbors.
        assert!(q.select(0).is_some());
        assert!(q.rebalances() >= 1, "empty worker took a batch");
        let mut drained = 3;
        while q.select(0).is_some() {
            drained += 1;
        }
        assert_eq!(drained, 8, "every task reachable from one worker");
        assert!(q.is_empty());
    }

    #[test]
    fn rebalance_takes_half_the_richest_neighbor() {
        let q = ShardedQueue::new(2);
        // Round-robin: evens land in shard 0, odds in shard 1.
        for i in 0..12 {
            q.insert(t(i), i as i64);
        }
        // Drain worker 0's own shard (6 tasks).
        for _ in 0..6 {
            assert!(q.select(0).is_some());
        }
        // Next select: shard 1 has 6 tasks; worker 0 takes a batch of 3
        // (half), returns the best, keeps 2 in its own shard.
        assert_eq!(q.select(0), Some(t(11)), "highest-priority of the batch");
        assert_eq!(q.rebalances(), 1);
        // The two kept tasks now serve worker 0 without touching shard 1.
        assert_eq!(q.select(0), Some(t(9)));
        assert_eq!(q.select(0), Some(t(7)));
        // Shard 1 still holds its un-rebalanced half for worker 1.
        assert_eq!(q.select(1), Some(t(5)));
        assert_eq!(q.rebalances(), 1, "no extra rebalance needed");
    }

    #[test]
    fn overfull_shard_spills_lowest_priority_to_pool() {
        let q = ShardedQueue::new(1);
        for i in 0..(SPILL_THRESHOLD as u32 + 5) {
            q.insert(t(i), i as i64);
        }
        assert_eq!(q.pool_len(), 5, "5 inserts beyond the watermark");
        assert_eq!(q.len(), SPILL_THRESHOLD + 5);
        // Spilled tasks are the lowest priorities at spill time.
        let stolen = q.extract_for_steal(5, |_| true);
        assert_eq!(stolen.len(), 5);
        assert!(stolen.iter().all(|s| (s.i as i64) < 5), "lowest prios pooled: {stolen:?}");
        assert_eq!(q.pool_len(), 0);
        assert_eq!(q.len(), SPILL_THRESHOLD);
    }

    #[test]
    fn steal_falls_back_to_shards_when_pool_dry() {
        let q = ShardedQueue::new(2);
        for (i, p) in [(1, 10), (2, 1), (3, 5), (4, 2)] {
            q.insert(t(i), p);
        }
        assert_eq!(q.pool_len(), 0, "under the watermark, nothing pooled");
        let stolen = q.extract_for_steal(2, |_| true);
        assert_eq!(
            stolen,
            vec![t(2), t(4)],
            "globally lowest priorities, regardless of shard"
        );
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn extract_stealable_matches_filter_path() {
        let q = ShardedQueue::new(2);
        for i in 0..10u32 {
            q.insert_meta(
                t(i),
                i as i64,
                TaskMeta {
                    stealable: i % 2 == 0,
                    payload_bytes: 8,
                    class: TaskClass::Synthetic,
                },
            );
        }
        assert_eq!(q.stealable_count(), 5);
        assert_eq!(q.stealable_payload_bytes(), 40);
        let stolen = q.extract_stealable(3);
        assert_eq!(stolen, vec![t(0), t(2), t(4)], "lowest-priority stealable");
        assert_eq!(q.stealable_count(), 2);
        assert_eq!(q.stealable_payload_bytes(), 16);
        assert_eq!(q.stats().scans, 0, "index path never scans");
        assert_eq!(q.len(), 7);
        // The pool was dry, so this extraction paid the fallback walk —
        // and banked the floor's worth of tasks in the pool for the
        // next request.
        assert_eq!(q.fallback_walks(), 1);
        assert_eq!(q.pool_len(), POOL_FLOOR, "walk restocked the pool");
        let again = q.extract_stealable(2);
        assert_eq!(again, vec![t(6), t(8)], "served from the restocked pool");
        assert_eq!(q.fallback_walks(), 1, "no second walk");
    }

    /// Gate-denial batches return to the pool (not a shard), so a
    /// sustained extract→deny→reinsert cycle never drains the pool
    /// into repeated fallback walks.
    #[test]
    fn gate_denial_reinsert_returns_to_the_pool() {
        let q = ShardedQueue::new(2);
        for i in 0..6u32 {
            q.insert(t(i), i as i64);
        }
        // First extraction: pool dry -> one walk (+ floor restock).
        let stolen = q.extract_stealable(2);
        assert_eq!(stolen.len(), 2);
        assert_eq!(q.fallback_walks(), 1);
        let batch: Vec<(TaskDesc, i64, TaskMeta)> = stolen
            .iter()
            .map(|&task| (task, task.i as i64, TaskMeta::default()))
            .collect();
        q.insert_batch_at(BatchSite::GateDenial, &batch);
        assert_eq!(q.len(), 6, "denied tasks returned");
        assert_eq!(q.stats().site(BatchSite::GateDenial).batches, 1);
        // Denied batch + restock live in the pool: repeat the cycle and
        // the walk count must not move.
        for _ in 0..10 {
            let stolen = q.extract_stealable(2);
            assert_eq!(stolen.len(), 2);
            let batch: Vec<(TaskDesc, i64, TaskMeta)> = stolen
                .iter()
                .map(|&task| (task, task.i as i64, TaskMeta::default()))
                .collect();
            q.insert_batch_at(BatchSite::GateDenial, &batch);
        }
        assert_eq!(q.fallback_walks(), 1, "pool floor keeps extraction off the walk");
        assert_eq!(q.len(), 6);
        // Pooled tasks are still selectable work.
        let mut seen = 0;
        for w in 0..2 {
            while q.select(w).is_some() {
                seen += 1;
            }
        }
        assert_eq!(seen, 6);
    }

    #[test]
    fn watermark_adapts_both_ways() {
        let q = ShardedQueue::new(1);
        assert_eq!(q.watermark(), SPILL_THRESHOLD);
        // Granted steals (gate feedback) drive it down...
        for _ in 0..50 {
            q.insert(t(0), 0);
            let got = q.extract_stealable(1);
            assert_eq!(got.len(), 1);
            q.feedback(StealOutcome::Granted);
        }
        assert_eq!(q.watermark(), WATERMARK_MIN, "grants floor the watermark");
        // ...waiting-time denials push it back up additively...
        for _ in 0..10 {
            q.feedback(StealOutcome::DeniedWaitingTime);
        }
        assert_eq!(q.watermark(), WATERMARK_MIN + 10, "denials raise it");
        assert_eq!(q.stats().feedback_wt_denials, 10);
        assert_eq!(q.stats().feedback_grants, 50);
        // ...and saturate at the ceiling.
        for _ in 0..(2 * WATERMARK_MAX) {
            q.feedback(StealOutcome::DeniedWaitingTime);
        }
        assert_eq!(q.watermark(), WATERMARK_MAX);
        // Reset down for the reclaim half of the test.
        for _ in 0..100 {
            q.feedback(StealOutcome::Granted);
        }
        assert_eq!(q.watermark(), WATERMARK_MIN);
        // ...and workers reclaiming pooled tasks push it back up: with
        // the watermark at the floor, inserts beyond it spill, and a
        // draining worker must take them back from the pool.
        for i in 0..(WATERMARK_MIN as u32 + 40) {
            q.insert(t(i), i as i64);
        }
        let mut taken = 0;
        while q.select(0).is_some() {
            taken += 1;
        }
        assert!(taken > 40, "drained everything");
        assert!(
            q.watermark() > WATERMARK_MIN,
            "pool reclaims raised the watermark to {}",
            q.watermark()
        );
        assert!(q.watermark() <= WATERMARK_MAX);
    }

    #[test]
    fn empty_queue_steal_does_not_adapt() {
        let q = ShardedQueue::new(2);
        for _ in 0..20 {
            assert!(q.extract_stealable(4).is_empty());
            q.feedback(StealOutcome::DeniedEmpty);
        }
        assert_eq!(
            q.watermark(),
            SPILL_THRESHOLD,
            "nothing stealable -> no adaptation signal"
        );
    }

    #[test]
    fn batch_insert_spills_past_the_watermark() {
        let q = ShardedQueue::new(1);
        let batch: Vec<(TaskDesc, i64, TaskMeta)> = (0..(SPILL_THRESHOLD as u32 + 6))
            .map(|i| (t(i), i as i64, TaskMeta::default()))
            .collect();
        q.insert_batch_meta(&batch);
        assert_eq!(q.len(), SPILL_THRESHOLD + 6);
        assert_eq!(q.pool_len(), 6, "overflow spilled to the pool");
        assert_eq!(q.stats().batch_inserts(), 1);
        assert_eq!(q.stats().batch_saved_locks(), SPILL_THRESHOLD as u64 + 5);
        // Spilled tasks are the lowest priorities and stay stealable.
        let stolen = q.extract_stealable(6);
        assert_eq!(stolen.len(), 6);
        assert!(stolen.iter().all(|s| (s.i as i64) < 6), "{stolen:?}");
        // Everything still selectable; nothing lost.
        let mut seen = 0;
        while q.select(0).is_some() {
            seen += 1;
        }
        assert_eq!(seen, SPILL_THRESHOLD);
    }

    #[test]
    fn pool_tasks_are_selectable_when_shards_empty() {
        let q = ShardedQueue::new(1);
        for i in 0..(SPILL_THRESHOLD as u32 + 3) {
            q.insert(t(i), i as i64);
        }
        let mut seen = 0;
        while q.select(0).is_some() {
            seen += 1;
        }
        assert_eq!(seen, SPILL_THRESHOLD + 3, "pooled tasks not lost");
        assert!(q.is_empty());
    }

    #[test]
    fn stats_and_conservation() {
        let q = ShardedQueue::new(3);
        for i in 0..30 {
            q.insert(t(i), (i % 7) as i64);
        }
        let stolen = q.extract_for_steal(4, |task| task.i % 2 == 0);
        let mut selected = 0;
        for w in 0..3 {
            while q.select(w).is_some() {
                selected += 1;
            }
        }
        let s = q.stats();
        assert_eq!(s.inserts, 30);
        assert_eq!(s.steal_extracted, stolen.len() as u64);
        assert_eq!(s.selects, selected);
        assert_eq!(stolen.len() as u64 + selected, 30, "conservation");
        assert!(q.is_empty());
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real threads: minutes under the interpreter
    fn concurrent_workers_and_stealer_conserve_tasks() {
        use std::sync::Arc;
        let q = Arc::new(ShardedQueue::new(4));
        let total = 4_000u32;
        let taken = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for w in 0..4u32 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.insert(t(w * 10_000 + i), (i % 13) as i64);
                }
            }));
        }
        for h in handles.drain(..) {
            h.join().unwrap();
        }
        for w in 0..4 {
            let q = q.clone();
            let taken = taken.clone();
            handles.push(std::thread::spawn(move || {
                while q.select(w).is_some() {
                    taken.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        {
            let q = q.clone();
            let taken = taken.clone();
            handles.push(std::thread::spawn(move || loop {
                let got = q.extract_stealable(8);
                if got.is_empty() {
                    break;
                }
                taken.fetch_add(got.len(), Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(taken.load(Ordering::SeqCst), total as usize);
        assert!(q.is_empty());
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
    }
}
