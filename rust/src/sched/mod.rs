//! Node-level schedulers: one trait, two backends.
//!
//! PaRSEC's default distributed scheduler keeps *node-level* queues
//! ordered by priority; worker threads `select` from the front, and the
//! migrate thread competes with them extracting steal candidates from the
//! *back* (lowest priority first — those tasks would wait longest
//! locally, so they are the cheapest to give away). §4.4 of the paper
//! attributes the run-to-run variance of No-Steal exactly to contention
//! on these queues.
//!
//! Everything that needs a ready queue — the threaded runtime
//! ([`crate::node`]), the discrete-event simulator ([`crate::sim`]) and
//! the victim-side steal protocol ([`crate::migrate::protocol`]) — goes
//! through the [`Scheduler`] trait, so backends are swappable per run
//! (`--sched central|sharded`):
//!
//! * [`CentralQueue`] — the reference backend: one `BTreeMap` keyed by
//!   `(priority, insertion-seq)` behind one lock. Both ends are O(log n)
//!   (`select` = pop-max, steal extraction = pop-min), iteration order is
//!   deterministic, and every worker plus the migrate thread serialize on
//!   the same lock — exactly the §4.4 contention structure.
//! * [`ShardedQueue`] — per-worker priority shards plus a low-priority
//!   *steal pool*. Workers pull from their own shard (falling back to the
//!   pool, then to neighbor shards when empty), inserts are spread
//!   round-robin, and overfull shards shed their lowest-priority tasks
//!   into the pool. Victim-side `extract_for_steal` drains the pool, so
//!   the steal path no longer competes with worker `select` on a single
//!   lock.
//!
//! Both backends preserve the semantics the policies rely on: per shard,
//! `select` is priority-then-FIFO; steal extraction takes lowest
//! priority first; tasks are conserved under any interleaving of
//! inserts, selects and extractions (property-tested in
//! `tests/sched_backends.rs`).

use std::str::FromStr;

use crate::dataflow::task::TaskDesc;

mod central;
mod sharded;

pub use central::CentralQueue;
pub use sharded::{SPILL_THRESHOLD, ShardedQueue};

/// The historical name of the node queue; kept as an alias for the
/// reference backend so existing call sites and tests read unchanged.
pub type SchedQueue = CentralQueue;

/// Key ordering: higher priority first; among equal priorities FIFO
/// (earlier seq first). Stored as (priority, Reverse-ish seq) — we use
/// `u64::MAX - seq` so `pop_last` yields highest-priority, oldest task.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub(crate) struct QKey {
    pub(crate) prio: i64,
    pub(crate) age: u64, // u64::MAX - seq: larger = older
}

/// Snapshot counters for the scheduler (feeds the E^b potential metric
/// and the §4.4 contention analysis).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    pub inserts: u64,
    pub selects: u64,
    pub steal_extracted: u64,
    /// Sum of queue length observed at each successful select
    /// (mean = sum / selects).
    pub select_len_sum: u64,
}

/// A node's ready-task scheduler.
///
/// Implementations do their own internal locking (`&self` methods), so
/// worker threads, the comm thread and the migrate thread can share one
/// instance without an external mutex — the whole point of the sharded
/// backend. Filters borrow the task (`&TaskDesc`), so the O(n) stealable
/// census never copies task descriptors.
pub trait Scheduler: Send + Sync + std::fmt::Debug {
    /// Enqueue a ready task at `priority`.
    fn insert(&self, task: TaskDesc, priority: i64);

    /// Worker-side `select`: the best ready task visible to `worker`
    /// (a shard hint; the central backend ignores it).
    fn select(&self, worker: usize) -> Option<TaskDesc>;

    /// Tasks currently queued (including any steal pool).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count tasks satisfying `filter` (victim-side stealable census).
    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize;

    /// Migrate-thread extraction: up to `max` tasks satisfying `filter`,
    /// lowest priority first. The allowance is an upper bound, not a
    /// guarantee — §3's best-effort extraction.
    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc>;

    /// Peek the highest priority value (scheduling diagnostics).
    fn max_priority(&self) -> Option<i64>;

    fn stats(&self) -> SchedStats;

    /// Drain everything (shutdown paths in tests). Not guaranteed atomic
    /// against concurrent inserts.
    fn drain(&self) -> Vec<TaskDesc>;

    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

/// Which [`Scheduler`] backend a run uses (`--sched central|sharded`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedBackend {
    /// One priority map behind one lock (reference / deterministic).
    #[default]
    Central,
    /// Per-worker shards + low-priority steal pool.
    Sharded,
}

impl SchedBackend {
    /// Instantiate the backend for a node with `workers` worker threads.
    pub fn build(self, workers: usize) -> Box<dyn Scheduler> {
        match self {
            SchedBackend::Central => Box::new(CentralQueue::new()),
            SchedBackend::Sharded => Box::new(ShardedQueue::new(workers)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SchedBackend::Central => "central",
            SchedBackend::Sharded => "sharded",
        }
    }

    pub const ALL: [SchedBackend; 2] = [SchedBackend::Central, SchedBackend::Sharded];
}

impl FromStr for SchedBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "central" | "btree" | "locked" => Ok(SchedBackend::Central),
            "sharded" | "shards" | "per-worker" => Ok(SchedBackend::Sharded),
            _ => Err(format!(
                "unknown scheduler backend '{s}' (central | sharded)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::TaskClass;

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn backend_parses() {
        assert_eq!("central".parse::<SchedBackend>().unwrap(), SchedBackend::Central);
        assert_eq!("Sharded".parse::<SchedBackend>().unwrap(), SchedBackend::Sharded);
        assert!("fancy".parse::<SchedBackend>().is_err());
        assert_eq!(SchedBackend::default(), SchedBackend::Central);
    }

    #[test]
    fn build_produces_working_backends() {
        for backend in SchedBackend::ALL {
            // one worker: both backends promise global priority order
            let q = backend.build(1);
            assert_eq!(q.name(), backend.label());
            assert!(q.is_empty());
            q.insert(t(1), 5);
            q.insert(t(2), 9);
            assert_eq!(q.len(), 2);
            assert_eq!(q.max_priority(), Some(9), "{backend:?}");
            let got = q.select(0).expect("a task");
            assert_eq!(got, t(2), "{backend:?}: highest priority first");
            assert_eq!(q.drain(), vec![t(1)]);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn trait_object_steal_path_respects_filter() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            for i in 0..10 {
                q.insert(t(i), i as i64);
            }
            assert_eq!(q.count_matching(&|task| task.i % 2 == 0), 5);
            let stolen = q.extract_for_steal(3, &|task| task.i % 2 == 0);
            assert_eq!(stolen.len(), 3, "{backend:?}");
            assert!(stolen.iter().all(|s| s.i % 2 == 0));
            assert_eq!(q.len(), 7);
        }
    }
}
