//! Node-level schedulers: one trait, three backends, O(1) steal accounting.
//!
//! PaRSEC's default distributed scheduler keeps *node-level* queues
//! ordered by priority; worker threads `select` from the front, and the
//! migrate thread competes with them extracting steal candidates from the
//! *back* (lowest priority first — those tasks would wait longest
//! locally, so they are the cheapest to give away). §4.4 of the paper
//! attributes the run-to-run variance of No-Steal exactly to contention
//! on these queues.
//!
//! Everything that needs a ready queue — the threaded runtime
//! ([`crate::node`]), the discrete-event simulator ([`crate::sim`]) and
//! the victim-side steal protocol ([`crate::migrate::protocol`]) — goes
//! through the [`Scheduler`] trait, so backends are swappable per run
//! (`--sched central|sharded|workassist`):
//!
//! * [`CentralQueue`] — the reference backend: one `BTreeMap` keyed by
//!   `(priority, insertion-seq)` behind one lock. Both ends are O(log n)
//!   (`select` = pop-max, steal extraction = pop-min), iteration order is
//!   deterministic, and every worker plus the migrate thread serialize on
//!   the same lock — exactly the §4.4 contention structure.
//! * [`ShardedQueue`] — per-worker priority shards plus a low-priority
//!   *steal pool*. Workers pull from their own shard (falling back to the
//!   pool, then to a half-shard batch rebalanced from the richest
//!   neighbor), inserts are spread round-robin, and shards over the spill
//!   watermark shed their lowest-priority tasks into the pool. Victim-side
//!   extraction drains the pool, so a steal request normally never blocks
//!   a worker `select`. The watermark is *adaptive*: steal requests the
//!   pool cannot cover push it down (spill more toward thieves), workers
//!   that have to fall back to the pool push it back up.
//! * [`WorkAssistQueue`] — the lock-free backend: published task blocks
//!   plus CAS-claimed entries in the work-assisting style, no mutex on
//!   any path ([`SchedStats::lock_acquisitions`] is hard-wired zero and
//!   [`SchedStats::cas_retries`] counts contention instead). Verified by
//!   a `loom` model-checking suite (`tests/loom_workassist.rs`) on top
//!   of the shared property suite.
//!
//! # The accounting contract
//!
//! The paper's victim policy needs "future tasks and the expected waiting
//! time" at every steal poll. Recomputing that view with an O(n) queue
//! scan per request is exactly the contention §4.4 warns about, so both
//! backends maintain it *incrementally*: every task enters the queue via
//! [`Scheduler::insert_meta`] carrying a [`TaskMeta`] (stealable? payload
//! bytes?), and the backend keeps
//!
//! * [`Scheduler::stealable_count`] — how many queued tasks are
//!   stealable,
//! * [`Scheduler::stealable_payload_bytes`] — the input bytes that would
//!   travel if all of them migrated,
//! * [`Scheduler::min_stealable_payload_bytes`] — the *exact* minimum
//!   payload over the queued stealable tasks (an exact payload
//!   multiset with a cached minimum), so a payload-certain waiting-time
//!   denial needs no extraction at all, and
//! * [`Scheduler::class_counts`] — queued tasks per [`TaskClass`], so
//!   the per-class waiting-time estimator (`--exec-per-class`) can
//!   weigh the actual queue composition,
//!
//! exact under any interleaving of insert / select / extract, each an
//! O(1) read. [`Scheduler::extract_stealable`] serves the migrate thread
//! from a per-queue index of stealable entries (lowest priority first)
//! without filtering the whole map. Callers must keep the inserted meta
//! consistent with the graph's `is_stealable`/`payload_bytes` (use
//! [`TaskMeta::of`]); the plain [`Scheduler::insert`] marks the task
//! stealable with zero payload, matching the pre-accounting behavior.
//!
//! The scan-based [`Scheduler::count_matching`] and
//! [`Scheduler::extract_for_steal`] survive as the *oracle* the property
//! tests compare the incremental accounting against; each bumps
//! [`SchedStats::scans`], so a test (and the §Perf acceptance gate) can
//! assert the steal hot path performs zero scans.
//!
//! # The feedback loop
//!
//! The victim-side gate ([`crate::migrate::protocol::decide_steal`])
//! does not just consume the accounting — it reports its verdict back
//! through [`Scheduler::feedback`] as a [`StealOutcome`]. A waiting-time
//! denial means queued tasks will reach a local worker sooner than they
//! could migrate (§3), so the sharded backend raises its spill watermark
//! (keep tasks in the shards); a granted steal means thieves are being
//! fed, so it lowers the watermark (spill earlier toward the pool). The
//! central backend records the outcomes in [`SchedStats`] so both
//! backends are observable under the same protocol. See
//! `docs/ARCHITECTURE.md` for the full loop diagram.
//!
//! Bulk arrivals — a steal reply re-creating stolen tasks at the thief,
//! a gate denial returning an extracted batch, or an activation ready
//! set (the hottest insert path) — go through
//! [`Scheduler::insert_batch_at`]: one lock acquisition per batch
//! instead of one per task (the queue-side mirror of PR 2's
//! `ActivateBatch`), attributed per call site ([`BatchSite`]) so each
//! path's one-batch-per-event contract stays individually assertable,
//! with the saving counted in [`BatchCounter::saved_locks`].
//!
//! Both backends preserve the semantics the policies rely on: per shard,
//! `select` is priority-then-FIFO; steal extraction takes lowest
//! priority first; tasks are conserved under any interleaving of
//! inserts, selects and extractions (property-tested in
//! `tests/sched_backends.rs`).

use std::collections::BTreeMap;
use std::str::FromStr;

use crate::dataflow::task::{TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;

mod central;
mod sharded;
mod workassist;

pub use central::CentralQueue;
pub use sharded::{POOL_FLOOR, SPILL_THRESHOLD, ShardedQueue};
pub use workassist::WorkAssistQueue;

/// The historical name of the node queue; kept as an alias for the
/// reference backend so existing call sites and tests read unchanged.
pub type SchedQueue = CentralQueue;

/// Key ordering: higher priority first; among equal priorities FIFO
/// (earlier seq first). Stored as (priority, Reverse-ish seq) — we use
/// `u64::MAX - seq` so `pop_last` yields highest-priority, oldest task.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub(crate) struct QKey {
    pub(crate) prio: i64,
    pub(crate) age: u64, // u64::MAX - seq: larger = older
}

/// Exact multiset of the queued stealable payloads (payload ->
/// occurrence count) with a cached minimum, shared by both backends —
/// the central queue keeps one inside its map mutex, the sharded queue
/// behind its own short mutex (mirroring the cached min into an atomic
/// for O(1) lock-free reads). This replaced PR 4's monotone-per-epoch
/// lower bound, whose empty-set reset could race an insert and leave
/// the payload-certain fast path gating on a stale value: the minimum
/// is now exact under any removal order.
#[derive(Debug)]
pub(crate) struct PayloadMultiset {
    counts: BTreeMap<u64, usize>,
    /// Cached `counts` minimum (`u64::MAX` = empty); recomputed only
    /// when the last copy of the minimum leaves, so reads are O(1).
    min: u64,
    /// Desync tripwire: a removal that misses the multiset (see
    /// [`SchedStats::min_payload_resets`]).
    resets: u64,
}

impl Default for PayloadMultiset {
    fn default() -> Self {
        PayloadMultiset {
            counts: BTreeMap::new(),
            min: u64::MAX,
            resets: 0,
        }
    }
}

impl PayloadMultiset {
    /// Add one stealable payload (and refresh the cached minimum).
    pub(crate) fn add(&mut self, payload: u64) {
        *self.counts.entry(payload).or_insert(0) += 1;
        if payload < self.min {
            self.min = payload;
        }
    }

    /// Remove one stealable payload. A removal that misses the multiset
    /// would mean the accounting desynced: the tripwire counter fires
    /// and the entry is skipped (the cached minimum stays valid).
    pub(crate) fn remove(&mut self, payload: u64) {
        match self.counts.get_mut(&payload) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.counts.remove(&payload);
                if payload == self.min {
                    self.min = self.counts.first_key_value().map_or(u64::MAX, |(p, _)| *p);
                }
            }
            None => {
                debug_assert!(false, "payload multiset out of sync at {payload}");
                self.resets += 1;
            }
        }
    }

    /// The exact minimum queued stealable payload (`u64::MAX` = none).
    pub(crate) fn min(&self) -> u64 {
        self.min
    }

    /// Conservative resets performed (0 unless the accounting desynced).
    pub(crate) fn resets(&self) -> u64 {
        self.resets
    }

    /// Drop everything (shutdown/drain paths).
    pub(crate) fn clear(&mut self) {
        self.counts.clear();
        self.min = u64::MAX;
    }
}

/// Steal-accounting metadata carried by every queued task.
///
/// Snapshotted at insert time from the graph ([`TaskMeta::of`]); the
/// graph's methods are pure functions of the descriptor, so the snapshot
/// never goes stale while the task waits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskMeta {
    /// May this task migrate to a thief? (The paper's TTG
    /// `is_stealable` hook, evaluated once at enqueue.)
    pub stealable: bool,
    /// Input bytes that travel with the task if it migrates.
    pub payload_bytes: u64,
    /// The task's class, snapshotted for the per-class waiting-time
    /// estimator (`--exec-per-class`). The backends key their per-class
    /// queued counts on `task.class` directly (so a mismatched meta can
    /// never make the counts drift), but the snapshot keeps the whole
    /// steal view of a queued task in one place.
    pub class: TaskClass,
}

impl Default for TaskMeta {
    /// Plain inserts count as stealable with no payload — the behavior
    /// filters gave before the accounting existed.
    fn default() -> Self {
        TaskMeta {
            stealable: true,
            payload_bytes: 0,
            class: TaskClass::Synthetic,
        }
    }
}

impl TaskMeta {
    /// Default metadata for a plain insert of `t`: stealable, zero
    /// payload, the task's own class — shared by the trait-level and
    /// both backends' `insert` so they cannot diverge.
    pub fn for_task(t: TaskDesc) -> TaskMeta {
        TaskMeta {
            class: t.class,
            ..TaskMeta::default()
        }
    }

    /// Snapshot the graph's steal view of `t`.
    pub fn of(graph: &dyn TaskGraph, t: TaskDesc) -> TaskMeta {
        TaskMeta {
            stealable: graph.is_stealable(t),
            payload_bytes: graph.payload_bytes(t),
            class: t.class,
        }
    }

    /// Build [`Scheduler::insert_batch_meta`] triples for `tasks`,
    /// keeping the stored-meta-agrees-with-graph contract in one place
    /// for every bulk-arrival call site (steal-reply re-enqueue in both
    /// runtimes, gate-denial reinsert).
    pub fn batch_of(graph: &dyn TaskGraph, tasks: &[TaskDesc]) -> Vec<(TaskDesc, i64, TaskMeta)> {
        tasks
            .iter()
            .map(|&t| (t, graph.priority(t), TaskMeta::of(graph, t)))
            .collect()
    }
}

/// Outcome of one victim-side steal decision, fed back into the
/// scheduler through [`Scheduler::feedback`].
///
/// This closes the loop the paper's §3 argues for: the waiting-time
/// gate's verdict is a direct measurement of whether queued tasks are
/// better off local or migrated, and the sharded backend turns it into
/// spill-watermark pressure (see [`ShardedQueue`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StealOutcome {
    /// The request was granted and tasks migrated. Thieves are being
    /// fed — spilling earlier helps the next request. (Task counts and
    /// payload sizes live in `migrate::StealStats`, not here.)
    Granted,
    /// The waiting-time gate denied the request: queued tasks will
    /// reach a local worker sooner than they could migrate, so they
    /// should stay local.
    DeniedWaitingTime,
    /// Nothing stealable was queued — no locality signal either way.
    DeniedEmpty,
    /// Thief-side only (`--faults`): a steal request timed out without
    /// any reply. No gate verdict was measured, but the thief just
    /// proved that migration over this fabric is *at least* a timeout
    /// slower than planned — treated like a denial (keep tasks local)
    /// by the sharded backend's watermark.
    TimedOut,
}

/// Which bulk-arrival path a batched insert came from. The accounting
/// is split per call site so the e2e assertions stay exact when more
/// than one path batches: one batch per non-empty steal reply, one per
/// gate denial, one per non-empty activation ready set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum BatchSite {
    /// Thief-side steal-reply re-enqueue (stolen tasks recreated).
    StealReply = 0,
    /// Victim-side gate-denial reinsert (extracted batch returned).
    GateDenial = 1,
    /// Successor-activation ready set (local fan-out or a delivered
    /// `ActivateBatch`), routed through one batched insert.
    Activation = 2,
    /// Direct callers without a protocol role (tests, tools).
    Other = 3,
}

impl BatchSite {
    pub const COUNT: usize = 4;

    pub const ALL: [BatchSite; BatchSite::COUNT] = [
        BatchSite::StealReply,
        BatchSite::GateDenial,
        BatchSite::Activation,
        BatchSite::Other,
    ];

    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            BatchSite::StealReply => "steal-reply",
            BatchSite::GateDenial => "gate-denial",
            BatchSite::Activation => "activation",
            BatchSite::Other => "other",
        }
    }
}

/// Batched-insert accounting for one [`BatchSite`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchCounter {
    /// Non-empty `insert_batch_at` calls.
    pub batches: u64,
    /// Tasks inserted across those batches.
    pub tasks: u64,
}

impl BatchCounter {
    /// Lock acquisitions avoided by batching (Σ per batch of `len − 1`).
    pub fn saved_locks(&self) -> u64 {
        self.tasks - self.batches
    }
}

/// Snapshot counters for the scheduler (feeds the E^b potential metric
/// and the §4.4 contention analysis).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    pub inserts: u64,
    pub selects: u64,
    pub steal_extracted: u64,
    /// Sum of queue length observed at each successful select
    /// (mean = sum / selects).
    pub select_len_sum: u64,
    /// O(queue-length) scan operations performed (`count_matching` and
    /// filter-based extraction). The steal hot path must keep this at
    /// zero — asserted by `migrate::protocol` tests.
    pub scans: u64,
    /// Per-call-site batched-insert accounting, indexed by
    /// [`BatchSite`]: exactly one batch per non-empty steal reply
    /// (thief side), one per gate-denial reinsert (victim side) and one
    /// per non-empty activation ready set — each asserted e2e against
    /// its own counter.
    pub batches: [BatchCounter; BatchSite::COUNT],
    /// [`StealOutcome::Granted`] feedback events received.
    pub feedback_grants: u64,
    /// [`StealOutcome::DeniedWaitingTime`] feedback events received.
    pub feedback_wt_denials: u64,
    /// [`StealOutcome::TimedOut`] feedback events received (thief-side
    /// steal timeouts under `--faults`).
    pub feedback_timeouts: u64,
    /// Live adaptive spill watermark at snapshot time (sharded backend
    /// only; the central backend has no watermark and reports 0).
    pub watermark: u64,
    /// Sharded backend only: `extract_stealable` calls that missed the
    /// steal pool and had to walk the shards' stealable indices. The
    /// payload-certain denial fast path plus the pool floor exist to
    /// keep this near zero under sustained denial.
    pub extract_fallback_walks: u64,
    /// Conservative (stale) resets of the min-stealable-payload bound.
    /// The exact payload multiset never needs one — this fires only if
    /// a removal misses the multiset (accounting desync), and the
    /// property suite plus the payload-certain e2e runs assert it stays
    /// zero.
    pub min_payload_resets: u64,
    /// Mutex acquisitions performed by the backend across every op —
    /// the lock-freedom gate. The locked backends count each `lock()`;
    /// the workassist backend has no mutex anywhere and hard-wires this
    /// to zero, which the bench and e2e asserts pin down.
    pub lock_acquisitions: u64,
    /// Failed compare-exchange attempts (claim races, chain-head and
    /// delta-stack pushes, combiner-epoch handoffs). Zero
    /// single-threaded; under contention each retry certifies that
    /// *another* thread made progress — the lock-freedom argument. The
    /// locked backends report 0.
    pub cas_retries: u64,
}

impl SchedStats {
    /// Batched-insert accounting for one call site.
    pub fn site(&self, site: BatchSite) -> BatchCounter {
        self.batches[site.idx()]
    }

    /// Total batched inserts across every call site.
    pub fn batch_inserts(&self) -> u64 {
        self.batches.iter().map(|b| b.batches).sum()
    }

    /// Total lock acquisitions avoided by batching, across every site.
    pub fn batch_saved_locks(&self) -> u64 {
        self.batches.iter().map(|b| b.saved_locks()).sum()
    }
}

/// A node's ready-task scheduler.
///
/// Implementations do their own internal locking (`&self` methods), so
/// worker threads, the comm thread and the migrate thread can share one
/// instance without an external mutex — the whole point of the sharded
/// backend. Filters borrow the task (`&TaskDesc`), so the O(n) oracle
/// census never copies task descriptors.
pub trait Scheduler: Send + Sync + std::fmt::Debug {
    /// Enqueue a ready task at `priority` with its steal accounting
    /// metadata (see the module docs for the consistency contract).
    fn insert_meta(&self, task: TaskDesc, priority: i64, meta: TaskMeta);

    /// Enqueue without explicit metadata: stealable, zero payload, the
    /// task's own class ([`TaskMeta::for_task`]).
    fn insert(&self, task: TaskDesc, priority: i64) {
        self.insert_meta(task, priority, TaskMeta::for_task(task));
    }

    /// Enqueue a batch of ready tasks under a single queue-lock
    /// acquisition (`(task, priority, meta)` triples), attributed to
    /// `site` in the per-call-site accounting. The batched twin of
    /// [`Scheduler::insert_meta`] for the bulk-arrival paths — the
    /// thief-side steal-reply re-enqueue, the victim-side gate-denial
    /// reinsert, and the activation ready set. Empty batches are a
    /// no-op; non-empty batches bump the site's
    /// [`BatchCounter::batches`] once and its task count by `len`.
    fn insert_batch_at(&self, site: BatchSite, batch: &[(TaskDesc, i64, TaskMeta)]);

    /// [`Scheduler::insert_batch_at`] without a protocol role
    /// ([`BatchSite::Other`]) — direct callers and tests.
    fn insert_batch_meta(&self, batch: &[(TaskDesc, i64, TaskMeta)]) {
        self.insert_batch_at(BatchSite::Other, batch);
    }

    /// Report a steal-decision outcome back to the scheduler (the
    /// closed loop of the module docs). The sharded backend adapts its
    /// spill watermark — denials raise it (tasks should stay local),
    /// grants lower it (feed thieves); both backends count the
    /// outcomes in [`SchedStats`].
    fn feedback(&self, outcome: StealOutcome);

    /// Worker-side `select`: the best ready task visible to `worker`
    /// (a shard hint; the central backend ignores it).
    fn select(&self, worker: usize) -> Option<TaskDesc>;

    /// Tasks currently queued (including any steal pool).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queued tasks whose meta marks them stealable. O(1): maintained
    /// incrementally on insert/select/extract.
    fn stealable_count(&self) -> usize;

    /// Total payload bytes of the queued stealable tasks. O(1).
    fn stealable_payload_bytes(&self) -> u64;

    /// The *exact* minimum payload of any queued stealable task, or
    /// `u64::MAX` when nothing stealable is queued. O(1) read of a
    /// cached minimum backed by an exact payload multiset maintained on
    /// every insert/select/extract (property-tested against the scan
    /// oracle). `decide_steal` uses it for the payload-certain denial
    /// fast path: any extractable batch carries at least this much
    /// payload, so when even that floor loses the waiting-time
    /// comparison the verdict is known without extracting — and because
    /// the minimum is exact, the fast path denies precisely the
    /// requests the full extract-and-weigh would have denied whenever a
    /// single-task allowance is in play.
    fn min_stealable_payload_bytes(&self) -> u64;

    /// Queued tasks per [`TaskClass`], indexed by class discriminant.
    /// O(1) reads of incrementally-maintained counters (keyed on
    /// `task.class`): the per-class waiting-time estimator
    /// (`--exec-per-class`) weighs the *actual queue composition*
    /// instead of `queue_len × one node-wide mean`.
    fn class_counts(&self) -> [usize; TaskClass::COUNT];

    /// Migrate-thread extraction of up to `max` stealable tasks, lowest
    /// priority first, via the incremental index — no queue scan. The
    /// allowance is an upper bound, not a guarantee (§3's best-effort
    /// extraction).
    fn extract_stealable(&self, max: usize) -> Vec<TaskDesc>;

    /// Count tasks satisfying `filter` — the O(n) oracle the property
    /// tests check the incremental accounting against. Bumps
    /// [`SchedStats::scans`].
    fn count_matching(&self, filter: &dyn Fn(&TaskDesc) -> bool) -> usize;

    /// Scan-based extraction of up to `max` tasks satisfying `filter`,
    /// lowest priority first. The oracle twin of
    /// [`Scheduler::extract_stealable`]; bumps [`SchedStats::scans`].
    fn extract_for_steal(&self, max: usize, filter: &dyn Fn(&TaskDesc) -> bool) -> Vec<TaskDesc>;

    /// Peek the highest priority value (scheduling diagnostics).
    fn max_priority(&self) -> Option<i64>;

    fn stats(&self) -> SchedStats;

    /// Drain everything (shutdown paths in tests). Not guaranteed atomic
    /// against concurrent inserts.
    fn drain(&self) -> Vec<TaskDesc>;

    /// Backend name for reports.
    fn name(&self) -> &'static str;
}

/// Which [`Scheduler`] backend a run uses
/// (`--sched central|sharded|workassist`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedBackend {
    /// One priority map behind one lock (reference / deterministic).
    #[default]
    Central,
    /// Per-worker shards + low-priority steal pool.
    Sharded,
    /// Lock-free published blocks + CAS-claimed entries
    /// (work-assisting).
    Workassist,
}

impl SchedBackend {
    /// Instantiate the backend for a node with `workers` worker threads
    /// (sharded steal-pool floor at its [`POOL_FLOOR`] default).
    pub fn build(self, workers: usize) -> Box<dyn Scheduler> {
        self.build_with(workers, POOL_FLOOR)
    }

    /// [`SchedBackend::build`] with an explicit sharded steal-pool
    /// floor (`--pool-floor`; the central backend has no pool and
    /// ignores it).
    pub fn build_with(self, workers: usize, pool_floor: usize) -> Box<dyn Scheduler> {
        match self {
            SchedBackend::Central => Box::new(CentralQueue::new()),
            SchedBackend::Sharded => {
                Box::new(ShardedQueue::new(workers).with_pool_floor(pool_floor))
            }
            // No pool, so no pool floor: thieves claim from the same
            // published blocks workers do.
            SchedBackend::Workassist => Box::new(WorkAssistQueue::new(workers)),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SchedBackend::Central => "central",
            SchedBackend::Sharded => "sharded",
            SchedBackend::Workassist => "workassist",
        }
    }

    pub const ALL: [SchedBackend; 3] = [
        SchedBackend::Central,
        SchedBackend::Sharded,
        SchedBackend::Workassist,
    ];
}

impl FromStr for SchedBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "central" | "btree" | "locked" => Ok(SchedBackend::Central),
            "sharded" | "shards" | "per-worker" => Ok(SchedBackend::Sharded),
            "workassist" | "lockfree" | "assist" => Ok(SchedBackend::Workassist),
            _ => Err(format!(
                "unknown scheduler backend '{s}' (central | sharded | workassist)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::TaskClass;

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn backend_parses() {
        assert_eq!("central".parse::<SchedBackend>().unwrap(), SchedBackend::Central);
        assert_eq!("Sharded".parse::<SchedBackend>().unwrap(), SchedBackend::Sharded);
        let wa = "workassist".parse::<SchedBackend>().unwrap();
        assert_eq!(wa, SchedBackend::Workassist);
        let alias = "lockfree".parse::<SchedBackend>().unwrap();
        assert_eq!(alias, SchedBackend::Workassist);
        assert!("fancy".parse::<SchedBackend>().is_err());
        assert_eq!(SchedBackend::default(), SchedBackend::Central);
    }

    #[test]
    fn build_produces_working_backends() {
        for backend in SchedBackend::ALL {
            // one worker: both backends promise global priority order
            let q = backend.build(1);
            assert_eq!(q.name(), backend.label());
            assert!(q.is_empty());
            q.insert(t(1), 5);
            q.insert(t(2), 9);
            assert_eq!(q.len(), 2);
            assert_eq!(q.max_priority(), Some(9), "{backend:?}");
            let got = q.select(0).expect("a task");
            assert_eq!(got, t(2), "{backend:?}: highest priority first");
            assert_eq!(q.drain(), vec![t(1)]);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn trait_object_steal_path_respects_filter() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            for i in 0..10 {
                q.insert(t(i), i as i64);
            }
            assert_eq!(q.count_matching(&|task| task.i % 2 == 0), 5);
            let stolen = q.extract_for_steal(3, &|task| task.i % 2 == 0);
            assert_eq!(stolen.len(), 3, "{backend:?}");
            assert!(stolen.iter().all(|s| s.i % 2 == 0));
            assert_eq!(q.len(), 7);
        }
    }

    #[test]
    fn accounting_tracks_meta_through_the_trait() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            for i in 0..10u32 {
                q.insert_meta(
                    t(i),
                    i as i64,
                    TaskMeta {
                        stealable: i % 2 == 0,
                        payload_bytes: 100 + i as u64,
                        class: TaskClass::Synthetic,
                    },
                );
            }
            assert_eq!(q.stealable_count(), 5, "{backend:?}");
            // i = 0,2,4,6,8 -> payloads 100,102,104,106,108
            assert_eq!(q.stealable_payload_bytes(), 520, "{backend:?}");
            let stolen = q.extract_stealable(3);
            assert_eq!(stolen.len(), 3, "{backend:?}");
            assert!(stolen.iter().all(|s| s.i % 2 == 0), "{backend:?}: {stolen:?}");
            assert_eq!(q.stealable_count(), 2, "{backend:?}");
            assert_eq!(q.stats().scans, 0, "{backend:?}: accounting path scanned");
            // The oracle agrees — and is itself counted as a scan.
            assert_eq!(q.count_matching(&|task| task.i % 2 == 0), 2);
            assert_eq!(q.stats().scans, 1, "{backend:?}");
        }
    }

    #[test]
    fn default_meta_is_stealable_zero_payload() {
        let m = TaskMeta::default();
        assert!(m.stealable);
        assert_eq!(m.payload_bytes, 0);
        assert_eq!(m.class, TaskClass::Synthetic);
    }

    #[test]
    fn batch_insert_counts_one_lock_acquisition() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            let batch: Vec<(TaskDesc, i64, TaskMeta)> = (0..6u32)
                .map(|i| {
                    (
                        t(i),
                        i as i64,
                        TaskMeta {
                            stealable: true,
                            payload_bytes: 10,
                            class: TaskClass::Synthetic,
                        },
                    )
                })
                .collect();
            q.insert_batch_meta(&batch);
            let s = q.stats();
            assert_eq!(s.batch_inserts(), 1, "{backend:?}");
            assert_eq!(s.batch_saved_locks(), 5, "{backend:?}");
            assert_eq!(s.site(BatchSite::Other).batches, 1, "{backend:?}");
            assert_eq!(s.site(BatchSite::Other).tasks, 6, "{backend:?}");
            assert_eq!(s.inserts, 6, "{backend:?}: per-task insert count kept");
            assert_eq!(q.len(), 6, "{backend:?}");
            assert_eq!(q.stealable_count(), 6, "{backend:?}");
            assert_eq!(q.stealable_payload_bytes(), 60, "{backend:?}");
            // Empty batches are a no-op, not a zero-length batch insert.
            q.insert_batch_meta(&[]);
            assert_eq!(q.stats().batch_inserts(), 1, "{backend:?}");
            // Highest priority first, exactly as per-task inserts.
            assert_eq!(q.select(0), Some(t(5)), "{backend:?}");
        }
    }

    /// Each bulk-arrival path books its batches under its own counter,
    /// so one path batching cannot blur another's e2e assertion.
    #[test]
    fn batch_sites_are_accounted_separately() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            let batch: Vec<(TaskDesc, i64, TaskMeta)> = (0..4u32)
                .map(|i| (t(i), i as i64, TaskMeta::default()))
                .collect();
            q.insert_batch_at(BatchSite::StealReply, &batch);
            q.insert_batch_at(BatchSite::Activation, &batch[..2]);
            q.insert_batch_at(BatchSite::Activation, &batch[..3]);
            q.insert_batch_at(BatchSite::GateDenial, &batch[..1]);
            let s = q.stats();
            assert_eq!(s.site(BatchSite::StealReply).batches, 1, "{backend:?}");
            assert_eq!(s.site(BatchSite::StealReply).tasks, 4, "{backend:?}");
            assert_eq!(s.site(BatchSite::Activation).batches, 2, "{backend:?}");
            assert_eq!(s.site(BatchSite::Activation).tasks, 5, "{backend:?}");
            assert_eq!(s.site(BatchSite::GateDenial).batches, 1, "{backend:?}");
            assert_eq!(s.site(BatchSite::GateDenial).saved_locks(), 0, "{backend:?}");
            assert_eq!(s.batch_inserts(), 4, "{backend:?}: total is the site sum");
            assert_eq!(s.batch_saved_locks(), 3 + 1 + 2, "{backend:?}");
            assert_eq!(q.len(), 10, "{backend:?}");
        }
    }

    /// Per-class queued counts follow every insert/select/extract, and
    /// the min-stealable-payload accounting is the *exact* multiset
    /// minimum: it rises when the lightest task leaves and returns to
    /// the sentinel when the stealable set empties.
    #[test]
    fn class_counts_and_min_payload_track_through_the_trait() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            assert_eq!(q.min_stealable_payload_bytes(), u64::MAX, "{backend:?}");
            let classes = [TaskClass::Potrf, TaskClass::Gemm, TaskClass::Gemm];
            for (i, class) in classes.into_iter().enumerate() {
                let task = TaskDesc::indexed(class, i as u32, 0, 0);
                let meta = TaskMeta {
                    stealable: true,
                    payload_bytes: 100 * (i as u64 + 1),
                    class,
                };
                q.insert_meta(task, i as i64, meta);
            }
            let counts = q.class_counts();
            assert_eq!(counts[TaskClass::Potrf.idx()], 1, "{backend:?}");
            assert_eq!(counts[TaskClass::Gemm.idx()], 2, "{backend:?}");
            assert_eq!(counts.iter().sum::<usize>(), q.len(), "{backend:?}");
            assert_eq!(q.min_stealable_payload_bytes(), 100, "{backend:?}");
            // Removals keep the counts exact, and the payload minimum
            // rises to the true next-smallest when the lightest leaves.
            let stolen = q.extract_stealable(1); // lowest priority = the POTRF
            assert_eq!(stolen[0].class, TaskClass::Potrf, "{backend:?}");
            assert_eq!(q.class_counts()[TaskClass::Potrf.idx()], 0, "{backend:?}");
            assert_eq!(q.min_stealable_payload_bytes(), 200, "{backend:?}");
            while q.select(0).is_some() {}
            assert_eq!(q.class_counts(), [0; TaskClass::COUNT], "{backend:?}");
            assert_eq!(
                q.min_stealable_payload_bytes(),
                u64::MAX,
                "{backend:?}: empty stealable set reads as the sentinel"
            );
            assert_eq!(q.stats().min_payload_resets, 0, "{backend:?}");
        }
    }

    /// The shared multiset both backends build their min-payload
    /// accounting on: exact minimum under duplicates and any removal
    /// order, sentinel when empty, zero resets unless desynced.
    #[test]
    fn payload_multiset_is_exact() {
        let mut m = PayloadMultiset::default();
        assert_eq!(m.min(), u64::MAX);
        for p in [500, 200, 900, 200] {
            m.add(p);
        }
        assert_eq!(m.min(), 200);
        m.remove(200);
        assert_eq!(m.min(), 200, "duplicate keeps the minimum");
        m.remove(200);
        assert_eq!(m.min(), 500, "minimum rises to the true next-smallest");
        m.remove(900);
        assert_eq!(m.min(), 500);
        m.remove(500);
        assert_eq!(m.min(), u64::MAX, "empty reads as the sentinel");
        assert_eq!(m.resets(), 0);
        m.add(7);
        m.clear();
        assert_eq!(m.min(), u64::MAX);
    }

    #[test]
    fn feedback_outcomes_are_counted_on_both_backends() {
        for backend in SchedBackend::ALL {
            let q = backend.build(2);
            q.feedback(StealOutcome::Granted);
            q.feedback(StealOutcome::DeniedWaitingTime);
            q.feedback(StealOutcome::DeniedWaitingTime);
            q.feedback(StealOutcome::DeniedEmpty);
            q.feedback(StealOutcome::TimedOut);
            q.feedback(StealOutcome::TimedOut);
            q.feedback(StealOutcome::TimedOut);
            let s = q.stats();
            assert_eq!(s.feedback_grants, 1, "{backend:?}");
            assert_eq!(s.feedback_wt_denials, 2, "{backend:?}");
            assert_eq!(s.feedback_timeouts, 3, "{backend:?}");
        }
    }
}
