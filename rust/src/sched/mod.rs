//! Node-level priority scheduler.
//!
//! PaRSEC's default distributed scheduler keeps *node-level* queues
//! ordered by priority; worker threads `select` from the front, and the
//! migrate thread competes with them extracting steal candidates from the
//! *back* (lowest priority first — those tasks would wait longest
//! locally, so they are the cheapest to give away). §4.4 of the paper
//! attributes the run-to-run variance of No-Steal exactly to contention
//! on these queues.
//!
//! Implementation: a `BTreeMap` keyed by `(priority, insertion-seq)` so
//! both ends are O(log n) (`select` = pop-max, steal extraction =
//! pop-min) and iteration order is deterministic.

use std::collections::BTreeMap;

use crate::dataflow::task::TaskDesc;

/// Key ordering: higher priority first; among equal priorities FIFO
/// (earlier seq first). Stored as (priority, Reverse-ish seq) — we use
/// `u64::MAX - seq` so `pop_last` yields highest-priority, oldest task.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct QKey {
    prio: i64,
    age: u64, // u64::MAX - seq: larger = older
}

/// Snapshot counters for the scheduler (feeds the E^b potential metric
/// and the §4.4 contention analysis).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedStats {
    pub inserts: u64,
    pub selects: u64,
    pub steal_extracted: u64,
    /// Sum of queue length observed at each successful select
    /// (mean = sum / selects).
    pub select_len_sum: u64,
}

/// A node's ready-task queue.
#[derive(Debug, Default)]
pub struct SchedQueue {
    map: BTreeMap<QKey, TaskDesc>,
    seq: u64,
    stats: SchedStats,
}

impl SchedQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn insert(&mut self, task: TaskDesc, priority: i64) {
        self.seq += 1;
        self.stats.inserts += 1;
        self.map.insert(
            QKey {
                prio: priority,
                age: u64::MAX - self.seq,
            },
            task,
        );
    }

    /// Worker-side `select`: highest-priority ready task.
    pub fn select(&mut self) -> Option<TaskDesc> {
        let entry = self.map.pop_last();
        if entry.is_some() {
            self.stats.selects += 1;
            self.stats.select_len_sum += self.map.len() as u64;
        }
        entry.map(|(_, t)| t)
    }

    /// Count tasks satisfying `filter` (victim-side stealable census).
    pub fn count_matching(&self, filter: impl Fn(TaskDesc) -> bool) -> usize {
        self.map.values().filter(|t| filter(**t)).count()
    }

    /// Migrate-thread extraction: up to `max` tasks satisfying `filter`,
    /// lowest priority first. This *competes* with `select` — the caller
    /// holds the same lock workers use, exactly the contention the paper
    /// describes; the allowance is an upper bound, not a guarantee.
    pub fn extract_for_steal(
        &mut self,
        max: usize,
        filter: impl Fn(TaskDesc) -> bool,
    ) -> Vec<TaskDesc> {
        if max == 0 {
            return Vec::new();
        }
        let keys: Vec<QKey> = self
            .map
            .iter()
            .filter(|(_, t)| filter(**t))
            .take(max)
            .map(|(k, _)| *k)
            .collect();
        let out: Vec<TaskDesc> = keys
            .iter()
            .map(|k| self.map.remove(k).expect("key vanished"))
            .collect();
        self.stats.steal_extracted += out.len() as u64;
        out
    }

    /// Peek the highest priority value (scheduling diagnostics).
    pub fn max_priority(&self) -> Option<i64> {
        self.map.last_key_value().map(|(k, _)| k.prio)
    }

    pub fn stats(&self) -> SchedStats {
        self.stats
    }

    /// Drain everything (shutdown paths in tests).
    pub fn drain(&mut self) -> Vec<TaskDesc> {
        let out = self.map.values().copied().collect();
        self.map.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn t(i: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
    }

    #[test]
    fn select_is_priority_then_fifo() {
        let mut q = SchedQueue::new();
        q.insert(t(1), 5);
        q.insert(t(2), 9);
        q.insert(t(3), 5);
        assert_eq!(q.select(), Some(t(2)));
        assert_eq!(q.select(), Some(t(1)), "FIFO among equal priorities");
        assert_eq!(q.select(), Some(t(3)));
        assert_eq!(q.select(), None);
    }

    #[test]
    fn steal_takes_lowest_priority_first() {
        let mut q = SchedQueue::new();
        for (i, p) in [(1, 10), (2, 1), (3, 5), (4, 2)] {
            q.insert(t(i), p);
        }
        let stolen = q.extract_for_steal(2, |_| true);
        assert_eq!(stolen, vec![t(2), t(4)], "two lowest priorities");
        assert_eq!(q.len(), 2);
        assert_eq!(q.select(), Some(t(1)), "high-priority work untouched");
    }

    #[test]
    fn steal_respects_filter_and_max() {
        let mut q = SchedQueue::new();
        for i in 0..10 {
            q.insert(t(i), i as i64);
        }
        let stolen = q.extract_for_steal(3, |task| task.i % 2 == 0);
        assert_eq!(stolen.len(), 3);
        assert!(stolen.iter().all(|s| s.i % 2 == 0));
        assert_eq!(q.len(), 7);
        assert_eq!(q.count_matching(|task| task.i % 2 == 0), 2);
    }

    #[test]
    fn stats_accumulate() {
        let mut q = SchedQueue::new();
        q.insert(t(0), 0);
        q.insert(t(1), 1);
        let _ = q.select();
        let _ = q.extract_for_steal(1, |_| true);
        let s = q.stats();
        assert_eq!((s.inserts, s.selects, s.steal_extracted), (2, 1, 1));
        assert_eq!(s.select_len_sum, 1);
    }

    #[test]
    fn extract_zero_is_noop() {
        let mut q = SchedQueue::new();
        q.insert(t(0), 0);
        assert!(q.extract_for_steal(0, |_| true).is_empty());
        assert_eq!(q.len(), 1);
    }
}
