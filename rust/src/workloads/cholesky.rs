//! Tiled sparse Cholesky factorization (§4.1).
//!
//! The global matrix is split into `t × t` tiles of `n × n` 64-bit
//! elements; each tile is either **dense** or **sparse** (all zero), with
//! exactly half the tiles dense in the paper's runs. Tiles are
//! distributed cyclically over nodes. The DAG is the classic
//! right-looking blocked factorization:
//!
//! ```text
//! POTRF(k):    A[k][k]   = chol(A[k][k])
//! TRSM(i,k):   A[i][k]   = A[i][k] · inv(L[k][k])ᵀ          (i > k)
//! SYRK(i,k):   A[i][i]  -= A[i][k] · A[i][k]ᵀ               (i > k)
//! GEMM(i,j,k): A[i][j]  -= A[i][k] · A[j][k]ᵀ           (i > j > k)
//! ```
//!
//! Tasks on sparse tiles exist but do no useful computation (§4.4), and
//! the programmer marks them non-stealable through the TTG
//! `is_stealable` hook — migrating a no-op is pure overhead.

use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;
use crate::util::rng::{mix2, Rng};

/// Is a tile dense or sparse (zero-filled)?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileKind {
    Dense,
    Sparse,
}

/// Workload parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct CholeskyParams {
    /// Tiles per side (the paper's headline config: 200).
    pub tiles: u32,
    /// Elements per tile side (the paper's headline config: 50).
    pub tile_size: u32,
    /// Number of nodes for the cyclic distribution.
    pub nodes: u32,
    /// Fraction of tiles that are dense (paper: exactly 0.5).
    pub dense_fraction: f64,
    /// Sparsity-mask seed (tile placement of dense tiles is random but
    /// reproducible; the diagonal is always dense so the factorization
    /// is well-posed).
    pub seed: u64,
    /// All tiles dense (numeric end-to-end validation mode).
    pub all_dense: bool,
}

impl Default for CholeskyParams {
    fn default() -> Self {
        CholeskyParams {
            tiles: 200,
            tile_size: 50,
            nodes: 4,
            dense_fraction: 0.5,
            seed: 0xC404,
            all_dense: false,
        }
    }
}

/// The sparse tiled Cholesky task graph.
pub struct CholeskyGraph {
    p: CholeskyParams,
    /// Row-major `tiles × tiles` mask for the lower triangle.
    mask: Vec<TileKind>,
}

impl CholeskyGraph {
    pub fn new(p: CholeskyParams) -> Self {
        assert!(p.tiles >= 1 && p.nodes >= 1);
        let t = p.tiles as usize;
        let mut mask = vec![TileKind::Sparse; t * t];
        if p.all_dense {
            mask.fill(TileKind::Dense);
        } else {
            // Diagonal always dense; off-diagonal lower-triangle tiles
            // shuffled so that `dense_fraction` of ALL tiles are dense.
            for k in 0..t {
                mask[k * t + k] = TileKind::Dense;
            }
            let mut off: Vec<(usize, usize)> = (0..t)
                .flat_map(|i| (0..i).map(move |j| (i, j)))
                .collect();
            let mut rng = Rng::new(p.seed);
            rng.shuffle(&mut off);
            let want_dense = ((t * t) as f64 * p.dense_fraction) as usize;
            let extra = want_dense.saturating_sub(t).min(off.len());
            for &(i, j) in off.iter().take(extra) {
                mask[i * t + j] = TileKind::Dense;
            }
        }
        CholeskyGraph { p, mask }
    }

    pub fn params(&self) -> &CholeskyParams {
        &self.p
    }

    #[inline]
    pub fn tile_kind(&self, i: u32, j: u32) -> TileKind {
        let (i, j) = if i >= j { (i, j) } else { (j, i) };
        self.mask[(i * self.p.tiles + j) as usize]
    }

    /// Count of dense tiles in the lower triangle (diagnostics).
    pub fn dense_tiles(&self) -> usize {
        let t = self.p.tiles as usize;
        (0..t)
            .flat_map(|i| (0..=i).map(move |j| (i, j)))
            .filter(|&(i, j)| self.mask[i * t + j] == TileKind::Dense)
            .count()
    }

    /// Cyclic distribution of tile (i, j) — the paper's static mapping.
    #[inline]
    pub fn tile_owner(&self, i: u32, j: u32) -> NodeId {
        // 2D block-cyclic with a 1×P process grid over the tile linear
        // index, matching "tiles are cyclically distributed across nodes".
        NodeId((i.wrapping_mul(self.p.tiles).wrapping_add(j)) % self.p.nodes)
    }

    /// Which tile does a task *write*? Tasks run where their output lives.
    fn output_tile(&self, t: TaskDesc) -> (u32, u32) {
        match t.class {
            TaskClass::Potrf => (t.k, t.k),
            TaskClass::Trsm => (t.i, t.k),
            TaskClass::Syrk => (t.i, t.i),
            TaskClass::Gemm => (t.i, t.j),
            _ => unreachable!("not a cholesky task"),
        }
    }

    /// Does the task's *output* tile hold useful data (dense)?
    pub fn is_dense_task(&self, t: TaskDesc) -> bool {
        let (i, j) = self.output_tile(t);
        self.tile_kind(i, j) == TileKind::Dense
    }

    pub fn potrf(k: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Potrf, k, k, k)
    }

    pub fn trsm(i: u32, k: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Trsm, i, k, k)
    }

    pub fn syrk(i: u32, k: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Syrk, i, i, k)
    }

    pub fn gemm(i: u32, j: u32, k: u32) -> TaskDesc {
        TaskDesc::indexed(TaskClass::Gemm, i, j, k)
    }

    /// Flop counts per dense tile op (n³ terms; the DES cost model scales
    /// them by measured per-op times instead, these drive priorities).
    fn class_weight(class: TaskClass) -> f64 {
        match class {
            TaskClass::Potrf => 1.0 / 3.0,
            TaskClass::Trsm => 1.0,
            TaskClass::Syrk => 1.0,
            TaskClass::Gemm => 2.0,
            _ => 1.0,
        }
    }
}

impl TaskGraph for CholeskyGraph {
    fn name(&self) -> &str {
        "sparse-cholesky"
    }

    fn num_nodes(&self) -> usize {
        self.p.nodes as usize
    }

    fn roots(&self) -> Vec<TaskDesc> {
        vec![Self::potrf(0)]
    }

    fn successors(&self, t: TaskDesc) -> Vec<TaskDesc> {
        let tt = self.p.tiles;
        let mut out = Vec::new();
        match t.class {
            TaskClass::Potrf => {
                // POTRF(k) -> TRSM(i,k) for all i > k
                for i in t.k + 1..tt {
                    out.push(Self::trsm(i, t.k));
                }
            }
            TaskClass::Trsm => {
                let (i, k) = (t.i, t.k);
                // TRSM(i,k) -> SYRK(i,k)
                out.push(Self::syrk(i, k));
                // -> GEMM(i,j,k) for k < j < i (as the A[i][k] operand)
                for j in k + 1..i {
                    out.push(Self::gemm(i, j, k));
                }
                // -> GEMM(r,i,k) for i < r < T (as the A[j][k] operand)
                for r in i + 1..tt {
                    out.push(Self::gemm(r, i, k));
                }
            }
            TaskClass::Syrk => {
                let (i, k) = (t.i, t.k);
                if k + 1 == i {
                    // last update of the diagonal tile -> factorize it
                    out.push(Self::potrf(i));
                } else {
                    out.push(Self::syrk(i, k + 1));
                }
            }
            TaskClass::Gemm => {
                let (i, j, k) = (t.i, t.j, t.k);
                if k + 1 == j {
                    // tile (i,j) fully updated for panel j -> panel solve
                    out.push(Self::trsm(i, j));
                } else {
                    out.push(Self::gemm(i, j, k + 1));
                }
            }
            _ => unreachable!(),
        }
        out
    }

    fn in_degree(&self, t: TaskDesc) -> u32 {
        match t.class {
            // POTRF(0) is the root; POTRF(k) waits for SYRK(k,k-1).
            TaskClass::Potrf => u32::from(t.k > 0),
            // TRSM(i,k): POTRF(k) + (k>0: GEMM(i,k,k-1))
            TaskClass::Trsm => 1 + u32::from(t.k > 0),
            // SYRK(i,k): TRSM(i,k) + (k>0: SYRK(i,k-1))
            TaskClass::Syrk => 1 + u32::from(t.k > 0),
            // GEMM(i,j,k): TRSM(i,k) + TRSM(j,k) + (k>0: GEMM(i,j,k-1))
            TaskClass::Gemm => 2 + u32::from(t.k > 0),
            _ => unreachable!(),
        }
    }

    fn owner(&self, t: TaskDesc) -> NodeId {
        let (i, j) = self.output_tile(t);
        self.tile_owner(i, j)
    }

    fn is_stealable(&self, t: TaskDesc) -> bool {
        // The paper's worked example for the TTG is_stealable hook:
        // tasks whose tile is sparse do no useful work, don't move them.
        self.is_dense_task(t)
    }

    fn priority(&self, t: TaskDesc) -> i64 {
        // Critical-path-descending heuristic (DPLASMA-style): tasks of
        // earlier panels first; within a panel POTRF ≫ TRSM ≫ SYRK ≫ GEMM,
        // and within a class earlier rows first.
        let tt = self.p.tiles as i64;
        let panel_room = 4 * tt * tt;
        let class_rank = match t.class {
            TaskClass::Potrf => 3,
            TaskClass::Trsm => 2,
            TaskClass::Syrk => 1,
            TaskClass::Gemm => 0,
            _ => 0,
        };
        (tt - t.k as i64) * panel_room + class_rank * tt * tt
            - (t.i as i64) * tt
            - t.j as i64
    }

    fn work_units(&self, t: TaskDesc) -> f64 {
        if self.is_dense_task(t) {
            Self::class_weight(t.class)
        } else {
            // Sparse-output tasks are queue-management no-ops (§4.4).
            0.0
        }
    }

    fn payload_bytes(&self, t: TaskDesc) -> u64 {
        // Migrating a task copies its *input* tiles (§3): output tile +
        // the panel operand tiles.
        let tile_bytes = 8 * self.p.tile_size as u64 * self.p.tile_size as u64;
        let inputs = match t.class {
            TaskClass::Potrf => 1,
            TaskClass::Trsm => 2,
            TaskClass::Syrk => 2,
            TaskClass::Gemm => 3,
            _ => 1,
        };
        inputs * tile_bytes
    }

    fn total_tasks(&self) -> Option<u64> {
        let t = self.p.tiles as u64;
        // POTRF: t, TRSM & SYRK: t(t-1)/2 each, GEMM: t(t-1)(t-2)/6
        Some(
            t + t * t.saturating_sub(1) / 2 * 2
                + t * t.saturating_sub(1) * t.saturating_sub(2) / 6,
        )
    }
}

/// Deterministic dense-tile content for real-mode runs: a diagonally
/// dominant SPD matrix A = M·Mᵀ/s + t·n·I generated tile-wise from the
/// seed, so every node can materialize its tiles without communication.
pub fn spd_tile_entry(seed: u64, t: u32, n: u32, gi: u64, gj: u64) -> f64 {
    // Pseudo-random symmetric entry + strong diagonal.
    let (a, b) = if gi <= gj { (gi, gj) } else { (gj, gi) };
    let h = mix2(seed, a.wrapping_mul(0x1_0000_0001).wrapping_add(b));
    let v = (h >> 11) as f64 / (1u64 << 53) as f64 - 0.5;
    if gi == gj {
        v + (t as f64) * (n as f64) * 0.5 + 2.0
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn graph(t: u32, nodes: u32) -> CholeskyGraph {
        CholeskyGraph::new(CholeskyParams {
            tiles: t,
            tile_size: 8,
            nodes,
            dense_fraction: 0.5,
            seed: 42,
            all_dense: false,
        })
    }

    /// Exhaustively walk the DAG from the root and check that every task
    /// receives exactly `in_degree` activations — the fundamental DAG
    /// consistency invariant between `successors` and `in_degree`.
    #[test]
    fn dag_activation_counts_are_consistent() {
        for t in [1u32, 2, 3, 5, 8] {
            let g = graph(t, 3);
            let mut incoming: HashMap<TaskDesc, u32> = HashMap::new();
            let mut visited = std::collections::HashSet::new();
            // DFS enumerating every edge
            let mut frontier = g.roots();
            while let Some(task) = frontier.pop() {
                if !visited.insert(task) {
                    continue;
                }
                for s in g.successors(task) {
                    *incoming.entry(s).or_insert(0) += 1;
                    frontier.push(s);
                }
            }
            assert_eq!(
                visited.len() as u64,
                g.total_tasks().unwrap(),
                "t={t}: all tasks reachable"
            );
            for task in &visited {
                let expect = g.in_degree(*task);
                let got = incoming.get(task).copied().unwrap_or(0);
                assert_eq!(got, expect, "t={t}: in-degree mismatch at {task}");
            }
        }
    }

    #[test]
    fn total_tasks_formula() {
        let g = graph(4, 2);
        // t=4: 4 potrf + 6 trsm + 6 syrk + 4 gemm = 20
        assert_eq!(g.total_tasks(), Some(20));
    }

    #[test]
    fn diagonal_always_dense() {
        let g = graph(16, 4);
        for k in 0..16 {
            assert_eq!(g.tile_kind(k, k), TileKind::Dense);
        }
    }

    #[test]
    fn dense_fraction_respected() {
        let g = graph(40, 4);
        let t = 40usize;
        // dense_fraction counts over the full square; lower-triangle dense
        // tiles = diagonal + extra so that 0.5*t*t are dense overall
        let want = (t * t) / 2;
        assert_eq!(g.dense_tiles(), want.max(t).min(t * (t + 1) / 2));
    }

    #[test]
    fn owner_is_cyclic_and_stable() {
        let g = graph(8, 3);
        let task = CholeskyGraph::gemm(5, 3, 1);
        assert_eq!(g.owner(task), g.tile_owner(5, 3));
        assert!(g.owner(task).idx() < 3);
        // same output tile -> same owner across panels
        assert_eq!(
            g.owner(CholeskyGraph::gemm(5, 3, 0)),
            g.owner(CholeskyGraph::gemm(5, 3, 2))
        );
    }

    #[test]
    fn stealability_follows_density() {
        let g = graph(20, 2);
        let mut saw_dense = false;
        let mut saw_sparse = false;
        for i in 1..20u32 {
            for j in 0..i {
                let task = CholeskyGraph::gemm(i, j, 0);
                let dense = g.tile_kind(i, j) == TileKind::Dense;
                assert_eq!(g.is_stealable(task), dense);
                saw_dense |= dense;
                saw_sparse |= !dense;
            }
        }
        assert!(saw_dense && saw_sparse, "mask has both kinds");
    }

    #[test]
    fn priorities_prefer_earlier_panels_and_potrf() {
        let g = graph(10, 2);
        assert!(g.priority(CholeskyGraph::potrf(0)) > g.priority(CholeskyGraph::trsm(1, 0)));
        assert!(g.priority(CholeskyGraph::trsm(1, 0)) > g.priority(CholeskyGraph::gemm(2, 1, 0)));
        assert!(g.priority(CholeskyGraph::gemm(5, 2, 0)) > g.priority(CholeskyGraph::potrf(1)));
    }

    #[test]
    fn sparse_tasks_cost_nothing() {
        let g = graph(20, 2);
        for i in 1..20u32 {
            for j in 0..i {
                let task = CholeskyGraph::gemm(i, j, 0);
                if g.tile_kind(i, j) == TileKind::Sparse {
                    assert_eq!(g.work_units(task), 0.0);
                } else {
                    assert!(g.work_units(task) > 0.0);
                }
            }
        }
    }

    #[test]
    fn spd_entries_are_symmetric_and_dominant() {
        let (t, n) = (4u32, 8u32);
        for gi in 0..16u64 {
            for gj in 0..16u64 {
                assert_eq!(
                    spd_tile_entry(7, t, n, gi, gj),
                    spd_tile_entry(7, t, n, gj, gi)
                );
            }
        }
        assert!(spd_tile_entry(7, t, n, 3, 3) > 10.0);
        assert!(spd_tile_entry(7, t, n, 3, 4).abs() <= 0.5);
    }

    #[test]
    fn all_dense_mode() {
        let g = CholeskyGraph::new(CholeskyParams {
            tiles: 6,
            all_dense: true,
            ..CholeskyParams::default()
        });
        assert_eq!(g.dense_tiles(), 21); // full lower triangle
    }
}
