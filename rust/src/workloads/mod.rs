//! Built-in workloads: the paper's two benchmarks.
//!
//! * [`cholesky`] — tiled sparse Cholesky factorization (POTRF / TRSM /
//!   SYRK / GEMM task classes, half the tiles dense, cyclic
//!   distribution) — §4.1;
//! * [`uts`] — the Unbalanced Tree Search benchmark with
//!   child-follows-parent mapping — §4.1/§4.4;
//! * [`kernels`] — pure-Rust tile kernels used as the no-PJRT fallback
//!   executor and as the verification oracle for the PJRT path.

pub mod cholesky;
pub mod kernels;
pub mod uts;

pub use cholesky::{CholeskyGraph, CholeskyParams, TileKind};
pub use uts::{UtsGraph, UtsParams};
