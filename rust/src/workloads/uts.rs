//! Unbalanced Tree Search (UTS) benchmark (Olivier et al., LCPC'06).
//!
//! Binomial variant, as used by the paper (§4.4, Fig. 7 caption
//! `b=120, m=5, q=0.200014, g=12e6`): the root has `b0` children; every
//! other node has `m` children with probability `q` and none otherwise.
//! With `m·q` slightly above 1 the tree is near-critical — deeply
//! unbalanced subtrees, the classic work-stealing stress test.
//!
//! The tree is derived *deterministically* from node hashes (standing in
//! for UTS's SHA-1 stream): the children of a node are a pure function
//! of its id, so thief and victim agree on the subtree under any
//! migration, and a run is reproducible from the seed.
//!
//! Placement is **child-follows-parent** unless stolen (`dynamic_placement`),
//! which is exactly the property the paper uses to explain why `Half`
//! behaves so differently here than on Cholesky: a starving node never
//! spawns new local work, while a busy node's subtree can grow
//! exponentially.

use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;
use crate::util::rng::{mix, mix2};

/// UTS parameters (binomial variant).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UtsParams {
    /// Root branching factor (paper: 120).
    pub b0: u32,
    /// Non-root branching factor (paper: 5).
    pub m: u32,
    /// Probability a non-root node has children (paper: 0.200014).
    pub q: f64,
    /// Work units per node (paper: 12e6 — granularity knob).
    pub g: f64,
    /// Tree seed.
    pub seed: u64,
    /// Nodes in the cluster.
    pub nodes: u32,
    /// Safety cap on total tree size (near-critical trees can blow up);
    /// nodes whose depth-first hash falls beyond the cap get no children.
    pub max_depth: u32,
}

impl Default for UtsParams {
    fn default() -> Self {
        UtsParams {
            b0: 120,
            m: 5,
            q: 0.200014,
            g: 12e6,
            seed: 0x075,
            nodes: 4,
            max_depth: 64,
        }
    }
}

/// The UTS task graph. One task = one tree-node expansion.
pub struct UtsGraph {
    p: UtsParams,
}

impl UtsGraph {
    pub fn new(p: UtsParams) -> Self {
        assert!(p.b0 >= 1 && p.nodes >= 1);
        UtsGraph { p }
    }

    pub fn params(&self) -> &UtsParams {
        &self.p
    }

    pub fn root() -> TaskDesc {
        TaskDesc::dynamic(TaskClass::UtsNode, 1, 0, 0)
    }

    fn child(&self, parent: TaskDesc, idx: u32) -> TaskDesc {
        let uid = mix2(self.p.seed ^ parent.uid, idx as u64 + 1);
        TaskDesc::dynamic(TaskClass::UtsNode, uid | 1, parent.i + 1, idx)
    }

    /// Number of children of a node — a pure function of its uid.
    pub fn num_children(&self, t: TaskDesc) -> u32 {
        if t.uid == 1 {
            return self.p.b0; // root
        }
        if t.i >= self.p.max_depth {
            return 0;
        }
        // Bernoulli(q) drawn from the node hash.
        let draw = mix(t.uid ^ self.p.seed) >> 11;
        let thresh = (self.p.q * (1u64 << 53) as f64) as u64;
        if draw < thresh {
            self.p.m
        } else {
            0
        }
    }

    /// Total tree size by sequential traversal (test/report helper; the
    /// runtime never needs this).
    pub fn tree_size(&self, cap: u64) -> u64 {
        let mut stack = vec![Self::root()];
        let mut count = 0u64;
        while let Some(t) = stack.pop() {
            count += 1;
            if count >= cap {
                return count;
            }
            for c in 0..self.num_children(t) {
                stack.push(self.child(t, c));
            }
        }
        count
    }
}

impl TaskGraph for UtsGraph {
    fn name(&self) -> &str {
        "uts"
    }

    fn num_nodes(&self) -> usize {
        self.p.nodes as usize
    }

    fn roots(&self) -> Vec<TaskDesc> {
        vec![Self::root()]
    }

    fn successors(&self, t: TaskDesc) -> Vec<TaskDesc> {
        (0..self.num_children(t)).map(|c| self.child(t, c)).collect()
    }

    fn in_degree(&self, t: TaskDesc) -> u32 {
        u32::from(t.uid != 1)
    }

    /// Static owner is only used for the root; all other placement is
    /// dynamic (child-follows-parent).
    fn owner(&self, _t: TaskDesc) -> NodeId {
        NodeId(0)
    }

    fn dynamic_placement(&self) -> bool {
        true
    }

    /// Every UTS task is stealable — there is no sparse-tile analogue.
    fn is_stealable(&self, _t: TaskDesc) -> bool {
        true
    }

    fn priority(&self, t: TaskDesc) -> i64 {
        // Deeper nodes first (DFS-ish): keeps queues short and mirrors
        // UTS implementations' LIFO local order.
        t.i as i64
    }

    fn work_units(&self, _t: TaskDesc) -> f64 {
        // Every UTS node performs `g` units of work (the granularity
        // parameter); the cost model converts units to time.
        self.p.g
    }

    fn payload_bytes(&self, _t: TaskDesc) -> u64 {
        // A UTS node migrates only its descriptor (the paper's UTS runs
        // steal "tasks", not data) — a few words on the wire.
        64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> UtsGraph {
        UtsGraph::new(UtsParams {
            b0: 8,
            m: 3,
            q: 0.25,
            g: 100.0,
            seed: 11,
            nodes: 2,
            max_depth: 30,
        })
    }

    #[test]
    fn root_has_b0_children() {
        let g = small();
        assert_eq!(g.successors(UtsGraph::root()).len(), 8);
    }

    #[test]
    fn children_are_deterministic_and_unique() {
        let g = small();
        let a = g.successors(UtsGraph::root());
        let b = g.successors(UtsGraph::root());
        assert_eq!(a, b);
        let mut uids: Vec<u64> = a.iter().map(|t| t.uid).collect();
        uids.sort();
        uids.dedup();
        assert_eq!(uids.len(), 8);
    }

    #[test]
    fn depth_increases() {
        let g = small();
        let c = g.successors(UtsGraph::root())[0];
        assert_eq!(c.i, 1);
        for gc in g.successors(c) {
            assert_eq!(gc.i, 2);
        }
    }

    #[test]
    fn tree_size_is_reproducible_and_finite() {
        let g = small();
        let s1 = g.tree_size(1_000_000);
        let s2 = g.tree_size(1_000_000);
        assert_eq!(s1, s2);
        assert!(s1 >= 9, "at least root + b0 children, got {s1}");
        assert!(s1 < 1_000_000, "capped tree should be finite");
    }

    #[test]
    fn branch_probability_roughly_q() {
        let g = UtsGraph::new(UtsParams {
            b0: 10_000,
            q: 0.2,
            max_depth: 2,
            ..UtsParams::default()
        });
        let children = g.successors(UtsGraph::root());
        let with_kids = children
            .iter()
            .filter(|c| g.num_children(**c) > 0)
            .count() as f64;
        let frac = with_kids / children.len() as f64;
        assert!((frac - 0.2).abs() < 0.02, "empirical q = {frac}");
    }

    #[test]
    fn max_depth_prunes() {
        let g = UtsGraph::new(UtsParams {
            max_depth: 1,
            ..UtsParams::default()
        });
        for c in g.successors(UtsGraph::root()) {
            assert_eq!(g.num_children(c), 0);
        }
    }

    #[test]
    fn dynamic_placement_flags() {
        let g = small();
        assert!(g.dynamic_placement());
        assert!(g.is_stealable(UtsGraph::root()));
        assert_eq!(g.in_degree(UtsGraph::root()), 0);
        assert_eq!(g.in_degree(g.successors(UtsGraph::root())[0]), 1);
    }
}
