//! Pure-Rust tile kernels.
//!
//! Two jobs: (1) the fallback executor for real-mode runs that skip PJRT
//! (fast tests, machines without the XLA extension), and (2) the
//! numerical oracle the PJRT path is verified against — these mirror
//! `python/compile/kernels/ref.py`.

use crate::dataflow::data::Tile;

/// L = chol(A), lower triangular (Cholesky–Banachiewicz).
pub fn potrf(a: &Tile) -> Tile {
    let n = a.n;
    let mut l = Tile::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                assert!(sum > 0.0, "tile not positive definite at ({i},{i}): {sum}");
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.at(j, j));
            }
        }
    }
    l
}

/// X = B · inv(L)ᵀ  (solve X Lᵀ = B, forward substitution per row of X).
pub fn trsm(l: &Tile, b: &Tile) -> Tile {
    let n = l.n;
    let m = b.n; // square tiles: m == n
    let mut x = Tile::zeros(m);
    for r in 0..m {
        for j in 0..n {
            let mut acc = b.at(r, j);
            for k in 0..j {
                acc -= x.at(r, k) * l.at(j, k);
            }
            x.set(r, j, acc / l.at(j, j));
        }
    }
    x
}

/// C ← C − A·Aᵀ.
pub fn syrk(c: &mut Tile, a: &Tile) {
    let n = c.n;
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..a.n {
                acc += a.at(i, k) * a.at(j, k);
            }
            let v = c.at(i, j) - acc;
            c.set(i, j, v);
        }
    }
}

/// C ← C − A·Bᵀ.
pub fn gemm(c: &mut Tile, a: &Tile, b: &Tile) {
    c.gemm_update(a, b);
}

/// ‖L·Lᵀ − A‖∞ (verification).
pub fn reconstruct_error(l: &Tile, a: &Tile) -> f64 {
    let n = l.n;
    let mut worst: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0;
            for k in 0..n {
                acc += l.at(i, k) * l.at(j, k);
            }
            worst = worst.max((acc - a.at(i, j)).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spd(n: usize, seed: u64) -> Tile {
        let mut rng = Rng::new(seed);
        let mut m = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, rng.normal());
            }
        }
        // a = m mᵀ + n I
        let mut a = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    acc += m.at(i, k) * m.at(j, k);
                }
                a.set(i, j, acc);
            }
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        for n in [1, 2, 5, 16, 32] {
            let a = spd(n, n as u64);
            let l = potrf(&a);
            assert!(reconstruct_error(&l, &a) < 1e-9, "n={n}");
            // strictly lower triangular
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn trsm_solves() {
        let n = 12;
        let a = spd(n, 3);
        let l = potrf(&a);
        let mut rng = Rng::new(5);
        let mut b = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b.set(i, j, rng.normal());
            }
        }
        let x = trsm(&l, &b);
        // x lᵀ == b
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += x.at(i, k) * l.at(j, k);
                }
                assert!((acc - b.at(i, j)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn syrk_gemm_agree_when_b_is_a() {
        let n = 10;
        let mut rng = Rng::new(7);
        let mut a = Tile::zeros(n);
        let mut c1 = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                a.set(i, j, rng.normal());
                c1.set(i, j, rng.normal());
            }
        }
        let mut c2 = c1.clone();
        syrk(&mut c1, &a);
        gemm(&mut c2, &a, &a);
        assert!(c1.max_abs_diff(&c2) < 1e-12);
    }

    /// Full blocked factorization using only tile kernels equals the
    /// monolithic factorization of the assembled matrix.
    #[test]
    fn blocked_cholesky_composes() {
        let (t, n) = (3usize, 6usize);
        let big = spd(t * n, 9);
        // split into tiles
        let mut tiles: Vec<Vec<Tile>> = (0..t)
            .map(|bi| {
                (0..t)
                    .map(|bj| {
                        let mut tile = Tile::zeros(n);
                        for i in 0..n {
                            for j in 0..n {
                                tile.set(i, j, big.at(bi * n + i, bj * n + j));
                            }
                        }
                        tile
                    })
                    .collect()
            })
            .collect();
        // right-looking blocked factorization
        for k in 0..t {
            tiles[k][k] = potrf(&tiles[k][k].clone());
            for i in k + 1..t {
                tiles[i][k] = trsm(&tiles[k][k], &tiles[i][k].clone());
            }
            for i in k + 1..t {
                let panel = tiles[i][k].clone();
                syrk(&mut tiles[i][i], &panel);
                for j in k + 1..i {
                    let pj = tiles[j][k].clone();
                    let (pi,) = (tiles[i][k].clone(),);
                    gemm(&mut tiles[i][j], &pi, &pj);
                }
            }
        }
        // assemble and compare against monolithic potrf
        let lref = potrf(&big);
        for bi in 0..t {
            for bj in 0..=bi {
                for i in 0..n {
                    for j in 0..n {
                        let want = lref.at(bi * n + i, bj * n + j);
                        let got = if bj < bi || j <= i {
                            tiles[bi][bj].at(i, j)
                        } else {
                            0.0
                        };
                        assert!(
                            (want - got).abs() < 1e-9,
                            "tile ({bi},{bj}) entry ({i},{j}): {want} vs {got}"
                        );
                    }
                }
            }
        }
    }
}
