//! A small multiply-rotate hasher for the `TaskDesc` hot paths.
//!
//! The std `HashMap` defaults to SipHash-1-3, which is DoS-resistant but
//! costs ~1ns per word of keying and finalization — measurable on the
//! activation path, where every task completion touches the tracker map
//! once per successor edge. Task descriptors are small fixed-size keys
//! produced by the runtime itself (never attacker-controlled), so the
//! collision-resistance of a keyed hash buys nothing here. This is the
//! FxHash construction Firefox and rustc use: fold each word into the
//! state with a rotate + xor + odd-constant multiply.
//!
//! No new crate dependency: `anyhow` stays the only external dep.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Knuth-style odd multiplier (2^64 / golden ratio, forced odd).
const SEED: u64 = 0x517c_c1b7_2722_0a95;
const ROTATE: u32 = 5;

/// Word-at-a-time multiplicative hasher (not keyed — do not expose to
/// untrusted inputs).
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn fold(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(ROTATE) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.fold(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.fold(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.fold(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.fold(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.fold(n);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.fold(n as u64);
        self.fold((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.fold(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed by [`FxHasher`] (drop-in via `FxHashMap::default()`).
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        FxBuildHasher::default().hash_one(v)
    }

    #[test]
    fn deterministic_and_discriminating() {
        let a = TaskDesc::indexed(TaskClass::Gemm, 1, 2, 3);
        let b = TaskDesc::indexed(TaskClass::Gemm, 1, 2, 4);
        assert_eq!(hash_of(&a), hash_of(&a));
        assert_ne!(hash_of(&a), hash_of(&b));
        assert_ne!(hash_of(&0u64), hash_of(&1u64));
    }

    #[test]
    fn map_and_set_round_trip() {
        let mut m: FxHashMap<TaskDesc, u32> = FxHashMap::default();
        let mut s: FxHashSet<TaskDesc> = FxHashSet::default();
        for i in 0..500 {
            let t = TaskDesc::indexed(TaskClass::Trsm, i, i / 3, 0);
            m.insert(t, i);
            s.insert(t);
        }
        assert_eq!(m.len(), 500);
        for i in 0..500 {
            let t = TaskDesc::indexed(TaskClass::Trsm, i, i / 3, 0);
            assert_eq!(m.get(&t), Some(&i));
            assert!(s.contains(&t));
        }
    }

    #[test]
    fn spreads_sequential_keys() {
        // Sequential uids must not collapse into a few buckets: count
        // distinct top-bytes across 4k sequential keys.
        let mut tops: FxHashSet<u8> = FxHashSet::default();
        for i in 0..4096u64 {
            tops.insert((hash_of(&i) >> 56) as u8);
        }
        assert!(tops.len() > 200, "only {} distinct top bytes", tops.len());
    }
}
