//! Flag parsing for the `repro` launcher.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments, with typed accessors that report unknown or
//! malformed flags with the offending text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: positionals + flag map.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// flags that were consumed by a typed accessor (unknown-flag check)
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    // boolean flag
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn u64_opt(&self, key: &str) -> Result<Option<u64>> {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| {
                v.parse::<u64>()
                    .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'"))
            })
            .transpose()
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.u64_opt(key)?.unwrap_or(default))
    }

    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        self.mark(key);
        self.flags
            .get(key)
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| anyhow!("--{key} expects a number, got '{v}'"))
            })
            .transpose()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.f64_opt(key)?.unwrap_or(default))
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.mark(key);
        match self.flags.get(key).map(String::as_str) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("no") | Some("off") => Ok(false),
            Some(v) => bail!("--{key} expects a boolean, got '{v}'"),
        }
    }

    /// Comma-separated u64 list, e.g. `--nodes 2,4,8`.
    pub fn u64_list_or(&self, key: &str, default: &[u64]) -> Result<Vec<u64>> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<u64>()
                        .map_err(|_| anyhow!("--{key}: bad integer '{s}'"))
                })
                .collect(),
        }
    }

    /// Error on any flag that no accessor asked about (catches typos).
    pub fn check_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_kv_and_positionals() {
        let a = parse("figure fig5 --nodes 2,4,8 --seed=7 --verbose");
        assert_eq!(a.positional, vec!["figure", "fig5"]);
        assert_eq!(a.u64_list_or("nodes", &[]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert!(a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.u64_or("workers", 4).unwrap(), 4);
        assert_eq!(a.str_or("policy", "single"), "single");
        assert!(!a.bool_or("steal", false).unwrap());
    }

    #[test]
    fn rejects_bad_types() {
        let a = parse("--seed abc");
        assert!(a.u64_opt("seed").is_err());
        let b = parse("--frac x");
        assert!(b.f64_opt("frac").is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse("--sede 7");
        let _ = a.u64_or("seed", 0);
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("--bias -3.5");
        assert_eq!(a.f64_or("bias", 0.0).unwrap(), -3.5);
    }
}
