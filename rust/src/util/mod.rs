//! Small self-contained substrates the coordinator depends on.
//!
//! The build is fully offline against a minimal vendored crate set, so the
//! usual ecosystem crates (serde, clap, rand, criterion, proptest) are
//! implemented in-tree at the scale this project needs:
//!
//! * [`rng`] — deterministic SplitMix64 / xoshiro256** generators and
//!   distributions (the whole system is seed-reproducible),
//! * [`json`] — a JSON value type with parser and writer (artifact
//!   manifests, cost models, figure outputs),
//! * [`cli`] — flag parsing for the `repro` launcher,
//! * [`bench`] — the micro-benchmark harness used by `cargo bench`,
//! * [`prop`] — a tiny property-testing driver (random cases + shrinking
//!   by case minimization) used by the invariant tests,
//! * [`hash`] — a fast unkeyed hasher (FxHash construction) for the
//!   `TaskDesc`-keyed maps on the activation hot path.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
