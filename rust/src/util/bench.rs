//! Micro-benchmark harness used by `cargo bench` (criterion is not in the
//! vendored crate set, so this provides the same core loop: warmup,
//! calibrated iteration count, multiple samples, robust statistics).

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

/// Result of one benchmark: per-iteration timings across samples.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// mean ns/iter per sample
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        let mut v = self.samples_ns.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        v[v.len() / 2]
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn stddev_ns(&self) -> f64 {
        let m = self.mean_ns();
        (self
            .samples_ns
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / self.samples_ns.len().max(1) as f64)
            .sqrt()
    }

    pub fn report(&self) -> String {
        let med = self.median_ns();
        format!(
            "{:<44} {:>12}/iter  (mean {}, sd {}, {} samples)",
            self.name,
            fmt_ns(med),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.stddev_ns()),
            self.samples_ns.len()
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with criterion-like calibration.
pub struct Bencher {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            sample_time: Duration::from_millis(200),
            samples: 12,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            sample_time: Duration::from_millis(50),
            samples: 5,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, printing the result line immediately.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + estimate iteration cost.
        let warm_start = Instant::now();
        let mut iters_done = 0u64;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            iters_done += 1;
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / iters_done as f64).max(0.5);
        let iters_per_sample = ((self.sample_time.as_nanos() as f64 / est_ns) as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            samples_ns,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Benchmark where each iteration needs fresh input (setup excluded
    /// from timing by batching: setup all inputs first, then time the run).
    pub fn bench_with_setup<S, T>(
        &mut self,
        name: &str,
        mut setup: impl FnMut() -> S,
        mut f: impl FnMut(S) -> T,
    ) -> &BenchResult {
        // estimate
        let warm_start = Instant::now();
        let mut iters_done = 0u64;
        while warm_start.elapsed() < self.warmup {
            let s = setup();
            black_box(f(s));
            iters_done += 1;
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / iters_done as f64).max(1.0);
        let iters_per_sample = ((self.sample_time.as_nanos() as f64 / est_ns) as u64)
            .clamp(1, 10_000);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let inputs: Vec<S> = (0..iters_per_sample).map(|_| setup()).collect();
            // Collect outputs so their Drop (which can dwarf the measured
            // operation, e.g. dropping a 10k-entry queue) runs after the
            // clock stops.
            let mut outputs = Vec::with_capacity(inputs.len());
            let t0 = Instant::now();
            for s in inputs {
                outputs.push(black_box(f(s)));
            }
            samples_ns.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
            drop(outputs);
        }
        let res = BenchResult {
            name: name.to_string(),
            samples_ns,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let mut b = Bencher::quick();
        let r = b.bench("noop-ish", || 1u64.wrapping_add(2)).clone();
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.median_ns() < 1e6);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }

    #[test]
    fn setup_variant_runs() {
        let mut b = Bencher::quick();
        let r = b
            .bench_with_setup("vec-sort", || vec![3u32, 1, 2], |mut v| {
                v.sort();
                v
            })
            .clone();
        assert!(r.mean_ns() > 0.0);
    }
}
