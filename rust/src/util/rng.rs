//! Deterministic pseudo-random number generation.
//!
//! Every stochastic choice in the system (tile sparsity mask, victim
//! selection, task-cost noise, UTS tree shape) flows through these
//! generators so that a run is fully reproducible from its seed — a
//! requirement for the figure harness (paper plots are multi-run
//! distributions over seeds) and for shrinking property-test failures.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
///
/// Also used as a *stateless* hash (`mix`) for UTS tree derivation:
/// the children of a tree node are a pure function of the node id.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }
}

/// The SplitMix64 finalizer as a standalone avalanche hash.
#[inline]
pub fn mix(z: u64) -> u64 {
    let mut z = z;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Combine two ids into one hash (for (parent, child-index) derivation).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    mix(a ^ mix(b ^ 0x9E3779B97F4A7C15))
}

/// xoshiro256** — the workhorse generator for distributions.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free for our n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Log-normal multiplicative noise with the given sigma (in log space).
    /// Used to perturb task costs in the simulator — execution times in the
    /// paper's testbed are right-skewed, and log-normal noise preserves
    /// positivity.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element index distinct from `excl` out of `n`
    /// (random victim selection: a thief never targets itself).
    pub fn pick_other(&mut self, n: usize, excl: usize) -> usize {
        debug_assert!(n > 1);
        let r = self.below(n as u64 - 1) as usize;
        if r >= excl {
            r + 1
        } else {
            r
        }
    }
}

/// The per-node thief-side stream: seed `run_seed ^ (0x5EA1 + node)`.
/// One derivation, called by the threaded runtime's migrate thread and
/// the DES's targeted victim selectors alike, so uniform-mode victim
/// sequences (and targeted-mode exploration draws) are identical by
/// construction across the two runtimes instead of by two hand-rolled
/// copies of the same expression.
pub fn thief_rng(run_seed: u64, node_idx: usize) -> Rng {
    Rng::new(run_seed ^ (0x5EA1 + node_idx as u64))
}

/// The fault-injection stream (`--faults`): one dedicated derivation
/// per fabric (`stream` 0 is the convention for a run's single fabric),
/// disjoint from `thief_rng` and the run seed itself, so an enabled
/// fault plan never perturbs scheduling decisions and a disabled one
/// draws nothing at all.
pub fn fault_rng(run_seed: u64, stream: usize) -> Rng {
    Rng::new(run_seed ^ (0xFA17_0000 + stream as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_differs_by_seed() {
        assert_ne!(SplitMix64::new(1).next_u64(), SplitMix64::new(2).next_u64());
    }

    #[test]
    fn mix2_is_not_symmetric() {
        assert_ne!(mix2(1, 2), mix2(2, 1));
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn pick_other_never_self() {
        let mut r = Rng::new(3);
        for n in 2..10 {
            for excl in 0..n {
                for _ in 0..200 {
                    let p = r.pick_other(n, excl);
                    assert!(p < n && p != excl);
                }
            }
        }
    }

    #[test]
    fn thief_rng_matches_hand_rolled_derivation() {
        // The derivation both runtimes hand-rolled before PR 6; the
        // helper must reproduce it exactly or uniform-mode victim
        // sequences (and figure outputs) change.
        for (seed, idx) in [(0u64, 0usize), (7, 3), (0xC404, 12), (u64::MAX, 255)] {
            let mut legacy = Rng::new(seed ^ (0x5EA1 + idx as u64));
            let mut helper = thief_rng(seed, idx);
            for _ in 0..64 {
                assert_eq!(legacy.next_u64(), helper.next_u64());
            }
        }
        // Distinct nodes get distinct streams.
        assert_ne!(thief_rng(7, 0).next_u64(), thief_rng(7, 1).next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
