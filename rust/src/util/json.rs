//! Minimal JSON: a value type, a recursive-descent parser and a writer.
//!
//! Used for the artifact `manifest.json` produced by the python AOT
//! pipeline, the calibrated cost model (`artifacts/costmodel.json`) and
//! the machine-readable figure outputs under `results/`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys keep insertion-independent (sorted) order via
/// `BTreeMap` so emitted files are deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors for manifest parsing.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing string field '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("missing numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing numeric field '{key}'"))
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (idx, item) in v.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (idx, (k, v)) in m.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at byte {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our files.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated utf-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c\n"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "c\n"
        );
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"dtype": "f64", "entries": [{"name": "gemm_n8_f64", "op": "gemm",
                       "tile": 8, "inputs": 3, "outputs": 1, "file": "gemm_n8_f64.hlo.txt"}]}"#;
        let v = Json::parse(text).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.req_u64("tile").unwrap(), 8);
        assert_eq!(e.req_str("op").unwrap(), "gemm");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_unicode() {
        let v = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café é");
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn pretty_is_deterministic() {
        let a = Json::obj(vec![("z", 1u64.into()), ("a", 2u64.into())]);
        let b = Json::obj(vec![("a", 2u64.into()), ("z", 1u64.into())]);
        assert_eq!(a.pretty(), b.pretty());
    }
}
