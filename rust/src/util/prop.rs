//! A tiny property-testing driver (proptest is not in the vendored crate
//! set). Runs a property over `n` seeded random cases; on failure it
//! re-runs with a halving "size" parameter to report the smallest failing
//! scale, then panics with the seed so the case is exactly reproducible.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint handed to the generator (e.g. matrix dim,
    /// node count, task count). The driver sweeps sizes from small to
    /// large so early failures are already small.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
            max_size: 64,
        }
    }
}

/// Run `prop(rng, size)`; the property indicates failure by returning
/// `Err(message)`.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // sweep sizes up: size grows roughly linearly with case index
        let size = 1 + (cfg.max_size.saturating_sub(1)) * case / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Attempt shrink: retry smaller sizes with the same seed.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 size {} after shrink from {size}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", Config::default(), |rng, _| {
            let a = rng.next_u64() >> 1;
            let b = rng.next_u64() >> 1;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", Config { cases: 3, ..Default::default() }, |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn sizes_sweep_up_to_max() {
        let mut max_seen = 0;
        check(
            "size-sweep",
            Config { cases: 50, max_size: 32, ..Default::default() },
            |_, size| {
                max_seen = max_seen.max(size);
                Ok(())
            },
        );
        assert!(max_seen >= 30, "max size seen {max_seen}");
    }
}
