//! Distributed termination detection (Safra's algorithm).
//!
//! PaRSEC destroys the migrate thread "when the termination detection
//! module detects distributed termination" (§3). With work stealing the
//! classic static-count shortcut is not enough for dynamic workloads
//! (UTS spawns tasks at run time), so the runtime carries a ring-based
//! Safra detector: each node keeps a message deficit (basic messages
//! sent − received) and a color (black after receiving a basic message);
//! a token circulates when nodes are passive, accumulating deficits.
//! The leader announces termination when a white token returns with a
//! zero global deficit to a white, passive leader.

use crate::dataflow::task::NodeId;

/// Token colors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Color {
    White,
    Black,
}

/// The circulating probe token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SafraToken {
    pub color: Color,
    /// Sum of message deficits accumulated so far this round.
    pub count: i64,
    /// Probe round (diagnostics only).
    pub round: u64,
}

/// Per-node Safra state.
#[derive(Debug)]
pub struct SafraState {
    me: NodeId,
    num_nodes: usize,
    /// basic messages sent − received at this node
    deficit: i64,
    color: Color,
    /// Token parked here until the node goes passive.
    held: Option<SafraToken>,
    /// Leader only: number of probe rounds initiated.
    rounds: u64,
}

/// What the caller must do after a state transition.
#[derive(Debug, PartialEq)]
pub enum SafraAction {
    /// Nothing to send.
    None,
    /// Forward this token to the next node in the ring.
    Forward(NodeId, SafraToken),
    /// Leader determined global termination.
    Terminate,
}

impl SafraState {
    pub fn new(me: NodeId, num_nodes: usize) -> Self {
        SafraState {
            me,
            num_nodes,
            deficit: 0,
            color: Color::White,
            held: None,
            rounds: 0,
        }
    }

    fn next(&self) -> NodeId {
        NodeId(((self.me.idx() + 1) % self.num_nodes) as u32)
    }

    pub fn is_leader(&self) -> bool {
        self.me.idx() == 0
    }

    /// Call on every *basic* message send.
    pub fn on_send(&mut self) {
        self.deficit += 1;
    }

    /// Call on every *basic* message receive. Receiving makes the node
    /// black: it may have been re-activated after the token passed.
    pub fn on_receive(&mut self) {
        self.deficit -= 1;
        self.color = Color::Black;
    }

    /// Call when the token arrives. The token is parked until the node is
    /// passive; pass current passivity and act on the returned action.
    pub fn on_token(&mut self, token: SafraToken, passive: bool) -> SafraAction {
        self.held = Some(token);
        self.try_forward(passive)
    }

    /// Leader: start a probe round (only when passive and not already
    /// holding/waiting on a token round).
    pub fn leader_start_probe(&mut self, passive: bool) -> SafraAction {
        debug_assert!(self.is_leader());
        if !passive || self.held.is_some() || self.num_nodes == 1 {
            if self.num_nodes == 1 && passive && self.deficit == 0 {
                return SafraAction::Terminate;
            }
            return SafraAction::None;
        }
        self.rounds += 1;
        // The leader starts a fresh white token with count 0; its own
        // (current) deficit is added at token *return* so late sends are
        // never missed. (Safra: machine 0 sends the token around the
        // ring; direction is irrelevant, we go +1.)
        let token = SafraToken {
            color: self.color,
            count: 0,
            round: self.rounds,
        };
        self.color = Color::White;
        SafraAction::Forward(self.next(), token)
    }

    /// Attempt to forward a parked token; call whenever the node may have
    /// become passive.
    pub fn try_forward(&mut self, passive: bool) -> SafraAction {
        if !passive {
            return SafraAction::None;
        }
        let Some(tok) = self.held else {
            return SafraAction::None;
        };
        if self.is_leader() {
            // Round completed.
            self.held = None;
            if tok.color == Color::White
                && self.color == Color::White
                && tok.count + self.deficit == 0
            {
                // Token accumulated every other node's deficit; adding the
                // leader's *current* deficit closes the global sum — zero
                // means no basic message is in flight anywhere and every
                // node was passive and white when the token passed.
                return SafraAction::Terminate;
            }
            // Inconclusive: whiten and immediately start the next round.
            self.color = Color::White;
            self.rounds += 1;
            let token = SafraToken {
                color: Color::White,
                count: self.deficit,
                round: self.rounds,
            };
            return SafraAction::Forward(self.next(), token);
        }
        // Ordinary node: add deficit, taint color, whiten self.
        self.held = None;
        let color = if self.color == Color::Black {
            Color::Black
        } else {
            tok.color
        };
        self.color = Color::White;
        SafraAction::Forward(
            self.next(),
            SafraToken {
                color,
                count: tok.count + self.deficit,
                round: tok.round,
            },
        )
    }

    pub fn deficit(&self) -> i64 {
        self.deficit
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a full ring by hand: `n` nodes, no traffic -> terminates in
    /// at most two rounds.
    #[test]
    fn quiet_ring_terminates() {
        let n = 4;
        let mut nodes: Vec<SafraState> =
            (0..n).map(|i| SafraState::new(NodeId(i as u32), n)).collect();
        let mut action = nodes[0].leader_start_probe(true);
        let mut hops = 0;
        loop {
            match action {
                SafraAction::Forward(dst, tok) => {
                    hops += 1;
                    assert!(hops < 3 * n, "token should settle quickly");
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => break,
                SafraAction::None => panic!("token lost"),
            }
        }
    }

    #[test]
    fn in_flight_message_defers_termination() {
        let n = 3;
        let mut nodes: Vec<SafraState> =
            (0..n).map(|i| SafraState::new(NodeId(i as u32), n)).collect();
        // node 1 has sent a message that nobody received yet
        nodes[1].on_send();
        let mut action = nodes[0].leader_start_probe(true);
        let mut forwards = 0;
        // run the ring for a while: must never terminate
        while forwards < 20 {
            match action {
                SafraAction::Forward(dst, tok) => {
                    forwards += 1;
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => panic!("terminated with message in flight"),
                SafraAction::None => break,
            }
        }
        // deliver the message: receiver goes black, deficits cancel
        nodes[2].on_receive();
        let mut action = nodes[0].leader_start_probe(true);
        let mut terminated = false;
        for _ in 0..30 {
            match action {
                SafraAction::Forward(dst, tok) => {
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => {
                    terminated = true;
                    break;
                }
                SafraAction::None => break,
            }
        }
        assert!(terminated, "ring must terminate after traffic settles");
    }

    #[test]
    fn busy_node_parks_token() {
        let n = 2;
        let mut a = SafraState::new(NodeId(0), n);
        let mut b = SafraState::new(NodeId(1), n);
        let SafraAction::Forward(dst, tok) = a.leader_start_probe(true) else {
            panic!()
        };
        assert_eq!(dst, NodeId(1));
        // b is busy: token parks
        assert_eq!(b.on_token(tok, false), SafraAction::None);
        // b later becomes passive: token moves on
        match b.try_forward(true) {
            SafraAction::Forward(dst, _) => assert_eq!(dst, NodeId(0)),
            other => panic!("expected forward, got {other:?}"),
        }
    }

    #[test]
    fn single_node_terminates_directly() {
        let mut s = SafraState::new(NodeId(0), 1);
        assert_eq!(s.leader_start_probe(true), SafraAction::Terminate);
        s.on_send();
        assert_eq!(s.leader_start_probe(true), SafraAction::None);
    }
}
