//! Distributed termination detection (Safra's algorithm).
//!
//! PaRSEC destroys the migrate thread "when the termination detection
//! module detects distributed termination" (§3). With work stealing the
//! classic static-count shortcut is not enough for dynamic workloads
//! (UTS spawns tasks at run time), so the runtime carries a ring-based
//! Safra detector: each node keeps a message deficit (basic messages
//! sent − received) and a color (black after receiving a basic message);
//! a token circulates when nodes are passive, accumulating deficits.
//! The leader announces termination when a white token returns with a
//! zero global deficit to a white, passive leader.
//!
//! # Crash-stop repair
//!
//! Since PR 9 the deficit is kept *per peer* rather than as one scalar,
//! and each node carries a live-set over the ring. [`SafraState::deficit`]
//! sums only over live peers, so [`SafraState::declare_dead`] reconciles
//! a dead node's unresolved message deficit by construction: sends to it
//! and receives from it simply stop counting, however late the caller
//! learns about the death (a send to a peer that is *later* declared
//! dead is excluded retroactively — there is no reconciliation race).
//! The ring splices around dead members ([`SafraState::next`] skips
//! them), any parked token from the pre-repair era is discarded, and the
//! leader regenerates the probe in a fresh round; stale in-flight tokens
//! from before the repair are recognized by their round number when they
//! return to the leader and dropped.

use crate::dataflow::task::NodeId;

/// Token colors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Color {
    White,
    Black,
}

/// The circulating probe token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SafraToken {
    pub color: Color,
    /// Sum of message deficits accumulated so far this round.
    pub count: i64,
    /// Probe round; the leader uses it to discard tokens that predate a
    /// ring repair (otherwise diagnostics only).
    pub round: u64,
}

/// Per-node Safra state.
#[derive(Debug)]
pub struct SafraState {
    me: NodeId,
    num_nodes: usize,
    /// Basic messages sent to / received from each peer. The deficit is
    /// computed over live peers only — see the module docs.
    sent_to: Vec<i64>,
    recv_from: Vec<i64>,
    live: Vec<bool>,
    color: Color,
    /// Token parked here until the node goes passive.
    held: Option<SafraToken>,
    /// Leader only: number of probe rounds initiated.
    rounds: u64,
    /// Ring repairs performed (peers declared dead).
    repairs: u64,
}

/// What the caller must do after a state transition.
#[derive(Debug, PartialEq)]
pub enum SafraAction {
    /// Nothing to send.
    None,
    /// Forward this token to the next node in the ring.
    Forward(NodeId, SafraToken),
    /// Leader determined global termination.
    Terminate,
}

impl SafraState {
    pub fn new(me: NodeId, num_nodes: usize) -> Self {
        SafraState {
            me,
            num_nodes,
            sent_to: vec![0; num_nodes],
            recv_from: vec![0; num_nodes],
            live: vec![true; num_nodes],
            color: Color::White,
            held: None,
            rounds: 0,
            repairs: 0,
        }
    }

    /// Next *live* node clockwise on the ring (self if alone).
    fn next(&self) -> NodeId {
        let mut i = (self.me.idx() + 1) % self.num_nodes;
        while !self.live[i] && i != self.me.idx() {
            i = (i + 1) % self.num_nodes;
        }
        NodeId(i as u32)
    }

    pub fn is_leader(&self) -> bool {
        self.me.idx() == 0
    }

    fn num_live(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn is_live(&self, peer: NodeId) -> bool {
        self.live[peer.idx()]
    }

    /// Call on every *basic* message send.
    pub fn on_send(&mut self, dst: NodeId) {
        self.sent_to[dst.idx()] += 1;
    }

    /// Call on every *basic* message receive. Receiving makes the node
    /// black: it may have been re-activated after the token passed.
    pub fn on_receive(&mut self, src: NodeId) {
        self.recv_from[src.idx()] += 1;
        self.color = Color::Black;
    }

    /// Splice `peer` out of the ring and reconcile its deficit: counted
    /// sends to it and receives from it stop contributing (the per-peer
    /// ledgers make this retroactive, so a racing send that was counted
    /// just before the declaration is excluded too). Any parked token is
    /// from the pre-repair era and is discarded — the leader regenerates
    /// the probe in a new round on its normal cadence.
    pub fn declare_dead(&mut self, peer: NodeId) {
        let p = peer.idx();
        if p == self.me.idx() || !self.live[p] {
            return;
        }
        self.live[p] = false;
        self.repairs += 1;
        self.held = None;
        if self.is_leader() {
            // Era bump: tokens launched before the repair carry a stale
            // round and die on return (see `try_forward`).
            self.rounds += 1;
        }
    }

    /// Call when the token arrives. The token is parked until the node is
    /// passive; pass current passivity and act on the returned action.
    pub fn on_token(&mut self, token: SafraToken, passive: bool) -> SafraAction {
        self.held = Some(token);
        self.try_forward(passive)
    }

    /// Leader: start a probe round (only when passive and not already
    /// holding/waiting on a token round).
    pub fn leader_start_probe(&mut self, passive: bool) -> SafraAction {
        debug_assert!(self.is_leader());
        if !passive || self.held.is_some() || self.num_live() == 1 {
            if self.num_live() == 1 && passive && self.deficit() == 0 {
                return SafraAction::Terminate;
            }
            return SafraAction::None;
        }
        self.rounds += 1;
        // The leader starts a fresh white token with count 0; its own
        // (current) deficit is added at token *return* so late sends are
        // never missed. (Safra: machine 0 sends the token around the
        // ring; direction is irrelevant, we go +1.)
        let token = SafraToken {
            color: self.color,
            count: 0,
            round: self.rounds,
        };
        self.color = Color::White;
        SafraAction::Forward(self.next(), token)
    }

    /// Attempt to forward a parked token; call whenever the node may have
    /// become passive.
    pub fn try_forward(&mut self, passive: bool) -> SafraAction {
        if !passive {
            return SafraAction::None;
        }
        let Some(tok) = self.held else {
            return SafraAction::None;
        };
        if self.is_leader() {
            self.held = None;
            if tok.round != self.rounds {
                // A token launched before a ring repair: its count mixes
                // contributions from an era with a different membership.
                // Drop it; the next probe uses the repaired ring.
                return SafraAction::None;
            }
            if tok.color == Color::White
                && self.color == Color::White
                && tok.count + self.deficit() == 0
            {
                // Token accumulated every other node's deficit; adding the
                // leader's *current* deficit closes the global sum — zero
                // means no basic message is in flight anywhere and every
                // node was passive and white when the token passed.
                return SafraAction::Terminate;
            }
            // Inconclusive: whiten and immediately start the next round.
            self.color = Color::White;
            self.rounds += 1;
            let token = SafraToken {
                color: Color::White,
                count: self.deficit(),
                round: self.rounds,
            };
            return SafraAction::Forward(self.next(), token);
        }
        // Ordinary node: add deficit, taint color, whiten self.
        self.held = None;
        let color = if self.color == Color::Black {
            Color::Black
        } else {
            tok.color
        };
        self.color = Color::White;
        SafraAction::Forward(
            self.next(),
            SafraToken {
                color,
                count: tok.count + self.deficit(),
                round: tok.round,
            },
        )
    }

    /// This node's message deficit over *live* peers.
    pub fn deficit(&self) -> i64 {
        (0..self.num_nodes)
            .filter(|&p| self.live[p])
            .map(|p| self.sent_to[p] - self.recv_from[p])
            .sum()
    }

    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Ring repairs this node has performed (peers spliced out).
    pub fn repairs(&self) -> u64 {
        self.repairs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> Vec<SafraState> {
        (0..n).map(|i| SafraState::new(NodeId(i as u32), n)).collect()
    }

    /// Run the ring until termination or `max` hops; returns whether the
    /// leader terminated. Every node is treated as permanently passive.
    fn settle(nodes: &mut [SafraState], max: usize) -> bool {
        let mut action = nodes[0].leader_start_probe(true);
        for _ in 0..max {
            match action {
                SafraAction::Forward(dst, tok) => {
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => return true,
                SafraAction::None => {
                    action = nodes[0].leader_start_probe(true);
                }
            }
        }
        false
    }

    /// Drive a full ring by hand: `n` nodes, no traffic -> terminates in
    /// at most two rounds.
    #[test]
    fn quiet_ring_terminates() {
        let n = 4;
        let mut nodes = ring(n);
        let mut action = nodes[0].leader_start_probe(true);
        let mut hops = 0;
        loop {
            match action {
                SafraAction::Forward(dst, tok) => {
                    hops += 1;
                    assert!(hops < 3 * n, "token should settle quickly");
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => break,
                SafraAction::None => panic!("token lost"),
            }
        }
    }

    #[test]
    fn in_flight_message_defers_termination() {
        let n = 3;
        let mut nodes = ring(n);
        // node 1 has sent a message to node 2 that nobody received yet
        nodes[1].on_send(NodeId(2));
        let mut action = nodes[0].leader_start_probe(true);
        let mut forwards = 0;
        // run the ring for a while: must never terminate
        while forwards < 20 {
            match action {
                SafraAction::Forward(dst, tok) => {
                    forwards += 1;
                    action = nodes[dst.idx()].on_token(tok, true);
                }
                SafraAction::Terminate => panic!("terminated with message in flight"),
                SafraAction::None => break,
            }
        }
        // deliver the message: receiver goes black, deficits cancel
        nodes[2].on_receive(NodeId(1));
        assert!(settle(&mut nodes, 30), "ring must terminate after traffic settles");
    }

    #[test]
    fn busy_node_parks_token() {
        let n = 2;
        let mut a = SafraState::new(NodeId(0), n);
        let mut b = SafraState::new(NodeId(1), n);
        let SafraAction::Forward(dst, tok) = a.leader_start_probe(true) else {
            panic!()
        };
        assert_eq!(dst, NodeId(1));
        // b is busy: token parks
        assert_eq!(b.on_token(tok, false), SafraAction::None);
        // b later becomes passive: token moves on
        match b.try_forward(true) {
            SafraAction::Forward(dst, _) => assert_eq!(dst, NodeId(0)),
            other => panic!("expected forward, got {other:?}"),
        }
    }

    #[test]
    fn single_node_terminates_directly() {
        let mut s = SafraState::new(NodeId(0), 1);
        assert_eq!(s.leader_start_probe(true), SafraAction::Terminate);
        s.on_send(NodeId(0));
        assert_eq!(s.leader_start_probe(true), SafraAction::None);
    }

    /// Ring repair with one dead node: the dead peer's unresolved
    /// deficit (sends to it that it never matched, receives from it that
    /// the survivors counted) reconciles to zero and the spliced ring
    /// terminates.
    #[test]
    fn ring_repair_reconciles_one_dead_node() {
        let n = 4;
        let mut nodes = ring(n);
        // Traffic involving the doomed node 2, unmatched at crash time:
        // 0 sent it two messages it processed (its recv ledger dies with
        // it), it sent 1 a message that 1 received (1's recv counted),
        // and 3 sent it a message still in flight.
        nodes[0].on_send(NodeId(2));
        nodes[0].on_send(NodeId(2));
        nodes[1].on_receive(NodeId(2));
        nodes[3].on_send(NodeId(2));
        // Without the repair the global deficit is permanently positive:
        // the ring can never terminate.
        assert!(!settle(&mut nodes, 40));
        // Node 2 crash-stops; every survivor splices it out.
        for i in [0usize, 1, 3] {
            nodes[i].declare_dead(NodeId(2));
            assert_eq!(nodes[i].repairs(), 1);
        }
        assert_eq!(nodes[0].deficit(), 0);
        assert_eq!(nodes[1].deficit(), 0);
        assert_eq!(nodes[3].deficit(), 0);
        assert!(settle(&mut nodes, 40), "spliced ring must terminate");
        // The ring now hops 0 -> 1 -> 3 -> 0.
        match nodes[1].on_token(
            SafraToken {
                color: Color::White,
                count: 0,
                round: nodes[0].rounds(),
            },
            true,
        ) {
            SafraAction::Forward(dst, _) => assert_eq!(dst, NodeId(3)),
            other => panic!("expected forward past the dead node, got {other:?}"),
        }
    }

    /// Two dead nodes, declared at different times, with a late racing
    /// send to an already-declared peer: the per-peer ledgers make the
    /// reconciliation retroactive, so the ring still terminates.
    #[test]
    fn ring_repair_reconciles_two_dead_nodes() {
        let n = 5;
        let mut nodes = ring(n);
        nodes[1].on_send(NodeId(2));
        nodes[3].on_send(NodeId(4));
        nodes[0].on_receive(NodeId(4));
        for i in [0usize, 1, 3] {
            nodes[i].declare_dead(NodeId(2));
        }
        // A racing send counted *after* the declaration: excluded
        // retroactively because the deficit is computed per peer.
        nodes[1].on_send(NodeId(2));
        assert_eq!(nodes[1].deficit(), 0);
        for i in [0usize, 1, 3] {
            nodes[i].declare_dead(NodeId(4));
            assert_eq!(nodes[i].repairs(), 2);
        }
        assert_eq!(nodes[0].deficit(), 0);
        assert_eq!(nodes[3].deficit(), 0);
        assert!(settle(&mut nodes, 60), "doubly spliced ring must terminate");
        // Survivor-to-survivor traffic still counts normally.
        nodes[1].on_send(NodeId(3));
        assert!(!settle(&mut nodes, 40));
        nodes[3].on_receive(NodeId(1));
        assert!(settle(&mut nodes, 60));
    }

    /// A token launched before a repair is recognized by its stale round
    /// number when it returns to the leader and discarded instead of
    /// being evaluated against the repaired ring.
    #[test]
    fn stale_round_token_dies_at_leader() {
        let n = 3;
        let mut nodes = ring(n);
        let SafraAction::Forward(_, tok) = nodes[0].leader_start_probe(true) else {
            panic!()
        };
        // While the token is in flight, node 2 dies and the ring repairs.
        nodes[0].declare_dead(NodeId(2));
        nodes[1].declare_dead(NodeId(2));
        // The stale token eventually finds its way back to the leader.
        assert_eq!(nodes[0].on_token(tok, true), SafraAction::None);
        // The next probe terminates on the repaired ring.
        assert!(settle(&mut nodes, 30));
    }
}
