//! `repro` — the leader entrypoint / CLI launcher.
//!
//! Subcommands:
//!
//! * `run`       — one run (DES by default; `--backend real` for the
//!   threaded runtime, `--backend pjrt` for real PJRT tile kernels).
//! * `figure`    — regenerate a paper figure/table (`fig1..fig9`,
//!   `table1`, `stats`, `all`).
//! * `calibrate` — measure PJRT kernel timings, fit and store the DES
//!   cost model.
//! * `verify`    — end-to-end numerical check: distributed Cholesky via
//!   PJRT artifacts, ‖L·Lᵀ − A‖∞.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use parsteal::config::{RunConfig, Workload};
use parsteal::dataflow::ttg::TaskGraph;
use parsteal::figures::{self, Ctx, RunOverrides, Scale};
use parsteal::node::{Cluster, ClusterConfig, SpinExecutor};
use parsteal::runtime::executor::build_tile_store;
use parsteal::runtime::{calibrate, KernelService, PjrtCholeskyExecutor};
use parsteal::sim::{CostModel, Simulator};
use parsteal::util::cli::Args;
use parsteal::workloads::{CholeskyGraph, CholeskyParams, UtsGraph};

fn usage() -> String {
    "usage: repro <run|figure|calibrate|verify> [flags]\n\
     \n\
     repro run [--workload cholesky|uts] [--nodes 4] [--workers 40]\n\
     \x20         [--tiles 200] [--tile-size 50] [--steal true] [--victim single]\n\
     \x20         [--thief ready-successors] [--waiting-time true] [--seed 1]\n\
     \x20         [--exec-ewma false] [--exec-per-class false]\n\
     \x20         [--share-estimates false] [--victim-select uniform|targeted]\n\
     \x20         [--sched central|sharded|workassist] [--pool-floor 2]\n\
     \x20         [--batch-activations true]\n\
     \x20         [--faults off|drop=P,dup=P,delay=Fx,slow-node=N,\n\
     \x20          crash-node=N,crash-at-us=T,crash-p=P,...]\n\
     \x20         [--topology flat|socket=S,rack=R,socket-lat-us=L,...]\n\
     \x20         [--steal-domains flat|hierarchical]\n\
     \x20         [--backend sim|real|pjrt] [--artifacts artifacts]\n\
     repro figure <fig1..fig9|table1|stats|all> [--out results] [--seeds 5]\n\
     \x20         [--figure-scale small|paper] [--sched central|sharded|workassist]\n\
     \x20         [--victim-select uniform|targeted] [--artifacts artifacts]\n\
     \x20         [--topology SPEC] [--steal-domains flat|hierarchical]\n\
     repro calibrate [--reps 50] [--out artifacts/costmodel.json]\n\
     repro verify [--tiles 6] [--tile-size 16] [--nodes 2] [--workers 2]\n\
     \x20         [--steal true] [--sched central|sharded|workassist]\n\
     \x20         [--artifacts artifacts] [--pjrt-threads 2]\n"
        .to_string()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv)?;
    let Some(cmd) = args.positional.first().cloned() else {
        eprint!("{}", usage());
        std::process::exit(2);
    };
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "figure" => cmd_figure(&args),
        "calibrate" => cmd_calibrate(&args),
        "verify" => cmd_verify(&args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{}", usage()),
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let backend = args.str_or("backend", "sim");
    let artifacts = artifacts_dir(args);
    args.check_unknown()?;
    let cost = CostModel::load_or_default(&artifacts.join("costmodel.json"));

    let report = match (&cfg.workload, backend.as_str()) {
        (Workload::Cholesky(p), "sim") => {
            let graph = Arc::new(CholeskyGraph::new(p.clone()));
            Simulator::new(graph, cfg.sim_config(), cost, cfg.migrate, p.tile_size).run()
        }
        (Workload::Uts(p), "sim") => {
            let graph = Arc::new(UtsGraph::new(*p));
            Simulator::new(graph, cfg.sim_config(), cost, cfg.migrate, 0).run()
        }
        (Workload::Cholesky(p), "real") => {
            let graph = Arc::new(CholeskyGraph::new(p.clone()));
            let g2 = graph.clone();
            let tile = p.tile_size;
            let ex = Arc::new(SpinExecutor::new(cost, tile, move |t| g2.work_units(t)));
            Cluster::run(graph, cfg.cluster_config(), ex)
        }
        (Workload::Cholesky(p), "pjrt") => {
            let graph = Arc::new(CholeskyGraph::new(p.clone()));
            let svc = KernelService::start(
                artifacts,
                Some(vec![p.tile_size]),
                args.u64_or("pjrt-threads", 2)? as usize,
            )?;
            let ex = Arc::new(PjrtCholeskyExecutor::new(graph.clone(), svc));
            Cluster::run(graph, cfg.cluster_config(), ex)
        }
        (Workload::Uts(p), "real") => {
            let graph = Arc::new(UtsGraph::new(*p));
            let g2 = graph.clone();
            let ex = Arc::new(SpinExecutor::new(cost, 0, move |t| g2.work_units(t)));
            Cluster::run(graph, cfg.cluster_config(), ex)
        }
        (_, other) => bail!("unsupported backend '{other}' for this workload"),
    };

    let steals = report.total_steals();
    println!("workload:        {}", report.workload);
    println!("backend:         {backend}");
    println!(
        "nodes x workers: {} x {}",
        report.nodes.len(),
        report.workers_per_node
    );
    println!("tasks executed:  {}", report.tasks_total_executed());
    println!("makespan:        {:.3} s", report.makespan_us / 1e6);
    println!(
        "per-node tasks:  {:?}",
        report
            .nodes
            .iter()
            .map(|n| n.tasks_executed)
            .collect::<Vec<_>>()
    );
    println!(
        "steals:          {} requests, {} successful ({:.1}%), {} tasks migrated, {} wt-denials",
        steals.requests_sent,
        steals.successful_steals,
        steals.success_pct(),
        steals.tasks_migrated,
        steals.waiting_time_denials
    );
    let wm = report.nodes.iter().map(|n| n.sched.watermark).max().unwrap_or(0);
    let walks: u64 = report.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
    let sites = report.batch_site_totals();
    let site_text = sites
        .iter()
        .filter(|(_, batches, _)| *batches > 0)
        .map(|(site, batches, saved)| format!("{} {batches} (+{saved} locks saved)", site.label()))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "sched:           batches: {}; max watermark {wm}, {walks} fallback walks",
        if site_text.is_empty() { "none".to_string() } else { site_text }
    );
    if !cfg.topology.is_flat() || cfg.steal_domains != parsteal::topology::StealDomains::Flat {
        let tiers = report.tier_steal_totals();
        let per_tier = parsteal::topology::TIER_NAMES
            .iter()
            .zip(tiers)
            .map(|(name, (req, _, _))| format!("{name} {req}"))
            .collect::<Vec<_>>()
            .join(", ");
        println!(
            "topology:        [{}] domains {}; tier requests: {per_tier}; cross-tier {} requests / {} bytes",
            cfg.topology.label(),
            cfg.steal_domains.label(),
            report.cross_tier_steal_requests(),
            report.cross_tier_steal_bytes()
        );
    }
    if steals.requests_sent > 0 {
        let victims = report.victim_totals();
        let text = victims
            .iter()
            .enumerate()
            .filter(|(_, (g, d, e, t, q))| g + d + e + t + q > 0)
            .map(|(v, (g, d, e, t, q))| {
                let mark = if *q > 0 { "/q" } else { "" };
                format!("n{v} {g}g/{d}d/{e}e/{t}t{mark}")
            })
            .collect::<Vec<_>>()
            .join(", ");
        println!(
            "victims:         [{}] {text} (grants/wt-denials/empties/timeouts per victim; \
             /q = quarantined)",
            cfg.migrate.victim_select.label()
        );
    }
    if cfg.faults.enabled {
        println!(
            "faults:          [{}] {} dropped, {} duplicated; {} timeouts, {} retries, \
             {} ledger reclaims, {} dup replies suppressed",
            cfg.faults.label(),
            report.faults_dropped,
            report.faults_duplicated,
            report.steal_timeouts_total(),
            report.steal_retries_total(),
            report.ledger_reclaims_total(),
            report.dup_replies_suppressed_total()
        );
    }
    if cfg.faults.has_crash() {
        println!(
            "recovery:        {} suspected, {} crashed, {} ring repairs, {} tasks recovered \
             (detect latency {:.0}µs)",
            report.recovery.nodes_suspected,
            report.recovery.nodes_crashed,
            report.recovery.ring_repairs,
            report.recovery.tasks_recovered,
            report.recovery.detect_latency_us
        );
    }
    if cfg.migrate.share_estimates {
        println!(
            "estimates:       {} digests merged, {} cold-class adoptions (merges per node {:?})",
            report.digest_merges_total(),
            report.digest_class_adoptions_total(),
            report
                .nodes
                .iter()
                .map(|n| n.digest_merges)
                .collect::<Vec<_>>()
        );
    }
    if cfg.migrate.exec_per_class {
        let est = report.class_est_us_max();
        let classes = parsteal::dataflow::task::TaskClass::ALL
            .iter()
            .filter(|c| est[c.idx()] > 0.0)
            .map(|c| format!("{} {:.1}µs", c.name(), est[c.idx()]))
            .collect::<Vec<_>>()
            .join(", ");
        println!("class est:       {classes}");
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = PathBuf::from(args.str_or("out", "results"));
    let scale = Scale::parse(&args.str_or("figure-scale", "small"));
    let seeds = args.u64_or("seeds", 5)?;
    let sched = args
        .str_or("sched", "central")
        .parse::<parsteal::sched::SchedBackend>()
        .map_err(anyhow::Error::msg)?;
    let victim_select = args
        .str_or("victim-select", "uniform")
        .parse::<parsteal::migrate::VictimSelect>()
        .map_err(anyhow::Error::msg)?;
    let topology = args
        .str_or("topology", "flat")
        .parse::<parsteal::topology::Topology>()
        .map_err(anyhow::Error::msg)?;
    let steal_domains = args
        .str_or("steal-domains", "flat")
        .parse::<parsteal::topology::StealDomains>()
        .map_err(anyhow::Error::msg)?;
    let artifacts = artifacts_dir(args);
    args.check_unknown()?;
    let overrides = RunOverrides::default()
        .with_sched(sched)
        .with_victim_select(victim_select)
        .with_topology(topology)
        .with_steal_domains(steal_domains);
    let ctx = Ctx::new(scale, seeds, &artifacts, &out).overrides(overrides);
    let text = figures::run(&ctx, &id)?;
    println!("{text}");
    eprintln!("(machine-readable output under {})", out.display());
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifacts = artifacts_dir(args);
    let reps = args.u64_or("reps", 50)? as usize;
    let out = PathBuf::from(args.str_opt("out").unwrap_or_else(|| {
        artifacts
            .join("costmodel.json")
            .to_string_lossy()
            .into_owned()
    }));
    args.check_unknown()?;
    let model = calibrate(&artifacts, reps, Some(&out))?;
    println!("calibrated cost model -> {}", out.display());
    println!("{}", model.to_json().pretty());
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let tiles = args.u64_or("tiles", 6)? as u32;
    let tile_size = args.u64_or("tile-size", 16)? as u32;
    let nodes = args.u64_or("nodes", 2)? as u32;
    let workers = args.u64_or("workers", 2)? as usize;
    let steal = args.bool_or("steal", true)?;
    let sched = args
        .str_or("sched", "central")
        .parse::<parsteal::sched::SchedBackend>()
        .map_err(anyhow::Error::msg)?;
    let threads = args.u64_or("pjrt-threads", 2)? as usize;
    let artifacts = artifacts_dir(args);
    args.check_unknown()?;

    let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles,
        tile_size,
        nodes,
        dense_fraction: 1.0,
        seed: 0xE2E,
        all_dense: true,
    }));
    let reference = build_tile_store(&graph);
    let svc = KernelService::start(artifacts, Some(vec![tile_size]), threads)?;
    let ex = Arc::new(PjrtCholeskyExecutor::new(graph.clone(), svc));
    let t0 = std::time::Instant::now();
    let migrate = if steal {
        parsteal::migrate::MigrateConfig::default().with_poll_interval_us(50.0)
    } else {
        parsteal::migrate::MigrateConfig::disabled()
    };
    let report = Cluster::run(
        graph.clone(),
        ClusterConfig::default()
            .with_workers_per_node(workers)
            .with_link(parsteal::comm::LinkModel::ideal())
            .with_migrate(migrate)
            .with_record_polls(false)
            .with_sched(sched),
        ex.clone(),
    );
    let wall = t0.elapsed();
    let err = ex.verify(&reference);
    let steals = report.total_steals();
    println!(
        "verify: {}x{} tiles of {}x{} f64, {} nodes x {} workers, steal={}",
        tiles, tiles, tile_size, tile_size, nodes, workers, steal
    );
    println!("tasks executed: {}", report.tasks_total_executed());
    println!(
        "steals: {} successful / {} requests, {} tasks migrated",
        steals.successful_steals, steals.requests_sent, steals.tasks_migrated
    );
    println!("wall time: {:.3} s", wall.as_secs_f64());
    println!("‖L·Lᵀ − A‖∞ = {err:.3e}");
    if err < 1e-8 {
        println!("VERIFY OK");
        Ok(())
    } else {
        bail!("verification FAILED: error {err:.3e} above 1e-8")
    }
}
