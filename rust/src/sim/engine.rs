//! The discrete-event engine.
//!
//! Virtual-time mirror of the threaded runtime in [`crate::node`]: the
//! same [`Scheduler`] backends, `ActivationTracker` and migrate-module
//! policy code run under an event loop with per-node worker pools.
//! Events:
//!
//! * `Finish`  — a worker completes a task (schedules successor
//!   activations, local or remote);
//! * `Deliver` — a message crosses the wire (activation or steal
//!   protocol, delayed by the link model);
//! * `Poll`    — a node's migrate thread wakes up and runs the thief-side
//!   starvation check;
//! * `Crash` / `Recover` — crash-stop fault injection (`--faults
//!   crash-*`): the node falls silent at the crash instant, and one
//!   detection latency later ([`suspicion_timeout_us`], the DES mirror
//!   of the threaded leader's heartbeat threshold) the recovery sweep
//!   re-homes every piece of its unfinished work onto the rehash
//!   survivor — ready queue, executing set, transfer ledger, partial
//!   activation state, and orphaned in-flight activations.
//!
//! Termination: the engine is done when no work remains anywhere
//! (queues, executing sets, in-flight messages); `Poll` events alone
//! never keep it alive. The real runtime must *detect* this state with
//! Safra's algorithm; the simulator, being omniscient, just observes it
//! — integration tests check both agree on task counts.

use std::cmp::Ordering as CmpOrdering;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::Arc;

use crate::comm::{LinkModel, Msg};
use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;
use crate::dataflow::ActivationTracker;
use crate::faults::{FaultClass, FaultPlan};
use crate::metrics::{NodeReport, PollSample, RecoveryStats, RunReport};
use crate::migrate::{
    class_estimate_update, classify_reply, ewma_update, exec_estimate_seeded_us, is_starving,
    merge_estimate, protocol::decide_steal, steal_req_id, steal_timeout_us, suspicion_timeout_us,
    EstimateDigest, ExecSnapshot, MigrateConfig, StarvationView, StealStats, VictimOutcome,
    VictimSelect, VictimSelector, ACK_PROBE_BUDGET, THIEF_RETRY_BUDGET,
};
use crate::sched::{BatchSite, POOL_FLOOR, SchedBackend, Scheduler, StealOutcome, TaskMeta};
use crate::topology::{EscalationState, StealDomains, Topology, TIER_COUNT};
use crate::util::rng::{fault_rng, thief_rng, Rng};

use super::cost::CostModel;

/// Successors of `task` that will activate locally on `node_id` — the
/// increment the incremental starvation view maintains per execution.
fn local_successor_count(graph: &dyn TaskGraph, node_id: NodeId, task: TaskDesc) -> usize {
    let dynamic = graph.dynamic_placement();
    graph
        .successors(task)
        .into_iter()
        .filter(|s| dynamic || graph.owner(*s) == node_id)
        .count()
}

/// Simulator knobs (cluster geometry and wire model).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimConfig {
    /// Worker threads per node (paper: 40).
    pub workers_per_node: usize,
    pub link: LinkModel,
    /// Seed for cost noise and victim selection.
    pub seed: u64,
    /// Hard safety cap on processed events.
    pub max_events: u64,
    /// Record per-select poll samples (Fig. 1/Fig. 3 instrumentation;
    /// costs memory on huge runs).
    pub record_polls: bool,
    /// Scheduler backend per node (`--sched
    /// central|sharded|workassist`). The sim is single-threaded, so
    /// every backend is deterministic given the seed; sharded and
    /// workassist reproduce their *ordering* semantics.
    pub sched: SchedBackend,
    /// Coalesce same-destination successor activations into one
    /// `Deliver` event (`--batch-activations`; off reproduces the
    /// per-edge protocol for ablations). Also routes each local
    /// activation ready set through one batched queue insert.
    pub batch_activations: bool,
    /// Sharded steal-pool floor (`--pool-floor`; see
    /// [`crate::sched::POOL_FLOOR`]).
    pub pool_floor: usize,
    /// Fault-injection plan for steal-protocol messages (`--faults`).
    /// The DES wire model drops messages for real (no Safra detector
    /// to balance), so the self-healing protocol — timeouts, retries,
    /// the transfer ledger — carries the run to completion. Default
    /// off: no draws, no extra events, byte-identical behavior.
    pub faults: FaultPlan,
    /// Tiered link model (`--topology`): resolves every node *pair* to
    /// the link of the tightest tier containing both. The flat default
    /// returns `link` verbatim for every pair — byte-identical to the
    /// pre-topology simulator.
    pub topology: Topology,
    /// Steal-domain traversal (`--steal-domains`): flat (the paper's
    /// cluster-wide victim pool, default) or hierarchical (exhaust the
    /// nearest topology tier before escalating).
    pub steal_domains: StealDomains,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            workers_per_node: 40,
            link: LinkModel::cluster(),
            seed: 1,
            max_events: u64::MAX,
            record_polls: true,
            sched: SchedBackend::Central,
            batch_activations: true,
            pool_floor: POOL_FLOOR,
            faults: FaultPlan::default(),
            topology: Topology::flat(),
            steal_domains: StealDomains::Flat,
        }
    }
}

/// Chainable setters, so call sites state only what differs from the
/// default instead of restating every knob (and silently breaking when
/// a knob is added).
impl SimConfig {
    pub fn with_workers_per_node(mut self, workers: usize) -> Self {
        self.workers_per_node = workers;
        self
    }
    pub fn with_link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_max_events(mut self, max_events: u64) -> Self {
        self.max_events = max_events;
        self
    }
    pub fn with_record_polls(mut self, record: bool) -> Self {
        self.record_polls = record;
        self
    }
    pub fn with_sched(mut self, sched: SchedBackend) -> Self {
        self.sched = sched;
        self
    }
    pub fn with_batch_activations(mut self, batch: bool) -> Self {
        self.batch_activations = batch;
        self
    }
    pub fn with_pool_floor(mut self, floor: usize) -> Self {
        self.pool_floor = floor;
        self
    }
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }
    pub fn with_steal_domains(mut self, domains: StealDomains) -> Self {
        self.steal_domains = domains;
        self
    }
}

#[derive(Clone, Debug)]
enum SimMsg {
    Activate(TaskDesc),
    /// Coalesced activations from one completion to one destination —
    /// the DES mirror of `comm::Msg::ActivateBatch`.
    ActivateBatch(Vec<TaskDesc>),
    StealRequest {
        thief: NodeId,
        /// Request id ([`steal_req_id`]); correlates retries, replies
        /// and acks. Header metadata, free on the modeled wire.
        req: u64,
    },
    /// The DES mirror of `comm::Msg::StealReply`: under
    /// `--share-estimates` a granted reply also carries the victim's
    /// [`EstimateDigest`], priced into the wire model exactly like the
    /// threaded runtime's message. `victim` is the sender (the threaded
    /// runtime reads it off the envelope) and `denied_by_waiting_time`
    /// mirrors the wire flag, so the thief can attribute the outcome to
    /// its per-victim history — both are header metadata, free on the
    /// modeled wire.
    StealReply {
        req: u64,
        victim: NodeId,
        tasks: Vec<TaskDesc>,
        digest: Option<EstimateDigest>,
        denied_by_waiting_time: bool,
    },
    /// Thief → victim handshake closing a steal request (faults-on
    /// only): `accepted` retires the parked ledger entry, a nack sends
    /// it home — the DES mirror of `comm::Msg::TransferAck`.
    TransferAck {
        req: u64,
        accepted: bool,
    },
}

#[derive(Clone, Debug)]
enum EventKind {
    Finish {
        node: NodeId,
        task: TaskDesc,
        started_us: f64,
    },
    Deliver {
        dst: NodeId,
        msg: SimMsg,
    },
    Poll {
        node: NodeId,
    },
    /// Thief-side watchdog (faults-on only): if `req` is still pending
    /// when this fires, the steal is abandoned, nacked and retried.
    StealTimeout {
        node: NodeId,
        req: u64,
    },
    /// Victim-side watchdog (faults-on only): if `req`'s ledger entry
    /// is still unacked when this fires, the stored reply retransmits.
    AckTimeout {
        node: NodeId,
        req: u64,
    },
    /// Crash-stop injection (`--faults crash-*` only): the node falls
    /// silent — its queued events are discarded at the pop, traffic to
    /// it is orphaned or dropped with exact accounting.
    Crash {
        node: NodeId,
    },
    /// Detection + ring repair + lineage recovery, one detection
    /// latency after the matching [`EventKind::Crash`]: the omniscient
    /// DES compresses the threaded runtime's heartbeat detector, Safra
    /// splice and leader sweep into a single deterministic event.
    Recover {
        node: NodeId,
    },
}

/// Thief-side record of one unanswered steal request. The map is
/// maintained on every run (exact end-of-run slot accounting — the
/// `inflight_steals` leak fix); only faults-on runs arm a
/// [`EventKind::StealTimeout`] against it.
#[derive(Clone, Copy, Debug)]
struct SimPendingSteal {
    victim: NodeId,
    attempt: u32,
}

/// How a request id was settled on the thief — the DES mirror of the
/// threaded runtime's resolution map. Late or duplicated replies
/// consult this to re-ack idempotently instead of re-enqueueing.
#[derive(Clone, Copy, Debug)]
enum SimStealResolution {
    AckedGrant,
    AckedDenial,
    Abandoned,
}

/// Victim-side transfer-ledger entry: a granted reply's tasks stay
/// parked here until the thief's ack retires them (or a nack sends
/// them home through a `GateDenial` batch insert). The stored reply
/// retransmits verbatim on ack-timeout, so duplicates are exact.
struct SimLedgerEntry {
    thief: NodeId,
    tasks: Vec<TaskDesc>,
    reply: SimMsg,
    reply_bytes: u64,
    attempt: u32,
}

struct Event {
    t_us: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t_us == other.t_us && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap: earliest time first, then insertion order
        other
            .t_us
            .total_cmp(&self.t_us)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct SimNode {
    /// Persistent slowness factor for this run (straggler model).
    slow_factor: f64,
    queue: Box<dyn Scheduler>,
    /// Round-robin worker cursor: which shard the next `select` hints
    /// (the central backend ignores it).
    next_worker: usize,
    tracker: ActivationTracker,
    executing: HashSet<TaskDesc>,
    /// Local successors of currently-executing tasks, maintained
    /// incrementally (see `node::cluster`): the thief-side poll reads a
    /// counter instead of walking `executing`.
    executing_local_succ: usize,
    idle_workers: usize,
    tasks_done: u64,
    exec_sum_us: f64,
    /// EWMA of observed execution times (µs); 0.0 = no history. Feeds
    /// the waiting-time gate under `MigrateConfig::exec_ewma` — the DES
    /// mirror of the threaded runtime's atomic-bits EWMA.
    exec_ewma_us: f64,
    /// Per-class execution-time estimates (µs; 0.0 = no history for the
    /// class), updated at finish under [`MigrateConfig::track_per_class`]
    /// via the shared [`class_estimate_update`] rule — the DES mirror
    /// of the threaded runtime's atomic-bits table. Steal-reply digests
    /// merge into the same entries via [`merge_estimate`].
    class_est_us: [f64; TaskClass::COUNT],
    /// Completed-task counts behind each class estimate (the merge
    /// weights for `--share-estimates`).
    class_samples: [u64; TaskClass::COUNT],
    /// Digest-merged node-wide seed (µs; 0.0 = none) and its weight:
    /// the gate's cold-start fallback ([`exec_estimate_seeded_us`]).
    remote_avg_us: f64,
    remote_avg_samples: u64,
    /// Steal-reply digests merged into this node's tables.
    digest_merges: u64,
    /// Class entries adopted cold from a digest (no local history).
    digest_class_adoptions: u64,
    /// Non-empty activation ready sets delivered through the batched
    /// path (asserted equal to the activation-site batch counter).
    activation_ready_batches: u64,
    busy_us: f64,
    steal: StealStats,
    /// Thief-side per-victim reply outcomes (index = victim node),
    /// recorded for every reply regardless of `--victim-select` —
    /// the DES mirror of the threaded runtime's atomic tables.
    victim_grants: Vec<u64>,
    victim_wt_denials: Vec<u64>,
    victim_empties: Vec<u64>,
    /// Per-victim abandoned requests (thief-side timeouts; faults-on
    /// only — a reliable fabric answers every request).
    victim_timeouts: Vec<u64>,
    /// Per-victim quarantine records (crash declarations and exhausted
    /// retry budgets): the permanent [`VictimOutcome::Quarantined`]
    /// state the targeted selector never forgives.
    victim_quarantined: Vec<u64>,
    /// The targeted victim selector (`--victim-select targeted`). Its
    /// RNG is the per-node thief stream ([`thief_rng`]), so targeted
    /// mode never perturbs the simulator's shared cost-noise stream —
    /// default-off runs stay bit-identical.
    victim_sel: VictimSelector,
    /// Hierarchical steal-domain escalation (`--steal-domains
    /// hierarchical`): the shared per-thief state machine. Inert (never
    /// consulted) in flat mode.
    escalation: EscalationState,
    /// Per-class counts of queued (ready) tasks, maintained alongside
    /// every queue insert/remove — the thief-side class mix the
    /// targeted selector weighs digest richness by. O(1) reads, like
    /// the starvation counters.
    queued_class: [usize; TaskClass::COUNT],
    /// Thief-side steal-request counts by victim tier
    /// ([`Topology::tier_of`]); sums to `steal.requests_sent`.
    tier_steal_requests: [u64; TIER_COUNT],
    /// Granted replies received, by victim tier; sums to
    /// `steal.successful_steals`.
    tier_steal_grants: [u64; TIER_COUNT],
    /// Granted-reply wire bytes received, by victim tier.
    tier_steal_bytes: [u64; TIER_COUNT],
    inflight_steals: usize,
    /// Monotonic counter behind [`steal_req_id`].
    next_req: u64,
    /// Thief side: requests awaiting a reply (or a timeout).
    pending_steals: HashMap<u64, SimPendingSteal>,
    /// Thief side: settled request ids (faults-on only; dedup + re-ack).
    resolved_steals: HashMap<u64, SimStealResolution>,
    /// Victim side: request ids already served (faults-on only;
    /// duplicate requests retransmit the parked reply instead of
    /// granting twice).
    served_reqs: HashSet<u64>,
    /// Victim side: the transfer ledger (faults-on only).
    ledger: HashMap<u64, SimLedgerEntry>,
    steal_timeouts: u64,
    steal_retries: u64,
    ledger_reclaims: u64,
    dup_replies_suppressed: u64,
    polls: Vec<PollSample>,
    arrival_ready: Vec<PollSample>,
    next_poll_scheduled: bool,
}

/// The simulator. Construct, then [`Simulator::run`].
pub struct Simulator {
    graph: Arc<dyn TaskGraph>,
    cfg: SimConfig,
    cost: CostModel,
    migrate: MigrateConfig,
    tile_size: u32,
    nodes: Vec<SimNode>,
    heap: BinaryHeap<Event>,
    seq: u64,
    now_us: f64,
    rng: Rng,
    events_processed: u64,
    /// Deliver (wire message) events processed — the quantity activation
    /// batching exists to shrink.
    deliver_events: u64,
    /// Activation messages currently on the wire.
    activate_in_flight: u64,
    /// Stolen tasks currently on the wire (inside StealReply messages).
    /// Faults-on grants are accounted in `ledger_total` instead — the
    /// wire may drop them, but the ledger cannot.
    tasks_in_transit: u64,
    /// Tasks parked in victim transfer ledgers (faults-on only): work
    /// that exists nowhere else once a granted reply is dropped, so it
    /// must keep the run alive until an ack or nack settles it.
    ledger_total: u64,
    /// Dedicated fault stream ([`fault_rng`]): a disabled plan draws
    /// nothing, an enabled one never perturbs the cost-noise stream.
    fault_rng: Rng,
    /// Steal-class messages the fault plan dropped / duplicated.
    faults_dropped: u64,
    faults_duplicated: u64,
    /// Resolved crash schedule (node, virtual time), drawn once from the
    /// dedicated crash stream (`fault_rng(seed, 1)`); `None` arms
    /// nothing — no draws, no events, byte-identical event streams.
    crash: Option<(u32, f64)>,
    /// Crashed nodes: their events are discarded at the pop, traffic to
    /// them is orphaned or dropped with exact accounting.
    dead: Vec<bool>,
    /// Crashed nodes whose recovery sweep has run: traffic still in
    /// flight to them re-routes to the rehash survivor on delivery.
    swept: Vec<bool>,
    /// Activations delivered to a dead node before its recovery sweep —
    /// the DES mirror of the threaded fabric's graveyard. Applied at the
    /// rehash survivor by the sweep; counted as outstanding work.
    orphans: Vec<TaskDesc>,
    /// Crash-recovery telemetry (detection, repair, re-homed tasks).
    recovery: RecoveryStats,
}

impl Simulator {
    /// `tile_size` parameterizes the dense-op cost fit (Cholesky); pass
    /// anything for workloads that ignore it (UTS, synthetic).
    pub fn new(
        graph: Arc<dyn TaskGraph>,
        cfg: SimConfig,
        cost: CostModel,
        migrate: MigrateConfig,
        tile_size: u32,
    ) -> Self {
        let n = graph.num_nodes();
        let mut rng = Rng::new(cfg.seed);
        let nodes = (0..n)
            .map(|i| SimNode {
                // The slow-factor draw stays on the shared stream in the
                // same order as ever; the selector gets its own per-node
                // thief stream so default-off runs are bit-identical.
                slow_factor: if cost.node_sigma > 0.0 {
                    rng.lognormal_noise(cost.node_sigma)
                } else {
                    1.0
                },
                queue: cfg.sched.build_with(cfg.workers_per_node, cfg.pool_floor),
                next_worker: 0,
                tracker: ActivationTracker::new(),
                executing: HashSet::new(),
                executing_local_succ: 0,
                idle_workers: cfg.workers_per_node,
                tasks_done: 0,
                exec_sum_us: 0.0,
                exec_ewma_us: 0.0,
                class_est_us: [0.0; TaskClass::COUNT],
                class_samples: [0; TaskClass::COUNT],
                remote_avg_us: 0.0,
                remote_avg_samples: 0,
                digest_merges: 0,
                digest_class_adoptions: 0,
                activation_ready_batches: 0,
                busy_us: 0.0,
                steal: StealStats::default(),
                victim_grants: vec![0; n],
                victim_wt_denials: vec![0; n],
                victim_empties: vec![0; n],
                victim_timeouts: vec![0; n],
                victim_quarantined: vec![0; n],
                victim_sel: VictimSelector::new(i, n.max(2), thief_rng(cfg.seed, i))
                    .with_topology(&cfg.topology, cfg.link),
                escalation: EscalationState::new(&cfg.topology, i, n),
                queued_class: [0; TaskClass::COUNT],
                tier_steal_requests: [0; TIER_COUNT],
                tier_steal_grants: [0; TIER_COUNT],
                tier_steal_bytes: [0; TIER_COUNT],
                inflight_steals: 0,
                next_req: 0,
                pending_steals: HashMap::new(),
                resolved_steals: HashMap::new(),
                served_reqs: HashSet::new(),
                ledger: HashMap::new(),
                steal_timeouts: 0,
                steal_retries: 0,
                ledger_reclaims: 0,
                dup_replies_suppressed: 0,
                polls: Vec::new(),
                arrival_ready: Vec::new(),
                next_poll_scheduled: false,
            })
            .collect();
        Simulator {
            rng,
            graph,
            cfg,
            cost,
            migrate,
            tile_size,
            nodes,
            heap: BinaryHeap::new(),
            seq: 0,
            now_us: 0.0,
            events_processed: 0,
            deliver_events: 0,
            activate_in_flight: 0,
            tasks_in_transit: 0,
            ledger_total: 0,
            fault_rng: fault_rng(cfg.seed, 0),
            faults_dropped: 0,
            faults_duplicated: 0,
            // The crash schedule draws from its own stream (index 1):
            // plans without a crash spec draw nothing, and an armed one
            // never perturbs the message-fault stream above.
            crash: cfg.faults.crash_schedule(n, &mut fault_rng(cfg.seed, 1)),
            dead: vec![false; n],
            swept: vec![false; n],
            orphans: Vec::new(),
            recovery: RecoveryStats::default(),
        }
    }

    /// Per-pair link resolution through the topology. Flat returns the
    /// base link *verbatim* (the same value, not a recomputation), so
    /// default-off runs are byte-identical to the pre-topology engine.
    fn link_for(&self, a: NodeId, b: NodeId) -> LinkModel {
        self.cfg.topology.link_between(a.idx(), b.idx(), self.cfg.link)
    }

    fn push_event(&mut self, t_us: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Event {
            t_us,
            seq: self.seq,
            kind,
        });
    }

    /// No work left anywhere: every queue and executing set is empty and
    /// no activation or stolen task is on the wire. (The real runtime has
    /// to *detect* this with Safra's algorithm; the simulator is
    /// omniscient.) Steal-protocol chatter is deliberately excluded —
    /// otherwise thieves keep each other alive forever (the bug class the
    /// termination-detection literature exists for).
    fn work_done(&self) -> bool {
        self.activate_in_flight == 0
            && self.tasks_in_transit == 0
            && self.ledger_total == 0
            && self.orphans.is_empty()
            && self
                .nodes
                .iter()
                .all(|n| n.queue.is_empty() && n.executing.is_empty())
    }

    /// Rehash target for work owned by `id`: `id` itself while live,
    /// else the first live node cyclically after it — the deterministic
    /// ownership rehash both runtimes share, so lineage recovery lands
    /// on the same survivor everywhere.
    fn route(&self, id: NodeId) -> NodeId {
        if !self.dead[id.idx()] {
            return id;
        }
        let n = self.nodes.len();
        for k in 1..n {
            let c = (id.idx() + k) % n;
            if !self.dead[c] {
                return NodeId(c as u32);
            }
        }
        id
    }

    /// Schedule a steal-class message across the modeled wire, routed
    /// through the fault plan exactly like the threaded fabric's send
    /// path: dropped messages schedule no `Deliver` at all (the DES has
    /// no Safra detector to balance), duplicates schedule two, delays
    /// stretch the modeled transfer time. Disabled plans draw nothing
    /// and multiply by exactly 1.0, so default-off event streams are
    /// byte-identical.
    fn send_steal_msg(
        &mut self,
        src: NodeId,
        dst: NodeId,
        class: FaultClass,
        bytes: u64,
        msg: SimMsg,
    ) {
        let d = self
            .cfg
            .faults
            .decide(class, src.0, dst.0, self.now_us, &mut self.fault_rng);
        if d.dropped {
            self.faults_dropped += 1;
            return;
        }
        let wire = self.link_for(src, dst).transfer_us(bytes) * d.delay_mult;
        if d.duplicate {
            self.faults_duplicated += 1;
            self.push_event(
                self.now_us + wire,
                EventKind::Deliver {
                    dst,
                    msg: msg.clone(),
                },
            );
        }
        self.push_event(self.now_us + wire, EventKind::Deliver { dst, msg });
    }

    /// Arm the thief-side watchdog for a pending request (faults-on
    /// only): the deadline is the Khatiri round-trip-derived
    /// [`steal_timeout_us`] on the *pairwise* link to the victim,
    /// backing off with the attempt number.
    fn arm_steal_timeout(&mut self, node: NodeId, victim: NodeId, req: u64, attempt: u32) {
        let link = self.link_for(node, victim);
        let t = steal_timeout_us(
            link.latency_us,
            link.bw_bytes_per_us,
            self.migrate.migrate_overhead_us,
            self.migrate.poll_interval_us,
            attempt,
        );
        self.push_event(self.now_us + t, EventKind::StealTimeout { node, req });
    }

    /// Arm the victim-side watchdog for an unacked ledger entry
    /// (faults-on only), same deadline schedule as the thief's on the
    /// same pairwise link.
    fn arm_ack_timeout(&mut self, node: NodeId, thief: NodeId, req: u64, attempt: u32) {
        let link = self.link_for(node, thief);
        let t = steal_timeout_us(
            link.latency_us,
            link.bw_bytes_per_us,
            self.migrate.migrate_overhead_us,
            self.migrate.poll_interval_us,
            attempt,
        );
        self.push_event(self.now_us + t, EventKind::AckTimeout { node, req });
    }

    /// The victim's execution-time estimates for the waiting-time gate
    /// (shared policy helpers, so the threaded runtime cannot diverge);
    /// the node-wide estimate falls back to the digest-merged seed
    /// while the node is cold (`--share-estimates`).
    fn victim_exec_snapshot(&self, node_ix: usize) -> ExecSnapshot {
        let node = &self.nodes[node_ix];
        ExecSnapshot {
            avg_us: exec_estimate_seeded_us(
                self.migrate.exec_ewma,
                node.exec_ewma_us,
                node.exec_sum_us,
                node.tasks_done,
                node.remote_avg_us,
            ),
            per_class: self.migrate.exec_per_class.then_some(node.class_est_us),
        }
    }

    /// Merge a steal-reply digest into the thief's estimator tables:
    /// the shared [`EstimateDigest::merge_into`] loop for the per-class
    /// entries, plus the node-wide cold-start seed.
    fn merge_digest(node: &mut SimNode, digest: &EstimateDigest) {
        node.digest_class_adoptions +=
            digest.merge_into(&mut node.class_est_us, &mut node.class_samples);
        if digest.avg_samples > 0 && digest.avg_us > 0.0 {
            let (merged, n) = merge_estimate(
                node.remote_avg_us,
                node.remote_avg_samples,
                digest.avg_us,
                digest.avg_samples,
            );
            node.remote_avg_us = merged;
            node.remote_avg_samples = n;
        }
        node.digest_merges += 1;
    }

    /// Pull ready tasks onto idle workers.
    fn dispatch(&mut self, node_id: NodeId) {
        loop {
            let node = &mut self.nodes[node_id.idx()];
            if node.idle_workers == 0 {
                break;
            }
            let worker = node.next_worker;
            let Some(task) = node.queue.select(worker) else {
                break;
            };
            node.queued_class[task.class.idx()] =
                node.queued_class[task.class.idx()].saturating_sub(1);
            node.next_worker = (worker + 1) % self.cfg.workers_per_node.max(1);
            if self.cfg.record_polls {
                node.polls.push(PollSample {
                    t_us: self.now_us,
                    ready: node.queue.len() as u32,
                });
            }
            node.idle_workers -= 1;
            node.executing.insert(task);
            node.executing_local_succ += local_successor_count(self.graph.as_ref(), node_id, task);
            let base = self
                .cost
                .exec_us(task.class, self.tile_size, self.graph.work_units(task));
            let noise = if self.cost.noise_sigma > 0.0 {
                self.rng.lognormal_noise(self.cost.noise_sigma)
            } else {
                1.0
            };
            let dur = (base * noise * node.slow_factor).max(0.01);
            self.push_event(
                self.now_us + dur,
                EventKind::Finish {
                    node: node_id,
                    task,
                    started_us: self.now_us,
                },
            );
        }
    }

    fn activate_at(&mut self, node_id: NodeId, task: TaskDesc) {
        let graph = self.graph.clone();
        let node = &mut self.nodes[node_id.idx()];
        if node.tracker.activate(graph.as_ref(), task) {
            node.queued_class[task.class.idx()] += 1;
            node.queue
                .insert_meta(task, graph.priority(task), TaskMeta::of(graph.as_ref(), task));
            self.dispatch(node_id);
        }
    }

    /// Deliver a coalesced activation batch: run the tracker over every
    /// task, then enqueue the whole ready set through one batched
    /// insert — the batch-first activation pipeline, mirroring the
    /// threaded runtime's `activate_local_batch`.
    fn activate_batch_at(&mut self, node_id: NodeId, tasks: &[TaskDesc]) {
        let graph = self.graph.clone();
        let node = &mut self.nodes[node_id.idx()];
        let mut ready = Vec::new();
        for &t in tasks {
            if node.tracker.activate(graph.as_ref(), t) {
                ready.push(t);
            }
        }
        if !ready.is_empty() {
            node.activation_ready_batches += 1;
            for t in &ready {
                node.queued_class[t.class.idx()] += 1;
            }
            let batch = TaskMeta::batch_of(graph.as_ref(), &ready);
            node.queue.insert_batch_at(BatchSite::Activation, &batch);
            self.dispatch(node_id);
        }
    }

    fn on_finish(&mut self, node_id: NodeId, task: TaskDesc, started_us: f64) {
        let dur = self.now_us - started_us;
        let succs = self.graph.successors(task);
        let dynamic = self.graph.dynamic_placement();
        // Same filter as local_successor_count, over the vec we already
        // hold — successors() (RNG work for UTS) runs once per finish.
        let local_succ = succs
            .iter()
            .filter(|s| dynamic || self.graph.owner(**s) == node_id)
            .count();
        {
            let node = &mut self.nodes[node_id.idx()];
            node.executing.remove(&task);
            node.executing_local_succ -= local_succ;
            node.idle_workers += 1;
            node.tasks_done += 1;
            node.exec_sum_us += dur;
            if self.migrate.exec_ewma {
                node.exec_ewma_us = ewma_update(node.exec_ewma_us, dur);
            }
            if self.migrate.track_per_class() {
                let est = &mut node.class_est_us[task.class.idx()];
                *est = class_estimate_update(*est, dur);
                node.class_samples[task.class.idx()] += 1;
            }
            node.busy_us += dur;
        }
        // Remote successors sharing a destination coalesce into one
        // Deliver event — the DES mirror of the ActivateBatch message —
        // and local successors coalesce into one batched queue insert.
        let mut local: Vec<TaskDesc> = Vec::new();
        let mut remote: Vec<(NodeId, Vec<TaskDesc>)> = Vec::new();
        for s in succs {
            let dest = if dynamic { node_id } else { self.graph.owner(s) };
            // Post-recovery, activations for dead-owned tasks re-route
            // to the rehash survivor at the send; inside the detection
            // window they stay addressed to the dead node and are
            // orphaned on delivery (detection latency is not free).
            let dest = if self.swept[dest.idx()] {
                self.route(dest)
            } else {
                dest
            };
            if dest == node_id {
                if self.cfg.batch_activations {
                    local.push(s);
                } else {
                    self.activate_at(node_id, s);
                }
            } else if self.cfg.batch_activations {
                match remote.iter_mut().find(|(d, _)| *d == dest) {
                    Some((_, bucket)) => bucket.push(s),
                    None => remote.push((dest, vec![s])),
                }
            } else {
                let wire = self
                    .link_for(node_id, dest)
                    .transfer_us(Msg::activation_wire_bytes(1));
                self.activate_in_flight += 1;
                self.push_event(
                    self.now_us + wire,
                    EventKind::Deliver {
                        dst: dest,
                        msg: SimMsg::Activate(s),
                    },
                );
            }
        }
        if !local.is_empty() {
            self.activate_batch_at(node_id, &local);
        }
        for (dest, tasks) in remote {
            let wire = self
                .link_for(node_id, dest)
                .transfer_us(Msg::activation_wire_bytes(tasks.len()));
            self.activate_in_flight += 1;
            let msg = if tasks.len() == 1 {
                SimMsg::Activate(tasks[0])
            } else {
                SimMsg::ActivateBatch(tasks)
            };
            self.push_event(self.now_us + wire, EventKind::Deliver { dst: dest, msg });
        }
        self.dispatch(node_id);
        self.ensure_poll(node_id);
    }

    /// Make sure a starvation-check poll is pending for this node.
    fn ensure_poll(&mut self, node_id: NodeId) {
        if !self.migrate.enabled || self.nodes.len() < 2 || self.work_done() {
            return;
        }
        let node = &mut self.nodes[node_id.idx()];
        if node.next_poll_scheduled {
            return;
        }
        node.next_poll_scheduled = true;
        self.push_event(
            self.now_us + self.migrate.poll_interval_us,
            EventKind::Poll { node: node_id },
        );
    }

    fn on_poll(&mut self, node_id: NodeId) {
        {
            let node = &mut self.nodes[node_id.idx()];
            node.next_poll_scheduled = false;
        }
        if !self.migrate.enabled || self.work_done() {
            return;
        }
        // O(1) counter reads — the poll never walks the queue or the
        // executing set (mirrors the threaded migrate thread).
        let view = StarvationView {
            ready: self.nodes[node_id.idx()].queue.len(),
            executing_local_successors: match self.migrate.thief {
                crate::migrate::ThiefPolicy::ReadyOnly => 0,
                crate::migrate::ThiefPolicy::ReadySuccessors => {
                    self.nodes[node_id.idx()].executing_local_succ
                }
            },
        };
        let starving = is_starving(self.migrate.thief, view);
        let (idle, can_request) = {
            let node = &self.nodes[node_id.idx()];
            (
                node.executing.is_empty() && node.queue.is_empty(),
                node.inflight_steals < self.migrate.max_inflight,
            )
        };
        if starving && can_request {
            let me = node_id.idx();
            let n_nodes = self.nodes.len();
            let hierarchical = self.cfg.steal_domains == StealDomains::Hierarchical;
            let victim = match self.migrate.victim_select {
                // The paper's protocol, on the simulator's shared
                // stream — the exact draw sequence of every prior PR
                // while the membership is intact; once a node has
                // crashed the same single draw maps onto the k-th live
                // candidate instead (`None` = no live peers to rob).
                // Hierarchical mode is a new mode and draws over the
                // escalation tier's live peers instead (falling back to
                // the whole cluster when the near tiers hold none).
                VictimSelect::Uniform => {
                    if hierarchical {
                        let tier = self.nodes[me].escalation.tier();
                        let mut cands: Vec<usize> = self
                            .cfg
                            .topology
                            .peers_within(me, n_nodes, tier)
                            .into_iter()
                            .filter(|&p| !self.dead[p])
                            .collect();
                        if cands.is_empty() {
                            cands = (0..n_nodes).filter(|&i| i != me && !self.dead[i]).collect();
                        }
                        if cands.is_empty() {
                            None
                        } else {
                            let k = self.rng.below(cands.len() as u64) as usize;
                            Some(NodeId(cands[k] as u32))
                        }
                    } else if self.dead.iter().any(|&d| d) {
                        let live: Vec<usize> = (0..n_nodes)
                            .filter(|&i| i != me && !self.dead[i])
                            .collect();
                        if live.is_empty() {
                            None
                        } else {
                            let k = self.rng.below(live.len() as u64) as usize;
                            Some(NodeId(live[k] as u32))
                        }
                    } else {
                        Some(NodeId(self.rng.pick_other(n_nodes, me) as u32))
                    }
                }
                VictimSelect::Targeted => {
                    // Fallback win per stolen task = the thief's own
                    // node-wide estimate (digest-seeded while cold) —
                    // the same quantity the victim-side gate runs on.
                    // With per-class tracking on, the thief's queued
                    // class mix weighs the digest-derived per-class
                    // richness; under hierarchical domains the pick is
                    // masked to the escalation tier's peers.
                    let node = &self.nodes[me];
                    let fallback = exec_estimate_seeded_us(
                        self.migrate.exec_ewma,
                        node.exec_ewma_us,
                        node.exec_sum_us,
                        node.tasks_done,
                        node.remote_avg_us,
                    );
                    let mix = self.migrate.track_per_class().then(|| node.queued_class);
                    let domain = hierarchical.then(|| {
                        let tier = node.escalation.tier();
                        let mut mask = vec![false; n_nodes];
                        for p in self.cfg.topology.peers_within(me, n_nodes, tier) {
                            mask[p] = true;
                        }
                        mask
                    });
                    let pick = self.nodes[me].victim_sel.pick_scoped(
                        fallback,
                        domain.as_deref(),
                        mix.as_ref(),
                    );
                    Some(NodeId(pick as u32))
                }
            };
            if let Some(victim) = victim {
                let tier = self.cfg.topology.tier_of(me, victim.idx());
                let req = {
                    let node = &mut self.nodes[me];
                    node.inflight_steals += 1;
                    node.steal.requests_sent += 1;
                    node.tier_steal_requests[tier] += 1;
                    let req = steal_req_id(node_id.0, node.next_req);
                    node.next_req += 1;
                    node.pending_steals
                        .insert(req, SimPendingSteal { victim, attempt: 0 });
                    req
                };
                self.send_steal_msg(
                    node_id,
                    victim,
                    FaultClass::Request,
                    16,
                    SimMsg::StealRequest {
                        thief: node_id,
                        req,
                    },
                );
                if self.cfg.faults.enabled {
                    self.arm_steal_timeout(node_id, victim, req, 0);
                }
            }
        }
        // Keep polling while the node still has any reason to act: the
        // paper's migrate thread runs until distributed termination, but
        // the simulator must not keep itself alive on polls alone — only
        // reschedule if something is still happening somewhere.
        let _ = idle;
        self.ensure_poll(node_id);
    }

    fn on_steal_request(&mut self, victim_id: NodeId, thief: NodeId, req: u64) {
        let faults_on = self.cfg.faults.enabled;
        if faults_on && !self.nodes[victim_id.idx()].served_reqs.insert(req) {
            // Duplicate request (fabric dup, or a retransmit racing the
            // reply): if the grant is still parked, resend the stored
            // reply verbatim; a settled denial needs nothing.
            let parked = self.nodes[victim_id.idx()]
                .ledger
                .get(&req)
                .map(|e| (e.reply.clone(), e.reply_bytes));
            if let Some((reply, bytes)) = parked {
                self.send_steal_msg(victim_id, thief, FaultClass::Reply, bytes, reply);
            }
            return;
        }
        let graph = self.graph.clone();
        let workers = self.cfg.workers_per_node;
        let est = self.victim_exec_snapshot(victim_id.idx());
        // The waiting-time gate prices the migration against the
        // *pairwise* link to this thief — a socket-local steal is
        // cheaper to grant than a cross-rack one.
        let link = self.link_for(victim_id, thief);
        let node = &mut self.nodes[victim_id.idx()];
        node.steal.requests_served += 1;
        let decision = decide_steal(
            &self.migrate,
            graph.as_ref(),
            node.queue.as_ref(),
            workers,
            &est,
            link.latency_us,
            link.bw_bytes_per_us,
        );
        if decision.tasks.is_empty() {
            if decision.denied_by_waiting_time {
                node.steal.waiting_time_denials += 1;
            } else {
                node.steal.empty_denials += 1;
            }
        } else {
            node.steal.tasks_migrated += decision.tasks.len() as u64;
            node.steal.payload_bytes += decision.payload_bytes;
            for t in &decision.tasks {
                node.queued_class[t.class.idx()] =
                    node.queued_class[t.class.idx()].saturating_sub(1);
            }
        }
        // Execution-time knowledge travels with stolen work
        // (--share-estimates): a granted reply carries the victim's
        // digest — built through the shared sample-capping constructor
        // — priced into the shared wire model below.
        let digest = (self.migrate.share_estimates && !decision.tasks.is_empty()).then(|| {
            let node = &self.nodes[victim_id.idx()];
            EstimateDigest::snapshot(
                est.avg_us,
                node.tasks_done,
                node.class_est_us,
                node.class_samples,
            )
        });
        // Reply (even when empty: the thief must learn the steal failed).
        let granted = !decision.tasks.is_empty();
        if !faults_on {
            // Reliable wire: the in-flight counter alone keeps the run
            // alive until the reply lands (exact PR 6 accounting).
            self.tasks_in_transit += decision.tasks.len() as u64;
        }
        let reply_bytes = Msg::steal_reply_wire_bytes(
            decision.tasks.len(),
            decision.payload_bytes,
            digest.as_ref(),
        );
        let msg = SimMsg::StealReply {
            req,
            victim: victim_id,
            tasks: decision.tasks,
            digest,
            denied_by_waiting_time: decision.denied_by_waiting_time,
        };
        if faults_on && granted {
            // Park the grant in the transfer ledger until the thief's
            // ack retires it: the wire may drop the reply, the ledger
            // cannot. Accounted in `ledger_total` *before* the send so
            // the work can never be invisible to `work_done`.
            let tasks = match &msg {
                SimMsg::StealReply { tasks, .. } => tasks.clone(),
                _ => unreachable!(),
            };
            self.ledger_total += tasks.len() as u64;
            self.nodes[victim_id.idx()].ledger.insert(
                req,
                SimLedgerEntry {
                    thief,
                    tasks,
                    reply: msg.clone(),
                    reply_bytes,
                    attempt: 0,
                },
            );
            self.arm_ack_timeout(victim_id, thief, req, 0);
        }
        self.send_steal_msg(victim_id, thief, FaultClass::Reply, reply_bytes, msg);
    }

    /// Permanently quarantine `victim` in `node`'s targeted selector and
    /// record it once in the per-victim telemetry (the `/q` marker both
    /// runtimes print). Idempotent: quarantine never decays, so only the
    /// first record per victim counts.
    fn quarantine(&mut self, node_ix: usize, victim_ix: usize) {
        let node = &mut self.nodes[node_ix];
        if node.victim_sel.is_quarantined(victim_ix) {
            return;
        }
        node.victim_sel
            .record(victim_ix, VictimOutcome::Quarantined, None);
        node.victim_quarantined[victim_ix] += 1;
    }

    fn on_steal_reply(
        &mut self,
        node_id: NodeId,
        req: u64,
        victim: NodeId,
        tasks: Vec<TaskDesc>,
        digest: Option<EstimateDigest>,
        denied_by_waiting_time: bool,
    ) {
        let graph = self.graph.clone();
        let granted = !tasks.is_empty();
        if granted
            && self.dead[victim.idx()]
            && !self.nodes[node_id.idx()].resolved_steals.contains_key(&req)
        {
            // A grant whose victim has since crashed is refused: the
            // durable copy of these tasks is the entry parked in the
            // dead node's transfer ledger, which the recovery sweep
            // re-homes — absorbing the in-flight copy too would run
            // them twice. Resolve the request as abandoned (the sweep's
            // probe reads exactly this verdict) and quarantine the
            // victim so the thief never solicits it again.
            let node = &mut self.nodes[node_id.idx()];
            node.pending_steals.remove(&req);
            node.resolved_steals
                .insert(req, SimStealResolution::Abandoned);
            node.inflight_steals = node.inflight_steals.saturating_sub(1);
            node.steal_timeouts += 1;
            node.victim_timeouts[victim.idx()] += 1;
            if self.cfg.steal_domains == StealDomains::Hierarchical {
                node.escalation.on_miss();
            }
            self.quarantine(node_id.idx(), victim.idx());
            self.ensure_poll(node_id);
            return;
        }
        if self.cfg.faults.enabled {
            // Settle the request id exactly once: duplicated or late
            // replies only repeat the handshake verdict, never the
            // enqueue.
            if let Some(&res) = self.nodes[node_id.idx()].resolved_steals.get(&req) {
                self.nodes[node_id.idx()].dup_replies_suppressed += 1;
                let ack = match res {
                    SimStealResolution::AckedGrant => Some(true),
                    SimStealResolution::Abandoned => Some(false),
                    SimStealResolution::AckedDenial => None,
                };
                if let Some(accepted) = ack {
                    self.send_steal_msg(
                        node_id,
                        victim,
                        FaultClass::Ack,
                        16,
                        SimMsg::TransferAck { req, accepted },
                    );
                }
                return;
            }
            let node = &mut self.nodes[node_id.idx()];
            node.pending_steals.remove(&req);
            node.resolved_steals.insert(
                req,
                if granted {
                    SimStealResolution::AckedGrant
                } else {
                    SimStealResolution::AckedDenial
                },
            );
            if granted {
                // Accept the transfer: the victim retires the ledger
                // entry when (a copy of) this ack lands.
                self.send_steal_msg(
                    node_id,
                    victim,
                    FaultClass::Ack,
                    16,
                    SimMsg::TransferAck {
                        req,
                        accepted: true,
                    },
                );
            }
        } else {
            self.nodes[node_id.idx()].pending_steals.remove(&req);
            self.tasks_in_transit -= tasks.len() as u64;
        }
        {
            let tier = self.cfg.topology.tier_of(node_id.idx(), victim.idx());
            let hierarchical = self.cfg.steal_domains == StealDomains::Hierarchical;
            let node = &mut self.nodes[node_id.idx()];
            node.inflight_steals = node.inflight_steals.saturating_sub(1);
            // Per-victim outcome telemetry (always) and, under
            // targeted selection, the selector's decayed history —
            // mirroring the threaded comm loop's reply arm.
            let outcome = classify_reply(granted, denied_by_waiting_time);
            match outcome {
                VictimOutcome::Granted => node.victim_grants[victim.idx()] += 1,
                VictimOutcome::DeniedWaitingTime => node.victim_wt_denials[victim.idx()] += 1,
                VictimOutcome::DeniedEmpty => node.victim_empties[victim.idx()] += 1,
                // Timeouts are recorded at the watchdog, never from a
                // reply in hand.
                VictimOutcome::TimedOut => node.victim_timeouts[victim.idx()] += 1,
            }
            // Hierarchical escalation: a grant snaps back to the near
            // tier, any denial counts toward widening the domain.
            if hierarchical {
                if granted {
                    node.escalation.on_grant();
                } else {
                    node.escalation.on_miss();
                }
            }
            if self.migrate.victim_select == VictimSelect::Targeted {
                node.victim_sel
                    .record(victim.idx(), outcome, digest.as_ref());
            }
            // Merge the victim's estimates BEFORE the stolen tasks enter
            // the queue, so the next gate decision on this node already
            // sees the seeded table.
            if let Some(d) = &digest {
                Self::merge_digest(node, d);
            }
            if !tasks.is_empty() {
                node.steal.successful_steals += 1;
                node.steal.tasks_received += tasks.len() as u64;
                node.tier_steal_grants[tier] += 1;
                node.tier_steal_bytes[tier] += Msg::steal_reply_wire_bytes(
                    tasks.len(),
                    tasks.iter().map(|t| graph.payload_bytes(*t)).sum(),
                    digest.as_ref(),
                );
                for t in &tasks {
                    node.queued_class[t.class.idx()] += 1;
                }
                // Fig. 3 instrumentation: queue length each stolen task
                // would have seen arriving one-by-one (len, len+1, …),
                // sampled before the batch insert.
                if self.cfg.record_polls {
                    let ready = node.queue.len() as u32;
                    for k in 0..tasks.len() as u32 {
                        node.arrival_ready.push(PollSample {
                            t_us: self.now_us,
                            ready: ready + k,
                        });
                    }
                }
                // Recreate the tasks (same uids) at the thief in one
                // batched insert — the DES mirror of the threaded
                // runtime's one-lock-per-reply re-enqueue.
                let batch = TaskMeta::batch_of(graph.as_ref(), &tasks);
                node.queue.insert_batch_at(BatchSite::StealReply, &batch);
            }
        }
        if !tasks.is_empty() {
            self.dispatch(node_id);
        }
        self.ensure_poll(node_id);
    }

    /// Victim side of the handshake: an ack retires the parked ledger
    /// entry; a nack (the thief abandoned the request) sends the tasks
    /// home through the same `GateDenial` batch insert a waiting-time
    /// reversal uses. Unknown request ids (entry already retired by an
    /// earlier ack copy) are idempotent no-ops.
    fn on_transfer_ack(&mut self, victim_id: NodeId, req: u64, accepted: bool) {
        let Some(entry) = self.nodes[victim_id.idx()].ledger.remove(&req) else {
            return;
        };
        if !accepted {
            let graph = self.graph.clone();
            let node = &mut self.nodes[victim_id.idx()];
            node.ledger_reclaims += 1;
            for t in &entry.tasks {
                node.queued_class[t.class.idx()] += 1;
            }
            let batch = TaskMeta::batch_of(graph.as_ref(), &entry.tasks);
            node.queue.insert_batch_at(BatchSite::GateDenial, &batch);
        }
        self.ledger_total -= entry.tasks.len() as u64;
        if !accepted {
            self.dispatch(victim_id);
            self.ensure_poll(victim_id);
        }
    }

    /// Thief side of the watchdog: if the request is still pending the
    /// steal is abandoned — scored as a timeout against the victim, fed
    /// back to the scheduler as a denial-flavored signal, nacked so a
    /// parked grant comes home, and retried (same victim, fresh request
    /// id, doubled deadline) while the budget lasts. The inflight slot
    /// is released only when the retry budget is spent — the leak fix's
    /// accounting discipline.
    fn on_steal_timeout(&mut self, node_id: NodeId, req: u64) {
        let Some(p) = self.nodes[node_id.idx()].pending_steals.remove(&req) else {
            return; // the reply won the race
        };
        {
            let node = &mut self.nodes[node_id.idx()];
            node.resolved_steals
                .insert(req, SimStealResolution::Abandoned);
            node.steal_timeouts += 1;
            node.victim_timeouts[p.victim.idx()] += 1;
            if self.cfg.steal_domains == StealDomains::Hierarchical {
                node.escalation.on_miss();
            }
            if self.migrate.victim_select == VictimSelect::Targeted {
                node.victim_sel
                    .record(p.victim.idx(), VictimOutcome::TimedOut, None);
            }
            node.queue.feedback(StealOutcome::TimedOut);
        }
        let dead_victim = self.dead[p.victim.idx()];
        if !dead_victim {
            // Nack eagerly: if the victim parked a grant whose reply
            // was lost, this sends it home without waiting for its
            // ack-timeout. A dead victim's ledger is swept by the
            // recovery pass instead — no point nacking a corpse.
            self.send_steal_msg(
                node_id,
                p.victim,
                FaultClass::Ack,
                16,
                SimMsg::TransferAck {
                    req,
                    accepted: false,
                },
            );
        }
        if !dead_victim && p.attempt < THIEF_RETRY_BUDGET {
            let tier = self.cfg.topology.tier_of(node_id.idx(), p.victim.idx());
            let new_req = {
                let node = &mut self.nodes[node_id.idx()];
                let new_req = steal_req_id(node_id.0, node.next_req);
                node.next_req += 1;
                node.pending_steals.insert(
                    new_req,
                    SimPendingSteal {
                        victim: p.victim,
                        attempt: p.attempt + 1,
                    },
                );
                node.steal_retries += 1;
                node.steal.requests_sent += 1;
                node.tier_steal_requests[tier] += 1;
                new_req
            };
            self.send_steal_msg(
                node_id,
                p.victim,
                FaultClass::Request,
                16,
                SimMsg::StealRequest {
                    thief: node_id,
                    req: new_req,
                },
            );
            self.arm_steal_timeout(node_id, p.victim, new_req, p.attempt + 1);
        } else {
            // Crashed victim, or the whole retry budget spent without a
            // single reply: quarantine it permanently. This is the fix
            // for the unbounded-stall liveness caveat — an unresponsive
            // victim ends in quarantine, never in an infinite retry
            // (or, victim-side, retransmit) loop.
            self.quarantine(node_id.idx(), p.victim.idx());
            let node = &mut self.nodes[node_id.idx()];
            node.inflight_steals = node.inflight_steals.saturating_sub(1);
            self.ensure_poll(node_id);
        }
    }

    /// Victim side of the watchdog: an unacked ledger entry retransmits
    /// its stored reply verbatim and re-arms with a doubled deadline —
    /// but not forever. Once [`ACK_PROBE_BUDGET`] retransmits are spent,
    /// or immediately when the thief has crashed, the victim settles the
    /// entry from the thief's own resolution book (the one place the
    /// omniscient DES — like the threaded shared-memory fabric — stands
    /// in for a real network's connection-reset signal): an absorbed
    /// grant retires the entry, anything else is marked abandoned at the
    /// thief and the tasks come home through the nack-reclaim path.
    /// This closes the PR 7 liveness caveat — a thief that never acks
    /// (permanent stall window, or a crash) can no longer pin its
    /// victim in an unbounded retransmit loop.
    fn on_ack_timeout(&mut self, victim_id: NodeId, req: u64) {
        let (thief, attempt, settle) = {
            let Some(e) = self.nodes[victim_id.idx()].ledger.get(&req) else {
                return; // acked (or reclaimed) in the meantime
            };
            let settle = self.dead[e.thief.idx()] || e.attempt >= ACK_PROBE_BUDGET;
            (e.thief, e.attempt, settle)
        };
        if settle {
            let resolved = self.nodes[thief.idx()].resolved_steals.get(&req);
            let absorbed = matches!(resolved, Some(SimStealResolution::AckedGrant));
            let Some(entry) = self.nodes[victim_id.idx()].ledger.remove(&req) else {
                return;
            };
            self.ledger_total -= entry.tasks.len() as u64;
            if absorbed {
                // The thief enqueued the tasks; only its ack was lost.
                return;
            }
            {
                // Abandon the request at the thief so a late reply copy
                // or its own watchdog cannot resurrect it, and release
                // the inflight slot its retry loop was holding.
                let tnode = &mut self.nodes[thief.idx()];
                if tnode.pending_steals.remove(&req).is_some() {
                    tnode.inflight_steals = tnode.inflight_steals.saturating_sub(1);
                }
                tnode
                    .resolved_steals
                    .insert(req, SimStealResolution::Abandoned);
            }
            let graph = self.graph.clone();
            {
                let node = &mut self.nodes[victim_id.idx()];
                node.ledger_reclaims += 1;
                let batch = TaskMeta::batch_of(graph.as_ref(), &entry.tasks);
                node.queue.insert_batch_at(BatchSite::GateDenial, &batch);
            }
            self.dispatch(victim_id);
            self.ensure_poll(victim_id);
            return;
        }
        let (reply, bytes) = {
            let Some(e) = self.nodes[victim_id.idx()].ledger.get_mut(&req) else {
                return;
            };
            e.attempt += 1;
            (e.reply.clone(), e.reply_bytes)
        };
        self.send_steal_msg(victim_id, thief, FaultClass::Reply, bytes, reply);
        self.arm_ack_timeout(victim_id, thief, req, attempt + 1);
    }

    /// The crash instant: the node falls silent. Its queued events are
    /// discarded as they pop and its unfinished work stays frozen in
    /// place until the recovery sweep one detection latency later — the
    /// threaded leader's heartbeat threshold, reused verbatim so both
    /// runtimes model the same detection delay.
    fn on_crash(&mut self, node_id: NodeId) {
        if self.dead[node_id.idx()] {
            return;
        }
        self.dead[node_id.idx()] = true;
        self.recovery.nodes_crashed += 1;
        // Suspicion must outlast a steal round trip to *any* victim, so
        // the detector keys off the topology's slowest pairwise link
        // (the base link verbatim when flat).
        let worst = self.cfg.topology.worst_link(self.nodes.len(), self.cfg.link);
        let detect = suspicion_timeout_us(
            worst.latency_us,
            worst.bw_bytes_per_us,
            self.migrate.migrate_overhead_us,
            self.migrate.poll_interval_us,
        );
        self.recovery.detect_latency_us = detect;
        self.push_event(self.now_us + detect, EventKind::Recover { node: node_id });
    }

    /// Detection + ring repair + lineage recovery, compressed into one
    /// deterministic sweep (the DES is omniscient; the threaded runtime
    /// spreads the same steps across the heartbeat detector, the Safra
    /// splice and the leader's re-injection loop):
    ///
    /// 1. quarantine the dead node at every live selector (membership);
    /// 2. re-home its ready queue, executing set and unabsorbed
    ///    transfer-ledger grants onto the rehash survivor;
    /// 3. reclaim grants parked *for* the dead thief at live victims;
    /// 4. replay its partial activation state and the orphaned in-flight
    ///    activations at the survivor's tracker.
    fn on_recover(&mut self, node_id: NodeId) {
        let d = node_id.idx();
        debug_assert!(self.dead[d] && !self.swept[d]);
        self.swept[d] = true;
        self.recovery.nodes_suspected += 1;
        self.recovery.ring_repairs += 1;
        let target = self.route(node_id);
        if target == node_id {
            return; // no live survivor (unreachable: node 0 never crashes)
        }
        let graph = self.graph.clone();
        for i in 0..self.nodes.len() {
            if i != d && !self.dead[i] {
                self.quarantine(i, d);
            }
        }
        // Ready queue first (dependencies already satisfied: direct
        // re-enqueue, no tracker replay), then the executing set —
        // sorted, HashSet iteration order is not deterministic.
        let mut ready = self.nodes[d].queue.drain();
        let mut executing: Vec<TaskDesc> = self.nodes[d].executing.drain().collect();
        executing.sort_unstable();
        ready.extend(executing);
        self.nodes[d].queued_class = [0; TaskClass::COUNT];
        self.nodes[d].executing_local_succ = 0;
        self.nodes[d].idle_workers = self.cfg.workers_per_node;
        // The dead victim's transfer ledger: a grant its thief provably
        // absorbed is settled (the tasks run over there); anything else
        // exists only here and is re-homed with the queue.
        let mut reqs: Vec<u64> = self.nodes[d].ledger.keys().copied().collect();
        reqs.sort_unstable();
        for req in reqs {
            let Some(entry) = self.nodes[d].ledger.remove(&req) else {
                continue;
            };
            self.ledger_total -= entry.tasks.len() as u64;
            let resolved = self.nodes[entry.thief.idx()].resolved_steals.get(&req);
            let absorbed = matches!(resolved, Some(SimStealResolution::AckedGrant));
            if !absorbed {
                ready.extend(entry.tasks);
            }
        }
        // Grants parked at live victims for the dead thief: absorbed
        // ones were already recovered with the dead queue above; the
        // rest come home through the nack-reclaim path.
        for i in 0..self.nodes.len() {
            if i == d || self.dead[i] {
                continue;
            }
            let mut reqs: Vec<u64> = self.nodes[i]
                .ledger
                .iter()
                .filter(|(_, e)| e.thief == node_id)
                .map(|(r, _)| *r)
                .collect();
            reqs.sort_unstable();
            let mut reclaimed = false;
            for req in reqs {
                let Some(entry) = self.nodes[i].ledger.remove(&req) else {
                    continue;
                };
                self.ledger_total -= entry.tasks.len() as u64;
                let resolved = self.nodes[d].resolved_steals.get(&req);
                let absorbed = matches!(resolved, Some(SimStealResolution::AckedGrant));
                if !absorbed {
                    let node = &mut self.nodes[i];
                    node.ledger_reclaims += 1;
                    for t in &entry.tasks {
                        node.queued_class[t.class.idx()] += 1;
                    }
                    let batch = TaskMeta::batch_of(graph.as_ref(), &entry.tasks);
                    node.queue.insert_batch_at(BatchSite::GateDenial, &batch);
                    reclaimed = true;
                }
            }
            if reclaimed {
                self.dispatch(NodeId(i as u32));
                self.ensure_poll(NodeId(i as u32));
            }
        }
        // The dead thief's own outstanding requests: live victims settle
        // them from its resolution book (the probe path), so the slots
        // are simply released; its watchdog events die at the pop.
        self.nodes[d].pending_steals.clear();
        self.nodes[d].inflight_steals = 0;
        if !ready.is_empty() {
            let batch = TaskMeta::batch_of(graph.as_ref(), &ready);
            let node = &mut self.nodes[target.idx()];
            for t in &ready {
                node.queued_class[t.class.idx()] += 1;
            }
            node.queue.insert_batch_at(BatchSite::Other, &batch);
        }
        // Partial activation state replays as `satisfied` activations at
        // the survivor's tracker (its lazy in-degree init reproduces the
        // dead tracker's counts exactly); the remaining edges arrive
        // there later through post-recovery re-routing.
        let partial = self.nodes[d].tracker.drain_partial(graph.as_ref());
        // `tasks_recovered` counts every task the sweep re-homed: ready
        // and executing work re-enqueued directly, unabsorbed ledger
        // grants, and partially-activated tasks whose lineage replays.
        self.recovery.tasks_recovered += (ready.len() + partial.len()) as u64;
        for (task, satisfied) in partial {
            for _ in 0..satisfied {
                self.activate_at(target, task);
            }
        }
        // Activations that were in flight to the dead node land last.
        let orphans = std::mem::take(&mut self.orphans);
        if !orphans.is_empty() {
            self.activate_batch_at(target, &orphans);
        }
        self.dispatch(target);
        self.ensure_poll(target);
    }

    /// Run to completion and produce the report.
    pub fn run(mut self) -> RunReport {
        // Arm the crash schedule, if any: one event, zero when the plan
        // has no crash spec — default-off heaps are byte-identical.
        if let Some((node, at_us)) = self.crash {
            self.push_event(at_us, EventKind::Crash { node: NodeId(node) });
        }
        // Seed roots.
        for root in self.graph.roots() {
            let owner = self.graph.owner(root);
            let meta = TaskMeta::of(self.graph.as_ref(), root);
            let node = &mut self.nodes[owner.idx()];
            node.tracker.mark_root(root);
            node.queued_class[root.class.idx()] += 1;
            node.queue.insert_meta(root, self.graph.priority(root), meta);
        }
        let node_count = self.nodes.len();
        for i in 0..node_count {
            self.dispatch(NodeId(i as u32));
            self.ensure_poll(NodeId(i as u32));
        }

        let mut makespan = 0.0f64;
        while let Some(ev) = self.heap.pop() {
            self.now_us = ev.t_us;
            self.events_processed += 1;
            if self.events_processed > self.cfg.max_events {
                panic!(
                    "simulator exceeded max_events={} (runaway?)",
                    self.cfg.max_events
                );
            }
            // A dead node's own events die at the pop: it finishes
            // nothing, polls nothing, and its watchdogs are settled by
            // the recovery sweep and the survivors' probe paths.
            let owner = match &ev.kind {
                EventKind::Finish { node, .. }
                | EventKind::Poll { node }
                | EventKind::StealTimeout { node, .. }
                | EventKind::AckTimeout { node, .. } => Some(*node),
                _ => None,
            };
            if let Some(owner) = owner {
                if self.dead[owner.idx()] {
                    continue;
                }
            }
            match ev.kind {
                EventKind::Finish {
                    node,
                    task,
                    started_us,
                } => {
                    makespan = makespan.max(self.now_us);
                    self.on_finish(node, task, started_us);
                }
                EventKind::Deliver { dst, msg } => {
                    self.deliver_events += 1;
                    if self.dead[dst.idx()] {
                        match msg {
                            // Activations survive the crash: orphaned
                            // into the graveyard inside the detection
                            // window, re-routed to the rehash survivor
                            // after the sweep.
                            SimMsg::Activate(t) => {
                                self.activate_in_flight -= 1;
                                if self.swept[dst.idx()] {
                                    let target = self.route(dst);
                                    self.activate_at(target, t);
                                } else {
                                    self.orphans.push(t);
                                }
                            }
                            SimMsg::ActivateBatch(tasks) => {
                                self.activate_in_flight -= 1;
                                if self.swept[dst.idx()] {
                                    let target = self.route(dst);
                                    self.activate_batch_at(target, &tasks);
                                } else {
                                    self.orphans.extend(tasks);
                                }
                            }
                            // Steal traffic to the dead is dropped:
                            // requests go unanswered (the thief's
                            // watchdog quarantines), a reply's grant
                            // stays parked in the sender's ledger (the
                            // probe path settles it), and acks target a
                            // ledger the sweep already emptied.
                            SimMsg::StealRequest { .. }
                            | SimMsg::StealReply { .. }
                            | SimMsg::TransferAck { .. } => {}
                        }
                        continue;
                    }
                    match msg {
                        SimMsg::Activate(t) => {
                            self.activate_in_flight -= 1;
                            self.activate_at(dst, t)
                        }
                        SimMsg::ActivateBatch(tasks) => {
                            self.activate_in_flight -= 1;
                            self.activate_batch_at(dst, &tasks);
                        }
                        SimMsg::StealRequest { thief, req } => {
                            self.on_steal_request(dst, thief, req)
                        }
                        SimMsg::StealReply {
                            req,
                            victim,
                            tasks,
                            digest,
                            denied_by_waiting_time,
                        } => self.on_steal_reply(
                            dst,
                            req,
                            victim,
                            tasks,
                            digest,
                            denied_by_waiting_time,
                        ),
                        SimMsg::TransferAck { req, accepted } => {
                            self.on_transfer_ack(dst, req, accepted)
                        }
                    }
                }
                EventKind::Poll { node } => self.on_poll(node),
                EventKind::StealTimeout { node, req } => self.on_steal_timeout(node, req),
                EventKind::AckTimeout { node, req } => self.on_ack_timeout(node, req),
                EventKind::Crash { node } => self.on_crash(node),
                EventKind::Recover { node } => self.on_recover(node),
            }
        }

        let executed: u64 = self.nodes.iter().map(|n| n.tasks_done).sum();
        if let Some(total) = self.graph.total_tasks() {
            assert_eq!(
                executed, total,
                "simulator finished without executing every task"
            );
        }
        for (ix, node) in self.nodes.iter().enumerate() {
            assert!(node.queue.is_empty(), "ready task left behind");
            assert!(node.executing.is_empty());
            assert!(node.tracker.is_quiescent(), "activation left behind");
            // The self-healing protocol's conservation laws: every
            // request was answered or timed out (so every inflight slot
            // was reclaimed — the leak fix), and every granted transfer
            // was acked or sent home (zero ledger residue).
            assert!(
                node.pending_steals.is_empty(),
                "node {ix}: steal request neither answered nor timed out"
            );
            assert_eq!(
                node.inflight_steals, 0,
                "node {ix}: leaked inflight-steal slots"
            );
            assert!(node.ledger.is_empty(), "node {ix}: transfer-ledger residue");
        }
        assert_eq!(self.ledger_total, 0, "transfer-ledger accounting residue");
        assert!(self.orphans.is_empty(), "orphaned activations never re-homed");

        RunReport {
            workload: self.graph.name().to_string(),
            makespan_us: makespan,
            total_tasks: executed,
            workers_per_node: self.cfg.workers_per_node,
            link: self.cfg.link,
            events: self.events_processed,
            deliver_events: self.deliver_events,
            faults_dropped: self.faults_dropped,
            faults_duplicated: self.faults_duplicated,
            recovery: self.recovery,
            nodes: self
                .nodes
                .into_iter()
                .map(|n| NodeReport {
                    tasks_executed: n.tasks_done,
                    busy_us: n.busy_us,
                    avg_exec_us: if n.tasks_done > 0 {
                        n.exec_sum_us / n.tasks_done as f64
                    } else {
                        0.0
                    },
                    class_est_us: n.class_est_us,
                    digest_merges: n.digest_merges,
                    digest_class_adoptions: n.digest_class_adoptions,
                    activation_ready_batches: n.activation_ready_batches,
                    steal: n.steal,
                    victim_grants: n.victim_grants,
                    victim_wt_denials: n.victim_wt_denials,
                    victim_empties: n.victim_empties,
                    victim_timeouts: n.victim_timeouts,
                    victim_quarantined: n.victim_quarantined,
                    tier_steal_requests: n.tier_steal_requests,
                    tier_steal_grants: n.tier_steal_grants,
                    tier_steal_bytes: n.tier_steal_bytes,
                    steal_timeouts: n.steal_timeouts,
                    steal_retries: n.steal_retries,
                    ledger_reclaims: n.ledger_reclaims,
                    dup_replies_suppressed: n.dup_replies_suppressed,
                    sched: n.queue.stats(),
                    polls: n.polls,
                    arrival_ready: n.arrival_ready,
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

    fn chol(tiles: u32, nodes: u32) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size: 20,
            nodes,
            dense_fraction: 0.5,
            seed: 3,
            all_dense: false,
        }))
    }

    fn sim(
        graph: Arc<dyn TaskGraph>,
        migrate: MigrateConfig,
        seed: u64,
        workers: usize,
    ) -> RunReport {
        sim_with(graph, migrate, seed, workers, SchedBackend::Central)
    }

    fn sim_with(
        graph: Arc<dyn TaskGraph>,
        migrate: MigrateConfig,
        seed: u64,
        workers: usize,
        sched: SchedBackend,
    ) -> RunReport {
        Simulator::new(
            graph,
            SimConfig::default()
                .with_workers_per_node(workers)
                .with_seed(seed)
                .with_max_events(50_000_000)
                .with_sched(sched),
            CostModel::default_calibrated(),
            migrate,
            20,
        )
        .run()
    }

    #[test]
    fn cholesky_completes_without_stealing() {
        let g = chol(10, 3);
        let total = g.total_tasks().unwrap();
        let r = sim(g, MigrateConfig::disabled(), 1, 4);
        assert_eq!(r.tasks_total_executed(), total);
        assert!(r.makespan_us > 0.0);
        assert_eq!(r.total_steals().requests_sent, 0);
    }

    #[test]
    fn cholesky_completes_with_stealing() {
        let g = chol(12, 4);
        let total = g.total_tasks().unwrap();
        let r = sim(g, MigrateConfig::default(), 2, 4);
        assert_eq!(r.tasks_total_executed(), total);
        let s = r.total_steals();
        assert!(s.requests_sent > 0, "imbalanced run should attempt steals");
    }

    #[test]
    fn stealing_preserves_task_count_across_policies() {
        use crate::migrate::{ThiefPolicy, VictimPolicy};
        let total = chol(10, 4).total_tasks().unwrap();
        for victim in [VictimPolicy::Half, VictimPolicy::Chunk(20), VictimPolicy::Single] {
            for thief in [ThiefPolicy::ReadyOnly, ThiefPolicy::ReadySuccessors] {
                for gate in [false, true] {
                    let mc = MigrateConfig::default()
                        .with_thief(thief)
                        .with_victim(victim)
                        .with_use_waiting_time(gate)
                        .with_poll_interval_us(50.0)
                        .with_exec_ewma(gate)
                        .with_exec_per_class(gate)
                        .with_share_estimates(gate);
                    let r = sim(chol(10, 4), mc, 7, 2);
                    assert_eq!(
                        r.tasks_total_executed(),
                        total,
                        "policy {victim:?}/{thief:?}/gate={gate}"
                    );
                }
            }
        }
    }

    #[test]
    fn uts_completes_and_steals() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 32,
            m: 4,
            q: 0.3,
            g: 50_000.0, // 50 µs/task: long enough for steals to land
            seed: 5,
            nodes: 4,
            max_depth: 24,
        }));
        let size = g.tree_size(10_000_000);
        let mc = MigrateConfig::default().with_poll_interval_us(20.0);
        let r = sim(g, mc, 3, 4);
        assert_eq!(r.tasks_total_executed(), size);
        // Everything starts at node 0: stealing is the only way any other
        // node gets work.
        let spread: u64 = r.nodes[1..].iter().map(|n| n.tasks_executed).sum();
        assert!(spread > 0, "stealing spread work: {:?}",
            r.nodes.iter().map(|n| n.tasks_executed).collect::<Vec<_>>());
        assert!(r.total_steals().successful_steals > 0);
    }

    #[test]
    fn uts_without_stealing_stays_on_node0() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 16,
            m: 3,
            q: 0.2,
            g: 500.0,
            seed: 6,
            nodes: 3,
            max_depth: 16,
        }));
        let size = g.tree_size(10_000_000);
        let r = sim(g, MigrateConfig::disabled(), 4, 4);
        assert_eq!(r.nodes[0].tasks_executed, size);
        assert_eq!(r.nodes[1].tasks_executed, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        for sched in SchedBackend::ALL {
            let a = sim_with(chol(8, 3), MigrateConfig::default(), 42, 4, sched);
            let b = sim_with(chol(8, 3), MigrateConfig::default(), 42, 4, sched);
            assert_eq!(a.makespan_us, b.makespan_us, "{sched:?}");
            assert_eq!(a.events, b.events, "{sched:?}");
            assert_eq!(
                a.total_steals().successful_steals,
                b.total_steals().successful_steals,
                "{sched:?}"
            );
        }
    }

    /// The sharded backend completes every workload the central one does
    /// — same task totals, full quiescence at exit.
    #[test]
    fn sharded_backend_completes_cholesky_and_uts() {
        let g = chol(10, 3);
        let total = g.total_tasks().unwrap();
        let r = sim_with(g, MigrateConfig::default(), 2, 4, SchedBackend::Sharded);
        assert_eq!(r.tasks_total_executed(), total);

        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 20_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let mc = MigrateConfig::default().with_poll_interval_us(20.0);
        let r = sim_with(g, mc, 3, 4, SchedBackend::Sharded);
        assert_eq!(r.tasks_total_executed(), size);
        assert!(r.total_steals().successful_steals > 0);
    }

    #[test]
    fn seed_changes_outcome() {
        let a = sim(chol(8, 3), MigrateConfig::default(), 1, 4);
        let b = sim(chol(8, 3), MigrateConfig::default(), 2, 4);
        // noise differs -> makespans differ (astronomically unlikely tie)
        assert_ne!(a.makespan_us, b.makespan_us);
    }

    #[test]
    fn single_node_never_steals() {
        let g = chol(8, 1);
        let r = sim(g, MigrateConfig::default(), 9, 4);
        assert_eq!(r.total_steals().requests_sent, 0);
    }

    /// The closed loop end to end in the DES: an all-on-node-0 UTS run
    /// whose migrate overhead makes every steal lose the waiting-time
    /// comparison must (a) deny heavily, (b) raise node 0's sharded
    /// spill watermark through the feedback hook, and (c) still record
    /// the denials on the central backend.
    #[test]
    fn denial_heavy_run_raises_sharded_watermark() {
        let mk_graph = || {
            Arc::new(UtsGraph::new(UtsParams {
                b0: 32,
                m: 4,
                q: 0.3,
                g: 50_000.0,
                seed: 5,
                nodes: 4,
                max_depth: 24,
            }))
        };
        let mc = MigrateConfig::default()
            .with_poll_interval_us(20.0)
            .with_migrate_overhead_us(1e9); // migration always loses the gate
        for sched in SchedBackend::ALL {
            let g = mk_graph();
            let size = g.tree_size(10_000_000);
            let r = sim_with(g, mc, 3, 4, sched);
            assert_eq!(r.tasks_total_executed(), size, "{sched:?}");
            let steals = r.total_steals();
            assert!(
                steals.waiting_time_denials > 10,
                "{sched:?}: wanted a denial-heavy run, got {steals:?}"
            );
            assert_eq!(steals.successful_steals, 0, "{sched:?}: gate denies all");
            // Node 0 is the only victim with work; its queue heard
            // every denial through the feedback hook.
            let fed: u64 = r.nodes.iter().map(|n| n.sched.feedback_wt_denials).sum();
            assert!(fed > 10, "{sched:?}: denials fed back ({fed})");
            match sched {
                SchedBackend::Sharded => {
                    assert!(
                        r.nodes[0].sched.watermark > crate::sched::SPILL_THRESHOLD as u64,
                        "denials must raise the watermark, got {}",
                        r.nodes[0].sched.watermark
                    );
                    // Every denial is certain from the O(1) accounting
                    // (overhead floor), so extraction never runs and
                    // never hits the all-shards fallback walk.
                    let walks: u64 = r.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
                    assert_eq!(walks, 0, "certain denials must skip extraction");
                }
                SchedBackend::Central => {
                    assert_eq!(r.nodes[0].sched.watermark, 0, "central has no watermark")
                }
                SchedBackend::Workassist => {
                    // No watermark, no mutex: the lock-free backend's
                    // denial-heavy run must stay lock-free end to end.
                    assert_eq!(r.nodes[0].sched.watermark, 0, "workassist has no watermark");
                    let locks: u64 = r.nodes.iter().map(|n| n.sched.lock_acquisitions).sum();
                    assert_eq!(locks, 0, "workassist must never take a lock");
                }
            }
        }
    }

    /// The thief-side re-enqueue is exactly one batched insert per
    /// non-empty steal reply: with the gate off nothing else batches,
    /// so Σ batch_inserts == Σ successful_steals, and the lock saving
    /// is Σ (tasks_received − replies).
    #[test]
    fn steal_reply_reenqueue_is_one_batch_per_reply() {
        for sched in SchedBackend::ALL {
            let g = Arc::new(UtsGraph::new(UtsParams {
                b0: 32,
                m: 4,
                q: 0.3,
                g: 50_000.0,
                seed: 5,
                nodes: 4,
                max_depth: 24,
            }));
            let mc = MigrateConfig::default()
                .with_poll_interval_us(20.0)
                .with_use_waiting_time(false) // no denial reinserts
                .with_victim(crate::migrate::VictimPolicy::Chunk(4));
            let r = sim_with(g, mc, 3, 4, sched);
            let steals = r.total_steals();
            assert!(steals.successful_steals > 0, "{sched:?}");
            // Per-call-site accounting keeps this exact even though the
            // activation path batches on the same queues.
            let reply: Vec<_> = r
                .nodes
                .iter()
                .map(|n| n.sched.site(BatchSite::StealReply))
                .collect();
            let batches: u64 = reply.iter().map(|b| b.batches).sum();
            let saved: u64 = reply.iter().map(|b| b.saved_locks()).sum();
            assert_eq!(
                batches, steals.successful_steals,
                "{sched:?}: exactly one batched insert per non-empty reply"
            );
            assert_eq!(
                saved,
                steals.tasks_received - steals.successful_steals,
                "{sched:?}: lock saving = tasks − replies"
            );
        }
    }

    /// The batch-first activation pipeline in the DES: per node, the
    /// number of non-empty ready sets delivered through the batched
    /// path equals the scheduler's activation-site batch counter —
    /// exactly one batched insert per ready set — and the per-edge
    /// ablation books nothing there.
    #[test]
    fn activation_ready_sets_batch_exactly_once() {
        for sched in SchedBackend::ALL {
            let run = |batch: bool| {
                Simulator::new(
                    chol(10, 3),
                    SimConfig::default()
                        .with_workers_per_node(4)
                        .with_seed(9)
                        .with_max_events(50_000_000)
                        .with_record_polls(false)
                        .with_sched(sched)
                        .with_batch_activations(batch),
                    CostModel::default_calibrated(),
                    MigrateConfig::disabled(),
                    20,
                )
                .run()
            };
            let r = run(true);
            let mut ready_sets = 0;
            for (ix, n) in r.nodes.iter().enumerate() {
                assert_eq!(
                    n.sched.site(BatchSite::Activation).batches,
                    n.activation_ready_batches,
                    "{sched:?} node {ix}: one batched insert per ready set"
                );
                ready_sets += n.activation_ready_batches;
            }
            assert!(ready_sets > 0, "{sched:?}: Cholesky fan-out must batch");
            let unbatched = run(false);
            for n in &unbatched.nodes {
                assert_eq!(n.sched.site(BatchSite::Activation).batches, 0, "{sched:?}");
                assert_eq!(n.activation_ready_batches, 0, "{sched:?}");
            }
        }
    }

    /// `--exec-per-class` on a mixed Cholesky: the per-class estimator
    /// table ends the run with genuinely different estimates for POTRF
    /// and GEMM (Table 1's orders-of-magnitude spread), the very signal
    /// the node-wide mean erases, while completion and per-backend
    /// determinism hold.
    #[test]
    fn exec_per_class_estimates_differ_by_class() {
        for sched in SchedBackend::ALL {
            let g = chol(12, 8);
            let total = g.total_tasks().unwrap();
            let mc = MigrateConfig::default().with_exec_per_class(true);
            let a = sim_with(g, mc, 11, 4, sched);
            assert_eq!(a.tasks_total_executed(), total, "{sched:?}");
            let est = a.class_est_us_max();
            let potrf = est[TaskClass::Potrf.idx()];
            let gemm = est[TaskClass::Gemm.idx()];
            assert!(potrf > 0.0 && gemm > 0.0, "{sched:?}: both classes ran");
            assert!(
                (potrf - gemm).abs() > 0.1 * potrf.max(gemm),
                "{sched:?}: per-class estimates must differ (POTRF {potrf} vs GEMM {gemm})"
            );
            let b = sim_with(chol(12, 8), mc, 11, 4, sched);
            assert_eq!(a.makespan_us, b.makespan_us, "{sched:?}: deterministic");
        }
    }

    /// `--exec-ewma` changes only the gate's execution-time estimate:
    /// every task still executes exactly once on both backends, and the
    /// run remains deterministic given the seed.
    #[test]
    fn exec_ewma_gate_preserves_completion_and_determinism() {
        for sched in SchedBackend::ALL {
            let g = chol(10, 3);
            let total = g.total_tasks().unwrap();
            let mc = MigrateConfig::default().with_exec_ewma(true);
            let a = sim_with(g.clone(), mc, 11, 4, sched);
            assert_eq!(a.tasks_total_executed(), total, "{sched:?}");
            let b = sim_with(chol(10, 3), mc, 11, 4, sched);
            assert_eq!(a.makespan_us, b.makespan_us, "{sched:?}: deterministic");
        }
    }

    /// The acceptance scenario for the payload-certain fast path: an
    /// all-on-node-0 UTS run over a link so slow that even the 64-byte
    /// UTS descriptor loses the waiting-time comparison, while the
    /// overhead floor alone (≈ 2µs) never proves anything. Every denial
    /// is payload-driven — exactly the regime where the PR 3 gate
    /// extracted-and-reinserted on every poll and sustained denial paid
    /// the sharded all-shards fallback walk — and the run now completes
    /// with zero extractions and zero fallback walks.
    #[test]
    fn payload_certain_denials_never_extract() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 32,
            m: 4,
            q: 0.3,
            g: 50_000.0,
            seed: 5,
            nodes: 4,
            max_depth: 24,
        }));
        let size = g.tree_size(10_000_000);
        let mc = MigrateConfig::default()
            .with_poll_interval_us(20.0)
            .with_migrate_overhead_us(1.0); // overhead floor alone is never certain
        let r = Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(4)
                // 1e-5 B/µs: the 64 B descriptor alone costs 6.4 s on
                // the wire — beyond any waiting time this run reaches.
                .with_link(LinkModel {
                    latency_us: 1.0,
                    bw_bytes_per_us: 1e-5,
                })
                .with_seed(3)
                .with_max_events(50_000_000)
                .with_record_polls(false)
                .with_sched(SchedBackend::Sharded),
            CostModel::default_calibrated(),
            mc,
            0,
        )
        .run();
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(
            steals.waiting_time_denials > 10,
            "wanted payload-driven denials, got {steals:?}"
        );
        assert_eq!(steals.successful_steals, 0);
        let extracted: u64 = r.nodes.iter().map(|n| n.sched.steal_extracted).sum();
        assert_eq!(extracted, 0, "payload-certain denials never extract");
        let walks: u64 = r.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
        assert_eq!(walks, 0, "and never pay the sharded fallback walk");
        let resets: u64 = r.nodes.iter().map(|n| n.sched.min_payload_resets).sum();
        assert_eq!(resets, 0, "the exact min-payload multiset never resets");
    }

    /// The estimate-sharing acceptance scenario, end to end in the DES:
    /// a cold thief's first *post-steal* gate decision runs on the
    /// victim-derived class estimate.
    ///
    /// Node 0 warms up on two non-stealable GEMMs (seeding its per-class
    /// table), then exposes four heavy stealable GEMMs. Node 1 — which
    /// has executed nothing — steals them, and node 0 starves and asks
    /// for work back while node 1 is still executing its first stolen
    /// task (zero completions: a genuinely cold victim). At that gate:
    ///
    /// * with `--share-estimates` the digest that rode the reply has
    ///   seeded node 1's GEMM entry with the victim's measured ≈750 µs,
    ///   so the expected wait (3 queued × 750 µs) dwarfs the migration
    ///   cost and node 1 **grants** — it never waiting-time-denies all
    ///   run long;
    /// * without it, node 1's table is empty and the per-class formula
    ///   falls back to the cold node-wide 1 µs: the expected wait is a
    ///   few µs, the payload floor alone wins, and node 1 **denies** —
    ///   the gap this PR closes.
    #[test]
    fn cold_thief_post_steal_gate_uses_victim_estimate() {
        use crate::dataflow::ttg::TtgBuilder;
        let mk_graph = || {
            Arc::new(
                TtgBuilder::new("estimate-sharing", 2)
                    .with_roots(vec![TaskDesc::indexed(TaskClass::Synthetic, 0, 0, 0)])
                    .wrap_g(
                        "chain-then-fan",
                        |t| t.i >= 3, // only the heavy fan is stealable
                        |t| match t.i {
                            // root -> warm-up GEMM 1 -> (warm-up GEMM 2
                            // + the stealable fan 3..=6)
                            0 => vec![TaskDesc::indexed(TaskClass::Gemm, 1, 0, 0)],
                            1 => (2..=6)
                                .map(|i| TaskDesc::indexed(TaskClass::Gemm, i, 0, 0))
                                .collect(),
                            _ => vec![],
                        },
                        |t| u32::from(t.i > 0),
                        |_| NodeId(0),
                        |_| 1.0,
                    )
                    .with_priority(|t| i64::from(t.i < 3)) // warm-ups first
                    .with_payload(|t| if t.i >= 3 { 100_000 } else { 0 })
                    .with_total_tasks(7)
                    .build(),
            )
        };
        // Noise-free costs so the schedule is analyzable: the root is
        // 1 µs (Synthetic = work units), each GEMM ≈ 754 µs (tile 150).
        let cost = CostModel {
            noise_sigma: 0.0,
            node_sigma: 0.0,
            ..CostModel::default_calibrated()
        };
        let run = |share: bool| {
            let mc = MigrateConfig::default()
                .with_poll_interval_us(5.0)
                .with_victim(crate::migrate::VictimPolicy::Chunk(4))
                .with_exec_per_class(true)
                .with_share_estimates(share);
            Simulator::new(
                mk_graph(),
                SimConfig::default()
                    .with_workers_per_node(1)
                    .with_link(LinkModel {
                        latency_us: 1.0,
                        bw_bytes_per_us: 1000.0,
                    })
                    .with_seed(3)
                    .with_max_events(10_000_000)
                    .with_record_polls(false),
                cost.clone(),
                mc,
                150,
            )
            .run()
        };
        let shared = run(true);
        assert_eq!(shared.tasks_total_executed(), 7);
        assert!(
            shared.nodes[1].digest_merges >= 1,
            "the granted reply must carry a digest"
        );
        assert!(
            shared.nodes[1].digest_class_adoptions >= 1,
            "node 1 was cold: the GEMM entry must be an adoption"
        );
        assert_eq!(
            shared.nodes[1].steal.waiting_time_denials, 0,
            "gating on the victim-derived ≈750 µs GEMM estimate, node 1 \
             never denies node 0's steal-back"
        );
        assert!(
            shared.nodes[1].steal.tasks_migrated > 0,
            "…and grants it: stolen work flows back to the starving owner"
        );

        let unshared = run(false);
        assert_eq!(unshared.tasks_total_executed(), 7);
        assert_eq!(unshared.nodes[1].digest_merges, 0, "no digest without the flag");
        assert!(
            unshared.nodes[1].steal.waiting_time_denials > 0,
            "cold node 1 gates on the 1 µs fallback and wrongly denies \
             the same request"
        );
    }

    /// `--victim-select targeted` in the DES: completion, determinism,
    /// and exact per-victim reply accounting. The DES heap drains
    /// fully, so — unlike the threaded runtime, where `max_inflight`
    /// requests can be unanswered at shutdown — every request has a
    /// recorded outcome: per node, grants + denials + empties equals
    /// requests sent, and grants alone equal successful steals.
    /// Uniform mode records the same telemetry.
    #[test]
    fn targeted_selection_completes_deterministic_and_accounts() {
        let mk_graph = || {
            Arc::new(UtsGraph::new(UtsParams {
                b0: 32,
                m: 4,
                q: 0.3,
                g: 50_000.0,
                seed: 5,
                nodes: 4,
                max_depth: 24,
            }))
        };
        for select in [VictimSelect::Uniform, VictimSelect::Targeted] {
            let mc = MigrateConfig::default()
                .with_poll_interval_us(20.0)
                .with_share_estimates(true)
                .with_victim_select(select);
            let g = mk_graph();
            let size = g.tree_size(10_000_000);
            let a = sim(g, mc, 3, 4);
            assert_eq!(a.tasks_total_executed(), size, "{select:?}");
            assert!(a.total_steals().successful_steals > 0, "{select:?}");
            for (ix, n) in a.nodes.iter().enumerate() {
                let grants: u64 = n.victim_grants.iter().sum();
                assert_eq!(
                    grants, n.steal.successful_steals,
                    "{select:?} node {ix}: grants mirror successful steals"
                );
                let replies: u64 = grants
                    + n.victim_wt_denials.iter().sum::<u64>()
                    + n.victim_empties.iter().sum::<u64>();
                assert_eq!(
                    replies, n.steal.requests_sent,
                    "{select:?} node {ix}: the heap drains every reply"
                );
                assert_eq!(
                    n.victim_grants[ix] + n.victim_wt_denials[ix] + n.victim_empties[ix],
                    0,
                    "{select:?} node {ix}: never robs itself"
                );
            }
            let b = sim(mk_graph(), mc, 3, 4);
            assert_eq!(a.makespan_us, b.makespan_us, "{select:?}: deterministic");
            assert_eq!(a.events, b.events, "{select:?}");
            assert_eq!(
                a.total_steals().successful_steals,
                b.total_steals().successful_steals,
                "{select:?}"
            );
        }
    }

    /// Default-off really is paper-faithful: a uniform-mode run built
    /// by a binary that *contains* the targeted machinery must be
    /// bit-identical to the PR 5 behavior — in particular the victim
    /// sequence, and therefore makespan, events and steal counts, must
    /// not shift by a single shared-stream RNG draw.
    #[test]
    fn uniform_mode_matches_explicit_default() {
        let a = sim(chol(10, 4), MigrateConfig::default(), 7, 2);
        let explicit = MigrateConfig::default().with_victim_select(VictimSelect::Uniform);
        let b = sim(chol(10, 4), explicit, 7, 2);
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.deliver_events, b.deliver_events);
        assert_eq!(
            a.total_steals().successful_steals,
            b.total_steals().successful_steals
        );
    }

    /// The master-switch contract: a *disabled* plan that nonetheless
    /// carries aggressive probabilities must be byte-identical to the
    /// default — `enabled: false` means no draws, no timeout events, no
    /// handshake messages, no ledger, no divergence of any kind.
    #[test]
    fn disabled_fault_plan_is_byte_identical() {
        let run = |faults: FaultPlan| {
            Simulator::new(
                chol(10, 4),
                SimConfig::default()
                    .with_workers_per_node(2)
                    .with_seed(7)
                    .with_max_events(50_000_000)
                    .with_faults(faults),
                CostModel::default_calibrated(),
                MigrateConfig::default(),
                20,
            )
            .run()
        };
        let a = run(FaultPlan::default());
        let b = run(FaultPlan {
            enabled: false, // the switch trumps every knob below
            drop_reply: 0.9,
            dup_request: 0.9,
            delay_factor: 8.0,
            crash_node: Some(3),
            crash_at_us: 5.0,
            crash_p: 0.9,
            ..FaultPlan::default()
        });
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.deliver_events, b.deliver_events);
        assert_eq!(
            a.total_steals().successful_steals,
            b.total_steals().successful_steals
        );
        assert_eq!(a.faults_dropped + b.faults_dropped, 0);
        for n in a.nodes.iter().chain(&b.nodes) {
            assert_eq!(n.steal_timeouts + n.steal_retries, 0);
            assert_eq!(n.ledger_reclaims + n.dup_replies_suppressed, 0);
        }
    }

    /// The acceptance scenario in the DES: an all-on-node-0 UTS run
    /// over a fabric that drops 40% of steal replies (plus request
    /// drops and duplicates everywhere) completes with every task
    /// executed exactly once — the internal end-of-run asserts prove
    /// zero ledger residue, zero pending requests and zero leaked
    /// inflight slots — while the healing machinery demonstrably
    /// engaged, and the whole ordeal is deterministic given the seed.
    #[test]
    fn faulty_fabric_des_completes_exactly_once_and_heals() {
        let mk_graph = || {
            Arc::new(UtsGraph::new(UtsParams {
                b0: 32,
                m: 4,
                q: 0.3,
                g: 50_000.0,
                seed: 5,
                nodes: 4,
                max_depth: 24,
            }))
        };
        let faults: FaultPlan = "drop-reply=0.4,drop-request=0.2,dup=0.25"
            .parse()
            .unwrap();
        let run = || {
            Simulator::new(
                mk_graph(),
                SimConfig::default()
                    .with_workers_per_node(4)
                    .with_seed(3)
                    .with_max_events(50_000_000)
                    .with_record_polls(false)
                    .with_faults(faults),
                CostModel::default_calibrated(),
                MigrateConfig::default().with_poll_interval_us(20.0),
                20,
            )
            .run()
        };
        let g = mk_graph();
        let size = g.tree_size(10_000_000);
        let a = run();
        assert_eq!(a.tasks_total_executed(), size, "exactly once under loss");
        assert!(a.faults_dropped > 0, "the plan must actually bite");
        assert!(a.faults_duplicated > 0);
        let timeouts: u64 = a.nodes.iter().map(|n| n.steal_timeouts).sum();
        let retries: u64 = a.nodes.iter().map(|n| n.steal_retries).sum();
        let reclaims: u64 = a.nodes.iter().map(|n| n.ledger_reclaims).sum();
        let dups: u64 = a.nodes.iter().map(|n| n.dup_replies_suppressed).sum();
        assert!(timeouts > 0, "dropped replies must time out");
        assert!(retries > 0, "timeouts must retry within the budget");
        assert!(dups > 0, "duplicated replies must be suppressed");
        assert!(
            reclaims > 0,
            "some dropped grant must come home via nack-reclaim \
             (timeouts {timeouts}, retries {retries}, dups {dups})"
        );
        // Per-victim timeout telemetry balances the node totals.
        for (ix, n) in a.nodes.iter().enumerate() {
            assert_eq!(
                n.victim_timeouts.iter().sum::<u64>(),
                n.steal_timeouts,
                "node {ix}"
            );
            assert_eq!(n.victim_timeouts[ix], 0, "node {ix}: never times out on itself");
        }
        // Chaos, but seeded chaos: the run is a pure function of the seed.
        let b = run();
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.faults_dropped, b.faults_dropped);
    }

    /// The straggler window: stalling node 1's steal traffic for the
    /// first half of the run must not break exactly-once completion,
    /// and the stalled traffic registers as drops.
    #[test]
    fn straggler_stall_window_heals() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 20_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(4)
                .with_seed(3)
                .with_max_events(50_000_000)
                .with_record_polls(false)
                .with_faults("slow-node=1,slow-until-us=20000,stall".parse().unwrap()),
            CostModel::default_calibrated(),
            MigrateConfig::default().with_poll_interval_us(20.0),
            20,
        )
        .run();
        assert_eq!(r.tasks_total_executed(), size);
        assert!(r.faults_dropped > 0, "in-window steal traffic stalls");
    }

    /// Crash-stop acceptance in the DES: killing node 2 a third of the
    /// way through an 8-node Cholesky still executes every task exactly
    /// once among the survivors — the run-exit asserts prove zero
    /// ledger/inflight/orphan residue — while the recovery telemetry
    /// records the detection, the ring repair and the re-homed work,
    /// and the whole ordeal is deterministic given the seed. All three
    /// scheduler backends.
    #[test]
    fn crash_stop_recovers_exactly_once_on_every_backend() {
        for sched in SchedBackend::ALL {
            let run = |faults: FaultPlan| {
                Simulator::new(
                    chol(12, 8),
                    SimConfig::default()
                        .with_workers_per_node(4)
                        .with_seed(3)
                        .with_max_events(50_000_000)
                        .with_record_polls(false)
                        .with_sched(sched)
                        .with_faults(faults),
                    CostModel::default_calibrated(),
                    MigrateConfig::default().with_poll_interval_us(20.0),
                    20,
                )
                .run()
            };
            let total = chol(12, 8).total_tasks().unwrap();
            // Calibrate the crash instant off the fault-free makespan so
            // node 2 is provably mid-run (busy) when it dies.
            let base = run(FaultPlan::default());
            assert_eq!(base.tasks_total_executed(), total, "{sched:?}");
            let mid = (base.makespan_us / 3.0).max(1.0) as u64;
            let plan: FaultPlan = format!("crash-node=2,crash-at-us={mid}").parse().unwrap();
            let a = run(plan);
            assert_eq!(a.tasks_total_executed(), total, "{sched:?}: exactly once");
            assert_eq!(a.recovery.nodes_crashed, 1, "{sched:?}");
            assert_eq!(a.recovery.nodes_suspected, 1, "{sched:?}");
            assert_eq!(a.recovery.ring_repairs, 1, "{sched:?}");
            assert!(
                a.recovery.tasks_recovered > 0,
                "{sched:?}: a mid-run crash must strand work to re-home"
            );
            assert!(
                a.recovery.detect_latency_us > 0.0,
                "{sched:?}: detection latency is modeled, not free"
            );
            assert!(
                a.makespan_us > base.makespan_us,
                "{sched:?}: losing an eighth of the cluster cannot be free"
            );
            // Every survivor quarantined the corpse exactly once.
            for (ix, n) in a.nodes.iter().enumerate() {
                if ix != 2 {
                    assert_eq!(n.victim_quarantined[2], 1, "{sched:?} node {ix}");
                }
            }
            let b = run(plan);
            assert_eq!(a.makespan_us, b.makespan_us, "{sched:?}: deterministic");
            assert_eq!(a.events, b.events, "{sched:?}");
            assert_eq!(a.recovery.tasks_recovered, b.recovery.tasks_recovered, "{sched:?}");
        }
    }

    /// The PR 7 liveness caveat, closed: a *permanent* stall window
    /// (node 1's steal traffic black-holed from 2 ms onward, with no
    /// end) used to pin victims whose granted reply crossed the window
    /// edge in an unbounded ack-retransmit loop — this regression test
    /// previously could not terminate. The probe budget now settles
    /// every parked grant from the thief's own book, so the run drains.
    #[test]
    fn permanent_stall_settles_via_probe_budget() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 20_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(4)
                .with_seed(3)
                .with_max_events(50_000_000)
                .with_record_polls(false)
                .with_faults("slow-node=1,slow-from-us=2000,stall".parse().unwrap()),
            CostModel::default_calibrated(),
            MigrateConfig::default().with_poll_interval_us(20.0),
            20,
        )
        .run();
        assert_eq!(r.tasks_total_executed(), size, "exactly once despite the stall");
        assert!(r.faults_dropped > 0, "the permanent window must bite");
    }

    #[test]
    fn polls_recorded_for_potential_metric() {
        let r = sim(chol(10, 2), MigrateConfig::disabled(), 5, 2);
        assert!(r.nodes.iter().any(|n| !n.polls.is_empty()));
        let series = r.potential_series(r.makespan_us / 5.0);
        assert!(!series.is_empty());
        assert!(series.iter().all(|e| *e >= 0.0 && e.is_finite()));
    }

    /// The tentpole's default-off contract: passing `--topology flat`
    /// and `--steal-domains flat` explicitly must be *byte-identical*
    /// to a config that never mentions either — same event count, same
    /// wire traffic, same makespan — because the flat topology returns
    /// the base link verbatim and flat domains never consult the
    /// escalation state.
    #[test]
    fn flat_topology_and_domains_are_byte_identical_to_default() {
        let a = sim(chol(10, 4), MigrateConfig::default(), 7, 2);
        let b = Simulator::new(
            chol(10, 4),
            SimConfig::default()
                .with_workers_per_node(2)
                .with_seed(7)
                .with_max_events(50_000_000)
                .with_topology("flat".parse().unwrap())
                .with_steal_domains(StealDomains::Flat),
            CostModel::default_calibrated(),
            MigrateConfig::default(),
            20,
        )
        .run();
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.events, b.events);
        assert_eq!(a.deliver_events, b.deliver_events);
        assert_eq!(
            a.total_steals().successful_steals,
            b.total_steals().successful_steals
        );
        // On a flat topology every remote steal is cluster-distance:
        // the socket and rack tiers never see a request.
        for n in a.nodes.iter().chain(&b.nodes) {
            assert_eq!(n.tier_steal_requests[0] + n.tier_steal_requests[1], 0);
            assert_eq!(n.tier_steal_requests[2], n.steal.requests_sent);
        }
    }

    /// Hierarchical steal domains on a 2-tier topology: thieves exhaust
    /// their socket before escalating, so at equal seeds the cross-tier
    /// steal-request traffic drops below the flat-domain run's — the
    /// PR's acceptance criterion — while every task still executes
    /// exactly once, the per-tier counters sum to the existing steal
    /// stats, and the run stays deterministic.
    #[test]
    fn hierarchical_domains_cut_cross_tier_steal_traffic() {
        let topo = Topology::two_tier(
            4,
            LinkModel {
                latency_us: 1.0,
                bw_bytes_per_us: 40_000.0,
            },
            LinkModel {
                latency_us: 20.0,
                bw_bytes_per_us: 2_500.0,
            },
        );
        let run = |domains: StealDomains| {
            Simulator::new(
                chol(14, 8),
                SimConfig::default()
                    .with_workers_per_node(2)
                    .with_seed(7)
                    .with_max_events(50_000_000)
                    .with_record_polls(false)
                    .with_topology(topo)
                    .with_steal_domains(domains),
                CostModel::default_calibrated(),
                MigrateConfig::default(),
                20,
            )
            .run()
        };
        let total = chol(14, 8).total_tasks().unwrap();
        let flat = run(StealDomains::Flat);
        let hier = run(StealDomains::Hierarchical);
        assert_eq!(flat.tasks_total_executed(), total);
        assert_eq!(hier.tasks_total_executed(), total);
        for r in [&flat, &hier] {
            for (ix, n) in r.nodes.iter().enumerate() {
                assert_eq!(
                    n.tier_steal_requests.iter().sum::<u64>(),
                    n.steal.requests_sent,
                    "node {ix}: tier requests partition requests_sent"
                );
                assert_eq!(
                    n.tier_steal_grants.iter().sum::<u64>(),
                    n.steal.successful_steals,
                    "node {ix}: tier grants partition successful steals"
                );
            }
        }
        assert!(
            flat.cross_tier_steal_requests() > 0,
            "flat domains must leak cross-socket requests for the comparison to mean anything"
        );
        assert!(
            hier.cross_tier_steal_requests() < flat.cross_tier_steal_requests(),
            "hierarchical must cut cross-tier requests: hier {} vs flat {}",
            hier.cross_tier_steal_requests(),
            flat.cross_tier_steal_requests()
        );
        // Near-tier traffic dominates once thieves prefer their socket.
        let near = hier.tier_steal_totals()[0].0;
        assert!(
            near > hier.cross_tier_steal_requests(),
            "near-tier requests ({near}) must dominate cross-tier ({})",
            hier.cross_tier_steal_requests()
        );
        // Determinism of the new mode.
        let again = run(StealDomains::Hierarchical);
        assert_eq!(hier.makespan_us, again.makespan_us);
        assert_eq!(hier.events, again.events);
    }

    #[test]
    fn builder_setters_equal_exhaustive_literal() {
        // The one place a full SimConfig literal is allowed to live:
        // the builders' own equivalence check.
        let topo: Topology = "socket=2,socket-lat-us=1,socket-bw=1000".parse().unwrap();
        let faults: FaultPlan = "drop=0.1,delay=2x".parse().unwrap();
        let link = LinkModel {
            latency_us: 2.0,
            bw_bytes_per_us: 500.0,
        };
        let built = SimConfig::default()
            .with_workers_per_node(3)
            .with_link(link)
            .with_seed(9)
            .with_max_events(123)
            .with_record_polls(false)
            .with_sched(SchedBackend::Sharded)
            .with_batch_activations(false)
            .with_pool_floor(7)
            .with_faults(faults)
            .with_topology(topo)
            .with_steal_domains(StealDomains::Hierarchical);
        let literal = SimConfig {
            workers_per_node: 3,
            link,
            seed: 9,
            max_events: 123,
            record_polls: false,
            sched: SchedBackend::Sharded,
            batch_activations: false,
            pool_floor: 7,
            faults,
            topology: topo,
            steal_domains: StealDomains::Hierarchical,
        };
        assert_eq!(built, literal);
    }
}
