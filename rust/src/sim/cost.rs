//! Task-cost model for virtual-time execution.
//!
//! Dense tile ops follow a cubic-plus-constant fit `t(n) = c3·n³ + c0`
//! per task class — the form BLAS-3 tile kernels follow — with
//! coefficients measured on the real PJRT artifacts by `repro calibrate`
//! and persisted to `artifacts/costmodel.json`. Sparse-tile tasks cost a
//! small constant (queue pass, no compute, §4.4); UTS tasks cost
//! `g × uts_us_per_unit`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::dataflow::task::TaskClass;
use crate::util::json::Json;

/// Cubic cost fit for one task class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassCost {
    /// µs per element³.
    pub c3: f64,
    /// Fixed per-task overhead in µs (dispatch + PJRT call).
    pub c0: f64,
}

impl ClassCost {
    pub fn eval_us(&self, n: u32) -> f64 {
        self.c3 * (n as f64).powi(3) + self.c0
    }
}

/// The full cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Indexed by POTRF/TRSM/SYRK/GEMM (TaskClass discriminants 0..4).
    pub dense: [ClassCost; 4],
    /// µs per UTS work unit (task cost = g · this).
    pub uts_us_per_unit: f64,
    /// Cost of a task whose tile is sparse: scheduler pass, no compute.
    pub sparse_task_us: f64,
    /// Log-normal sigma applied multiplicatively to every execution
    /// (system noise; the paper's normality analysis motivates ~5–10%).
    pub noise_sigma: f64,
    /// Log-normal sigma of a *persistent per-node* slowness factor drawn
    /// once per run — shared-cluster stragglers (OS jitter, neighbors on
    /// the interconnect, NUMA placement). This is the imbalance a static
    /// work division cannot absorb and work stealing exists to fix; the
    /// paper's Fig. 4 run-to-run spread (~±20% on Gadi) calibrates the
    /// default.
    pub node_sigma: f64,
}

impl CostModel {
    /// Defaults measured on this container's PJRT CPU backend (see
    /// EXPERIMENTS.md §Calibration); used when costmodel.json is absent.
    pub fn default_calibrated() -> Self {
        CostModel {
            dense: [
                // POTRF: sequential column loop dominates -> large c0
                ClassCost { c3: 2.4e-4, c0: 45.0 },
                // TRSM: forward substitution, loop-carried
                ClassCost { c3: 3.1e-4, c0: 40.0 },
                // SYRK
                ClassCost { c3: 2.0e-4, c0: 12.0 },
                // GEMM
                ClassCost { c3: 2.2e-4, c0: 12.0 },
            ],
            uts_us_per_unit: 1e-3,
            sparse_task_us: 1.5,
            noise_sigma: 0.08,
            node_sigma: 0.18,
        }
    }

    /// Execution time of one task in µs, before noise.
    pub fn exec_us(&self, class: TaskClass, tile_size: u32, work_units: f64) -> f64 {
        match class {
            TaskClass::Potrf | TaskClass::Trsm | TaskClass::Syrk | TaskClass::Gemm => {
                if work_units == 0.0 {
                    self.sparse_task_us
                } else {
                    self.dense[class as usize].eval_us(tile_size)
                }
            }
            TaskClass::UtsNode => work_units * self.uts_us_per_unit,
            // Synthetic tasks carry their cost directly in µs.
            TaskClass::Synthetic => work_units,
        }
    }

    pub fn to_json(&self) -> Json {
        let class_obj = |c: &ClassCost| {
            Json::obj(vec![("c3_us", Json::Num(c.c3)), ("c0_us", Json::Num(c.c0))])
        };
        Json::obj(vec![
            ("potrf", class_obj(&self.dense[0])),
            ("trsm", class_obj(&self.dense[1])),
            ("syrk", class_obj(&self.dense[2])),
            ("gemm", class_obj(&self.dense[3])),
            ("uts_us_per_unit", Json::Num(self.uts_us_per_unit)),
            ("sparse_task_us", Json::Num(self.sparse_task_us)),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            ("node_sigma", Json::Num(self.node_sigma)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let class = |name: &str| -> Result<ClassCost> {
            let o = j
                .get(name)
                .with_context(|| format!("costmodel: missing '{name}'"))?;
            Ok(ClassCost {
                c3: o.req_f64("c3_us")?,
                c0: o.req_f64("c0_us")?,
            })
        };
        Ok(CostModel {
            dense: [class("potrf")?, class("trsm")?, class("syrk")?, class("gemm")?],
            uts_us_per_unit: j.req_f64("uts_us_per_unit")?,
            sparse_task_us: j.req_f64("sparse_task_us")?,
            noise_sigma: j.req_f64("noise_sigma")?,
            // Optional for older costmodel.json files.
            node_sigma: j
                .get("node_sigma")
                .and_then(Json::as_f64)
                .unwrap_or_else(|| Self::default_calibrated().node_sigma),
        })
    }

    /// Load `artifacts/costmodel.json` if present, else defaults.
    pub fn load_or_default(path: &Path) -> Self {
        std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|j| Self::from_json(&j).ok())
            .unwrap_or_else(Self::default_calibrated)
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self::default_calibrated()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_fit_grows_with_tile() {
        let cm = CostModel::default_calibrated();
        let t10 = cm.exec_us(TaskClass::Gemm, 10, 2.0);
        let t50 = cm.exec_us(TaskClass::Gemm, 50, 2.0);
        assert!(t50 > t10, "{t50} vs {t10}");
        // asymptotically ~125x for pure cubic; with c0 it's less
        assert!(t50 / t10 > 2.0);
    }

    #[test]
    fn sparse_tasks_are_cheap() {
        let cm = CostModel::default_calibrated();
        assert!(cm.exec_us(TaskClass::Gemm, 50, 0.0) < cm.exec_us(TaskClass::Gemm, 50, 2.0));
        assert_eq!(cm.exec_us(TaskClass::Gemm, 50, 0.0), cm.sparse_task_us);
    }

    #[test]
    fn uts_scales_with_g() {
        let cm = CostModel::default_calibrated();
        assert_eq!(
            cm.exec_us(TaskClass::UtsNode, 0, 12e6),
            12e6 * cm.uts_us_per_unit
        );
    }

    #[test]
    fn json_roundtrip() {
        let cm = CostModel::default_calibrated();
        let j = cm.to_json();
        let back = CostModel::from_json(&Json::parse(&j.pretty()).unwrap()).unwrap();
        assert_eq!(cm, back);
    }

    #[test]
    fn load_or_default_falls_back() {
        let cm = CostModel::load_or_default(Path::new("/nonexistent/x.json"));
        assert_eq!(cm, CostModel::default_calibrated());
    }
}
