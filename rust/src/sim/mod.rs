//! Discrete-event simulator of the distributed runtime.
//!
//! The paper's evaluation ran on 2–32 Gadi nodes with 40 worker threads
//! each; this testbed is one container. The simulator executes the *same
//! protocol code* (scheduler queues, activation tracking, migrate-module
//! policies) under virtual time, with per-task costs drawn from a cost
//! model calibrated against real PJRT kernel timings (`repro calibrate`).
//! That preserves exactly what the figures measure — relative speedups,
//! variance, steal success, imbalance — while letting us model 8×40
//! workers faithfully. See DESIGN.md's substitution table.

pub mod cost;
pub mod engine;

pub use cost::{ClassCost, CostModel};
pub use engine::{SimConfig, Simulator};
