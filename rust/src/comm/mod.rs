//! Inter-node communication.
//!
//! All cross-node traffic — dependency activations, the steal protocol,
//! termination tokens — is message passing through a [`Network`] of
//! per-node mailboxes. There are no shared data structures between
//! protocol domains (distinguishing this, per §2 of the paper, from PGAS
//! work stealing): the in-process transport stands in for MPI, with a
//! configurable latency/bandwidth model applied on the wire.

pub mod message;
pub mod network;

pub use message::{Envelope, Msg};
pub use network::{LinkModel, Network, NodeMailbox};
