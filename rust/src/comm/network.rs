//! In-process transport with a wire model.
//!
//! Each node owns a [`NodeMailbox`] (an mpsc receiver). Sends go either
//! directly (zero-latency) or through a delay-line thread that holds each
//! envelope until its modeled arrival time — `latency + bytes/bandwidth`
//! — preserving per-link FIFO order like an MPI point-to-point channel.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::message::{Envelope, Msg};
use crate::dataflow::task::NodeId;
use crate::faults::{FaultClass, FaultMark, FaultPlan};
use crate::topology::{Topology, TIER_COUNT};
use crate::util::rng::{fault_rng, Rng};

/// Wire model: time on the wire = `latency_us + bytes / bw_bytes_per_us`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    pub latency_us: f64,
    pub bw_bytes_per_us: f64,
}

impl LinkModel {
    /// Instant delivery (unit tests, pure-throughput benches).
    pub fn ideal() -> Self {
        LinkModel {
            latency_us: 0.0,
            bw_bytes_per_us: f64::INFINITY,
        }
    }

    /// A cluster-interconnect-ish default: ~5 µs latency, ~10 GB/s.
    pub fn cluster() -> Self {
        LinkModel {
            latency_us: 5.0,
            bw_bytes_per_us: 10_000.0,
        }
    }

    pub fn transfer_us(&self, bytes: u64) -> f64 {
        self.latency_us + bytes as f64 / self.bw_bytes_per_us
    }

    pub fn is_ideal(&self) -> bool {
        self.latency_us <= 0.0 && self.bw_bytes_per_us.is_infinite()
    }
}

/// Per-node receive side.
pub struct NodeMailbox {
    rx: Receiver<Envelope>,
}

impl NodeMailbox {
    pub fn recv_timeout(&self, d: Duration) -> Option<Envelope> {
        match self.rx.recv_timeout(d) {
            Ok(e) => Some(e),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    pub fn try_recv(&self) -> Option<Envelope> {
        self.rx.try_recv().ok()
    }
}

struct Delayed {
    deliver_at: Instant,
    seq: u64,
    env: Envelope,
}

impl PartialEq for Delayed {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at == other.deliver_at && self.seq == other.seq
    }
}
impl Eq for Delayed {}
impl PartialOrd for Delayed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Delayed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap by (deliver_at, seq)
        other
            .deliver_at
            .cmp(&self.deliver_at)
            .then(other.seq.cmp(&self.seq))
    }
}

struct DelayLine {
    heap: Mutex<BinaryHeap<Delayed>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Crash-stop gate (`--faults crash-*`), shared between the fabric and
/// its delay-line thread. Once the armed node's crash time passes, the
/// fabric drops everything the dead node sends and diverts everything
/// addressed to it into a graveyard, which the recovery coordinator
/// drains: basic messages are re-injected to the rehash survivor,
/// steal-class ones are discarded (the steal protocol's own timeout and
/// ledger machinery heals them). Unarmed (the default), every check is
/// one relaxed atomic load and the fabric behaves exactly as before.
struct CrashGate {
    /// Armed victim (`u32::MAX` = none).
    node: AtomicU32,
    /// Crash time as `f64` bits, µs on the fabric clock.
    at_us_bits: AtomicU64,
    /// Fabric start time (copy of [`Network::t0`]).
    t0: Instant,
    /// Envelopes addressed to the dead node after its crash.
    graveyard: Mutex<Vec<Envelope>>,
}

impl CrashGate {
    fn unarmed(t0: Instant) -> CrashGate {
        CrashGate {
            node: AtomicU32::new(u32::MAX),
            at_us_bits: AtomicU64::new(0),
            t0,
            graveyard: Mutex::new(Vec::new()),
        }
    }

    fn is_crashed(&self, node: NodeId) -> bool {
        let armed = self.node.load(Ordering::Relaxed);
        armed == node.0
            && self.t0.elapsed().as_secs_f64() * 1e6
                >= f64::from_bits(self.at_us_bits.load(Ordering::Relaxed))
    }

    fn bury(&self, env: Envelope) {
        self.graveyard.lock().unwrap().push(env);
    }
}

/// The cluster fabric.
pub struct Network {
    senders: Vec<Sender<Envelope>>,
    link: LinkModel,
    /// Tier model resolving each (src, dst) pair to its link
    /// (`--topology`); flat by default, in which case every pair is
    /// `link` verbatim.
    topo: Topology,
    delay: Option<Arc<DelayLine>>,
    delay_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    seq: AtomicU64,
    pub sent_msgs: AtomicU64,
    pub sent_bytes: AtomicU64,
    /// `--faults` schedule, applied to steal-protocol traffic only
    /// (see [`Network::new_with_faults`]); default off.
    faults: FaultPlan,
    /// Dedicated RNG stream for fault decisions (never touched when the
    /// plan is off, so a faults-off fabric is byte-identical to one
    /// built without a plan).
    fault_rng: Mutex<Rng>,
    /// Fabric start time: the straggler window's run clock.
    t0: Instant,
    /// Steal-class messages delivered marked-dropped (diagnostics).
    pub faults_dropped: AtomicU64,
    /// Injected duplicate copies (diagnostics).
    pub faults_duplicated: AtomicU64,
    /// Crash-stop gate (`--faults crash-*`); unarmed by default.
    crash: Arc<CrashGate>,
}

impl Network {
    /// Build a fabric for `n` nodes; returns the network plus each node's
    /// mailbox (index = node id).
    pub fn new(n: usize, link: LinkModel) -> (Arc<Network>, Vec<NodeMailbox>) {
        Self::new_with_faults(n, link, FaultPlan::default(), 0)
    }

    /// Build a fabric with a fault plan (`--faults`). `seed` feeds the
    /// dedicated fault stream; with `plan` disabled this is exactly
    /// [`Network::new`].
    pub fn new_with_faults(
        n: usize,
        link: LinkModel,
        plan: FaultPlan,
        seed: u64,
    ) -> (Arc<Network>, Vec<NodeMailbox>) {
        Self::new_with_topology(n, link, Topology::flat(), plan, seed)
    }

    /// Build a fabric with a fault plan and a [`Topology`]
    /// (`--topology`): each (src, dst) pair's wire time uses the link of
    /// the tightest tier containing both. With `topo` flat this is
    /// exactly [`Network::new_with_faults`].
    pub fn new_with_topology(
        n: usize,
        link: LinkModel,
        topo: Topology,
        plan: FaultPlan,
        seed: u64,
    ) -> (Arc<Network>, Vec<NodeMailbox>) {
        let mut senders = Vec::with_capacity(n);
        let mut mailboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            mailboxes.push(NodeMailbox { rx });
        }
        // The delay line exists iff *any* resolvable pair has a
        // non-ideal link; for a flat topology every tier link is the
        // base link, so this is the old `!link.is_ideal()` test.
        let needs_delay = (0..TIER_COUNT).any(|t| !topo.tier_link(t, link).is_ideal());
        let delay = if !needs_delay {
            None
        } else {
            Some(Arc::new(DelayLine {
                heap: Mutex::new(BinaryHeap::new()),
                cv: Condvar::new(),
                shutdown: Mutex::new(false),
            }))
        };
        let t0 = Instant::now();
        let net = Arc::new(Network {
            senders,
            link,
            topo,
            delay,
            delay_thread: Mutex::new(None),
            seq: AtomicU64::new(0),
            sent_msgs: AtomicU64::new(0),
            sent_bytes: AtomicU64::new(0),
            faults: plan,
            fault_rng: Mutex::new(fault_rng(seed, 0)),
            t0,
            faults_dropped: AtomicU64::new(0),
            faults_duplicated: AtomicU64::new(0),
            crash: Arc::new(CrashGate::unarmed(t0)),
        });
        if net.delay.is_some() {
            let line = net.delay.as_ref().unwrap().clone();
            let senders = net.senders.clone();
            let gate = net.crash.clone();
            let handle = std::thread::Builder::new()
                .name("net-delay".into())
                .spawn(move || delay_loop(line, senders, gate))
                .expect("spawn delay line");
            *net.delay_thread.lock().unwrap() = Some(handle);
        }
        (net, mailboxes)
    }

    /// Arm the crash-stop gate: from `at_us` on the fabric clock, `node`
    /// is dead to the network. Called once at startup from the resolved
    /// [`FaultPlan::crash_schedule`].
    pub fn arm_crash(&self, node: u32, at_us: f64) {
        self.crash.at_us_bits.store(at_us.to_bits(), Ordering::Relaxed);
        self.crash.node.store(node, Ordering::Relaxed);
    }

    /// Whether `node` is past its armed crash time.
    pub fn is_crashed(&self, node: NodeId) -> bool {
        self.crash.is_crashed(node)
    }

    /// Run clock (µs since fabric start) — the time base of the fault
    /// plan's straggler window and the crash gate.
    pub fn now_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }

    /// Divert an envelope to the dead node's graveyard (also used by
    /// the dead node's comm thread to hand over its final mailbox
    /// contents — messages delivered but never processed).
    pub fn bury(&self, env: Envelope) {
        self.crash.bury(env);
    }

    /// Drain the graveyard (recovery coordinator only).
    pub fn drain_graveyard(&self) -> Vec<Envelope> {
        std::mem::take(&mut *self.crash.graveyard.lock().unwrap())
    }

    /// True when no envelope is buried awaiting recovery.
    pub fn graveyard_is_empty(&self) -> bool {
        self.crash.graveyard.lock().unwrap().is_empty()
    }

    /// True while the delay line still holds traffic addressed to
    /// `node` — the leader gates termination on this so a message in
    /// flight toward a dead node (invisible to Safra after the ring
    /// repair) cannot be lost to the graveyard after the final drain.
    pub fn inflight_to(&self, node: NodeId) -> bool {
        match &self.delay {
            None => false,
            Some(line) => line.heap.lock().unwrap().iter().any(|d| d.env.dst == node),
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.senders.len()
    }

    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// The fabric's tier model (flat unless built with
    /// [`Network::new_with_topology`]).
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// The link this fabric uses between one specific pair of nodes.
    pub fn link_between(&self, a: NodeId, b: NodeId) -> LinkModel {
        self.topo.link_between(a.idx(), b.idx(), self.link)
    }

    /// Which fault class (if any) a message belongs to: only the steal
    /// protocol is ever faulted — activations, tokens and shutdown stay
    /// reliable.
    fn steal_class(msg: &Msg) -> Option<FaultClass> {
        match msg {
            Msg::StealRequest { .. } => Some(FaultClass::Request),
            Msg::StealReply { .. } => Some(FaultClass::Reply),
            Msg::TransferAck { .. } => Some(FaultClass::Ack),
            _ => None,
        }
    }

    /// Send `msg` from `src` to `dst` through the wire model. With a
    /// fault plan active, steal-class messages may be delivered marked
    /// [`FaultMark::Dropped`] (the receiver balances Safra's accounting
    /// and discards), duplicated (extra copy marked
    /// [`FaultMark::Duplicate`]) or delayed (multiplied wire time; a
    /// no-op on ideal links, which model zero wire time).
    pub fn send(&self, src: NodeId, dst: NodeId, msg: Msg) {
        if self.crash.is_crashed(src) {
            // A crashed node's last racing sends never reach the wire.
            return;
        }
        if self.crash.is_crashed(dst) {
            // Addressed to a dead host: straight to the graveyard for
            // the recovery coordinator (no wire, no fault draws).
            self.crash.bury(Envelope {
                src,
                dst,
                msg,
                fault: FaultMark::None,
            });
            return;
        }
        let bytes = msg.wire_bytes();
        self.sent_msgs.fetch_add(1, Ordering::Relaxed);
        self.sent_bytes.fetch_add(bytes, Ordering::Relaxed);
        let mut mark = FaultMark::None;
        let mut delay_mult = 1.0;
        let mut duplicate = false;
        if self.faults.enabled {
            if let Some(class) = Self::steal_class(&msg) {
                let now_us = self.t0.elapsed().as_secs_f64() * 1e6;
                let d = self.faults.decide(
                    class,
                    src.0,
                    dst.0,
                    now_us,
                    &mut self.fault_rng.lock().unwrap(),
                );
                if d.dropped {
                    mark = FaultMark::Dropped;
                    self.faults_dropped.fetch_add(1, Ordering::Relaxed);
                } else {
                    duplicate = d.duplicate;
                    delay_mult = d.delay_mult;
                }
            }
        }
        if duplicate {
            self.faults_duplicated.fetch_add(1, Ordering::Relaxed);
            self.sent_msgs.fetch_add(1, Ordering::Relaxed);
            self.sent_bytes.fetch_add(bytes, Ordering::Relaxed);
            self.dispatch(
                Envelope {
                    src,
                    dst,
                    msg: msg.clone(),
                    fault: FaultMark::Duplicate,
                },
                bytes,
                delay_mult,
            );
        }
        self.dispatch(Envelope { src, dst, msg, fault: mark }, bytes, delay_mult);
    }

    fn dispatch(&self, env: Envelope, bytes: u64, delay_mult: f64) {
        match &self.delay {
            None => {
                // Ignore send errors during shutdown (receiver dropped).
                let _ = self.senders[env.dst.idx()].send(env);
            }
            Some(line) => {
                let delay_us =
                    self.link_between(env.src, env.dst).transfer_us(bytes) * delay_mult;
                let deliver_at = Instant::now() + Duration::from_nanos((delay_us * 1e3) as u64);
                let seq = self.seq.fetch_add(1, Ordering::Relaxed);
                line.heap.lock().unwrap().push(Delayed {
                    deliver_at,
                    seq,
                    env,
                });
                line.cv.notify_one();
            }
        }
    }

    /// Broadcast (used for Shutdown).
    pub fn broadcast_from(&self, src: NodeId, msg: Msg) {
        for i in 0..self.senders.len() {
            if i != src.idx() {
                self.send(src, NodeId(i as u32), msg.clone());
            }
        }
    }

    /// Stop the delay-line thread (idempotent).
    pub fn shutdown(&self) {
        if let Some(line) = &self.delay {
            *line.shutdown.lock().unwrap() = true;
            line.cv.notify_all();
            if let Some(h) = self.delay_thread.lock().unwrap().take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Network {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn delay_loop(line: Arc<DelayLine>, senders: Vec<Sender<Envelope>>, gate: Arc<CrashGate>) {
    // Deliver, or bury if the destination crashed while the envelope
    // was on the wire (the in-flight half of the crash gate; sends
    // after the crash never reach the heap at all).
    let deliver = |env: Envelope| {
        if gate.is_crashed(env.dst) {
            gate.bury(env);
        } else {
            let _ = senders[env.dst.idx()].send(env);
        }
    };
    loop {
        let mut heap = line.heap.lock().unwrap();
        loop {
            if *line.shutdown.lock().unwrap() {
                // Flush whatever is pending so no message is lost.
                while let Some(d) = heap.pop() {
                    deliver(d.env);
                }
                return;
            }
            let now = Instant::now();
            match heap.peek() {
                Some(d) if d.deliver_at <= now => {
                    let d = heap.pop().unwrap();
                    deliver(d.env);
                }
                Some(d) => {
                    let wait = d.deliver_at - now;
                    let (h, _timeout) = line.cv.wait_timeout(heap, wait).unwrap();
                    heap = h;
                }
                None => {
                    let (h, _timeout) = line
                        .cv
                        .wait_timeout(heap, Duration::from_millis(50))
                        .unwrap();
                    heap = h;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{TaskClass, TaskDesc};

    fn activate(i: u32) -> Msg {
        Msg::Activate {
            task: TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0),
        }
    }

    #[test]
    fn ideal_network_delivers_immediately() {
        let (net, mb) = Network::new(2, LinkModel::ideal());
        net.send(NodeId(0), NodeId(1), activate(7));
        let env = mb[1].recv_timeout(Duration::from_millis(100)).unwrap();
        assert_eq!(env.src, NodeId(0));
        assert!(matches!(env.msg, Msg::Activate { task } if task.i == 7));
    }

    #[test]
    fn delayed_network_preserves_order_and_delivers() {
        let (net, mb) = Network::new(2, LinkModel {
            latency_us: 200.0,
            bw_bytes_per_us: 1_000.0,
        });
        let t0 = Instant::now();
        for i in 0..5 {
            net.send(NodeId(0), NodeId(1), activate(i));
        }
        for i in 0..5 {
            let env = mb[1].recv_timeout(Duration::from_secs(1)).expect("delivery");
            assert!(matches!(env.msg, Msg::Activate { task } if task.i == i));
        }
        assert!(t0.elapsed() >= Duration::from_micros(200), "latency applied");
        net.shutdown();
    }

    #[test]
    fn broadcast_reaches_everyone_but_source() {
        let (net, mb) = Network::new(4, LinkModel::ideal());
        net.broadcast_from(NodeId(1), Msg::Shutdown);
        for (i, m) in mb.iter().enumerate() {
            let got = m.try_recv();
            if i == 1 {
                assert!(got.is_none());
            } else {
                assert!(matches!(got.unwrap().msg, Msg::Shutdown));
            }
        }
    }

    #[test]
    fn counters_track_traffic() {
        let (net, _mb) = Network::new(2, LinkModel::ideal());
        net.send(NodeId(0), NodeId(1), activate(0));
        net.send(
            NodeId(0),
            NodeId(1),
            Msg::StealRequest {
                thief: NodeId(0),
                req: 1,
            },
        );
        assert_eq!(net.sent_msgs.load(Ordering::Relaxed), 2);
        assert!(net.sent_bytes.load(Ordering::Relaxed) >= 48);
    }

    #[test]
    fn faulted_fabric_marks_but_never_loses_steal_messages() {
        // Every steal-class message still arrives — dropped ones are
        // *marked*, so Safra's send/receive accounting stays balanced —
        // while activations pass untouched.
        let plan: FaultPlan = "drop=0.5,dup=0.3".parse().unwrap();
        let (net, mb) = Network::new_with_faults(2, LinkModel::ideal(), plan, 0xFAB);
        let sends = 400u64;
        for i in 0..sends {
            net.send(
                NodeId(0),
                NodeId(1),
                Msg::StealRequest {
                    thief: NodeId(0),
                    req: i,
                },
            );
        }
        net.send(NodeId(0), NodeId(1), activate(9));
        let (mut normal, mut dropped, mut dups) = (0u64, 0u64, 0u64);
        let mut activations = 0u64;
        while let Some(env) = mb[1].recv_timeout(Duration::from_millis(100)) {
            match (&env.msg, env.fault) {
                (Msg::Activate { .. }, mark) => {
                    assert_eq!(mark, FaultMark::None, "activations are never faulted");
                    activations += 1;
                }
                (_, FaultMark::None) => normal += 1,
                (_, FaultMark::Dropped) => dropped += 1,
                (_, FaultMark::Duplicate) => dups += 1,
            }
        }
        assert_eq!(activations, 1);
        assert_eq!(normal + dropped, sends, "every original send arrives");
        assert_eq!(dropped, net.faults_dropped.load(Ordering::Relaxed));
        assert_eq!(dups, net.faults_duplicated.load(Ordering::Relaxed));
        assert!(dropped > 0, "a 50% drop plan must drop something");
        assert!(dups > 0, "a 30% dup plan must duplicate something");
    }

    #[test]
    fn crash_gate_buries_traffic_to_and_drops_traffic_from_the_dead() {
        let (net, mb) = Network::new(3, LinkModel::ideal());
        // Unarmed: nobody is crashed, nothing is buried.
        assert!(!net.is_crashed(NodeId(1)));
        assert!(net.graveyard_is_empty());
        net.arm_crash(1, 0.0); // dead from t = 0
        assert!(net.is_crashed(NodeId(1)));
        assert!(!net.is_crashed(NodeId(2)));
        // To the dead: buried, not delivered.
        net.send(NodeId(0), NodeId(1), activate(4));
        assert!(mb[1].try_recv().is_none());
        assert!(!net.graveyard_is_empty());
        // From the dead: dropped outright.
        net.send(NodeId(1), NodeId(2), activate(5));
        assert!(mb[2].try_recv().is_none());
        // Survivor-to-survivor traffic is untouched.
        net.send(NodeId(0), NodeId(2), activate(6));
        assert!(matches!(
            mb[2].recv_timeout(Duration::from_millis(100)).unwrap().msg,
            Msg::Activate { task } if task.i == 6
        ));
        // The coordinator drains exactly what was buried.
        let grave = net.drain_graveyard();
        assert_eq!(grave.len(), 1);
        assert!(matches!(grave[0].msg, Msg::Activate { task } if task.i == 4));
        assert!(net.graveyard_is_empty());
        assert!(!net.inflight_to(NodeId(1)), "ideal links hold nothing");
    }

    #[test]
    fn topology_fabric_resolves_pairwise_links() {
        // Ideal base link, but cross-socket pairs ride a modeled
        // cluster link: the fabric must spin up its delay line and
        // resolve each pair's link from the topology.
        let topo: Topology = "socket=2,cluster-lat-us=300,cluster-bw=1000"
            .parse()
            .unwrap();
        let (net, mb) =
            Network::new_with_topology(4, LinkModel::ideal(), topo, FaultPlan::default(), 0);
        let socket = net.link_between(NodeId(0), NodeId(1));
        assert!(socket.is_ideal(), "socket mates inherit the ideal base");
        let cross = net.link_between(NodeId(0), NodeId(2));
        assert_eq!((cross.latency_us, cross.bw_bytes_per_us), (300.0, 1_000.0));
        let t0 = Instant::now();
        net.send(NodeId(0), NodeId(2), activate(1));
        let env = mb[2].recv_timeout(Duration::from_secs(1)).expect("delivery");
        assert!(matches!(env.msg, Msg::Activate { task } if task.i == 1));
        assert!(
            t0.elapsed() >= Duration::from_micros(300),
            "cross-socket latency applied"
        );
        // Socket-local traffic is not slowed by the cluster tier.
        net.send(NodeId(0), NodeId(1), activate(2));
        assert!(mb[1].recv_timeout(Duration::from_millis(200)).is_some());
        net.shutdown();
        // A flat topology keeps the ideal fast path (no delay line).
        let (flat, _mb) = Network::new_with_topology(
            2,
            LinkModel::ideal(),
            Topology::flat(),
            FaultPlan::default(),
            0,
        );
        assert!(flat.link_between(NodeId(0), NodeId(1)).is_ideal());
        assert!(flat.delay.is_none(), "flat+ideal needs no delay thread");
    }

    #[test]
    fn faults_off_fabric_is_unmarked() {
        let (net, mb) = Network::new(2, LinkModel::ideal());
        net.send(
            NodeId(0),
            NodeId(1),
            Msg::StealRequest {
                thief: NodeId(0),
                req: 3,
            },
        );
        let env = mb[1].recv_timeout(Duration::from_millis(100)).unwrap();
        assert_eq!(env.fault, FaultMark::None);
        assert_eq!(net.faults_dropped.load(Ordering::Relaxed), 0);
        assert_eq!(net.faults_duplicated.load(Ordering::Relaxed), 0);
    }
}
