//! Wire messages between runtime domains.

use crate::dataflow::task::{NodeId, TaskDesc};
use crate::term::SafraToken;

/// Everything that crosses a node boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// One input dependency of `task` (owned by the destination) has been
    /// satisfied by a task completion at the source.
    Activate { task: TaskDesc },
    /// Coalesced activations: one task completion satisfied several
    /// dependencies owned by the same destination, shipped as one
    /// message (one header, one Safra deficit entry, one tracker lock at
    /// the receiver) instead of one `Activate` per edge.
    ActivateBatch { tasks: Vec<TaskDesc> },
    /// Thief -> victim: the thief detected starvation and asks for work.
    StealRequest { thief: NodeId },
    /// Victim -> thief: migrated tasks (empty = steal failed). Each task
    /// is *recreated* at the thief with the same uid; `payload_bytes` is
    /// the size of the input data copied along (drives the link model).
    StealReply {
        tasks: Vec<TaskDesc>,
        payload_bytes: u64,
    },
    /// Safra termination-detection token, traveling the ring.
    Token(SafraToken),
    /// Leader -> all: distributed termination detected, shut down.
    Shutdown,
}

impl Msg {
    /// Wire size of an activation carrying `n` satisfied dependencies:
    /// a standalone `Activate` is 32 bytes; a batch amortizes one
    /// 16-byte header over 24-byte packed descriptors. The DES uses
    /// this directly so both runtimes share one wire model.
    pub fn activation_wire_bytes(n: usize) -> u64 {
        if n <= 1 {
            32
        } else {
            16 + 24 * n as u64
        }
    }

    /// Approximate wire size (drives the latency/bandwidth model).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Msg::Activate { .. } => Self::activation_wire_bytes(1),
            Msg::ActivateBatch { tasks } => Self::activation_wire_bytes(tasks.len()),
            Msg::StealRequest { .. } => 16,
            Msg::StealReply {
                tasks,
                payload_bytes,
            } => 16 + 32 * tasks.len() as u64 + payload_bytes,
            Msg::Token(_) => 24,
            Msg::Shutdown => 8,
        }
    }

    /// Safra counts "basic" messages (application traffic); control
    /// messages (token, shutdown) are excluded from the message deficit.
    pub fn is_basic(&self) -> bool {
        !matches!(self, Msg::Token(_) | Msg::Shutdown)
    }
}

/// A routed message.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub src: NodeId,
    pub dst: NodeId,
    pub msg: Msg,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::TaskClass;

    #[test]
    fn wire_bytes_scale_with_payload() {
        let t = TaskDesc::indexed(TaskClass::Gemm, 1, 2, 3);
        let small = Msg::StealReply {
            tasks: vec![t],
            payload_bytes: 0,
        };
        let big = Msg::StealReply {
            tasks: vec![t],
            payload_bytes: 20_000,
        };
        assert!(big.wire_bytes() > small.wire_bytes() + 19_000);
    }

    #[test]
    fn control_messages_are_not_basic() {
        assert!(Msg::Activate {
            task: TaskDesc::indexed(TaskClass::Potrf, 0, 0, 0)
        }
        .is_basic());
        assert!(Msg::ActivateBatch { tasks: vec![] }.is_basic());
        assert!(!Msg::Shutdown.is_basic());
    }

    #[test]
    fn batched_activations_are_cheaper_than_singletons() {
        let tasks: Vec<TaskDesc> = (0..5)
            .map(|i| TaskDesc::indexed(TaskClass::Gemm, i, 0, 0))
            .collect();
        let batch = Msg::ActivateBatch {
            tasks: tasks.clone(),
        };
        let singles: u64 = tasks
            .iter()
            .map(|t| Msg::Activate { task: *t }.wire_bytes())
            .sum();
        assert!(batch.wire_bytes() < singles, "coalescing must save bytes");
    }
}
