//! Wire messages between runtime domains.

use crate::dataflow::task::{NodeId, TaskDesc};
use crate::faults::FaultMark;
use crate::migrate::EstimateDigest;
use crate::term::SafraToken;

/// Everything that crosses a node boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// One input dependency of `task` (owned by the destination) has been
    /// satisfied by a task completion at the source.
    Activate { task: TaskDesc },
    /// Coalesced activations: one task completion satisfied several
    /// dependencies owned by the same destination, shipped as one
    /// message (one header, one Safra deficit entry, one tracker lock at
    /// the receiver) instead of one `Activate` per edge.
    ActivateBatch { tasks: Vec<TaskDesc> },
    /// Thief -> victim: the thief detected starvation and asks for work.
    /// `req` is the thief's monotonically-seeded request id (thief id in
    /// the high bits, per-thief counter in the low bits): the reply
    /// echoes it, so under `--faults` the thief can match replies to
    /// outstanding requests, suppress duplicates and time out the rest.
    /// It rides in the existing 16-byte header (wire-free).
    StealRequest { thief: NodeId, req: u64 },
    /// Victim -> thief: migrated tasks (empty = steal failed). Each task
    /// is *recreated* at the thief with the same uid; `payload_bytes` is
    /// the size of the input data copied along (drives the link model).
    /// Under `--share-estimates` a granted reply also carries the
    /// victim's [`EstimateDigest`] — its execution-time knowledge
    /// travels with the stolen work and seeds the thief's estimator
    /// tables (merged via `migrate::merge_estimate`); the digest's wire
    /// cost is accounted in [`Msg::wire_bytes`].
    ///
    /// An empty reply distinguishes *why* it is empty:
    /// `denied_by_waiting_time` is true when the victim had stealable
    /// tasks but its waiting-time gate refused, false when its queue
    /// was simply empty. Thieves feed the distinction to the targeted
    /// victim selector (`migrate::VictimSelector`) and the per-victim
    /// outcome telemetry. The flag is a single bit riding in the
    /// 16-byte reply header, so the wire model is unchanged.
    StealReply {
        /// Echo of the originating [`Msg::StealRequest`] id (wire-free,
        /// rides in the reply header like the denial flag).
        req: u64,
        tasks: Vec<TaskDesc>,
        payload_bytes: u64,
        digest: Option<EstimateDigest>,
        denied_by_waiting_time: bool,
    },
    /// Thief -> victim: transfer handshake for request `req`
    /// (`--faults` only). `accepted = true` acknowledges a granted
    /// reply — the victim retires the matching transfer-ledger entry;
    /// `accepted = false` is a nack sent when the thief timed out and
    /// abandoned the request — the victim reclaims the ledger entry's
    /// tasks into its own queue. Priced like a request header.
    TransferAck { req: u64, accepted: bool },
    /// Crash recovery (leader -> rehash survivor): ready tasks swept
    /// from a dead node's queue, executing set, transfer ledger or
    /// orphan bin, re-injected for direct enqueue — their dependencies
    /// were already satisfied on the dead node, so they bypass the
    /// activation tracker. Basic on purpose: re-injection must blacken
    /// the receiver and count in the Safra deficit, or a token that
    /// already passed the survivor could declare termination with the
    /// recovered work still queued.
    Recover { tasks: Vec<TaskDesc> },
    /// Idle-period heartbeat to the leader's failure detector
    /// (`--faults crash-*` only). Control traffic like the token: not
    /// counted by Safra, never faulted by the plan.
    Ping,
    /// Safra termination-detection token, traveling the ring.
    Token(SafraToken),
    /// Leader -> all: distributed termination detected, shut down.
    Shutdown,
}

impl Msg {
    /// Wire size of an activation carrying `n` satisfied dependencies:
    /// a standalone `Activate` is 32 bytes; a batch amortizes one
    /// 16-byte header over 24-byte packed descriptors. The DES uses
    /// this directly so both runtimes share one wire model.
    pub fn activation_wire_bytes(n: usize) -> u64 {
        if n <= 1 {
            32
        } else {
            16 + 24 * n as u64
        }
    }

    /// Wire size of a steal reply carrying `tasks` task descriptors,
    /// `payload_bytes` of input data and (under `--share-estimates`)
    /// the victim's estimate digest: one 16-byte header, 32 bytes per
    /// recreated descriptor, the payload itself, and the digest's
    /// seeded entries. The DES uses this directly so both runtimes
    /// share one wire model for the whole steal path.
    pub fn steal_reply_wire_bytes(
        tasks: usize,
        payload_bytes: u64,
        digest: Option<&EstimateDigest>,
    ) -> u64 {
        16 + 32 * tasks as u64 + payload_bytes + digest.map_or(0, EstimateDigest::wire_bytes)
    }

    /// Approximate wire size (drives the latency/bandwidth model).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Msg::Activate { .. } => Self::activation_wire_bytes(1),
            Msg::ActivateBatch { tasks } => Self::activation_wire_bytes(tasks.len()),
            Msg::StealRequest { .. } => 16,
            Msg::StealReply {
                tasks,
                payload_bytes,
                digest,
                ..
            } => Self::steal_reply_wire_bytes(tasks.len(), *payload_bytes, digest.as_ref()),
            Msg::TransferAck { .. } => 16,
            // Recovered tasks re-enter as packed descriptors under one
            // header, priced like a same-sized activation batch.
            Msg::Recover { tasks } => 16 + 24 * tasks.len() as u64,
            Msg::Ping => 16,
            Msg::Token(_) => 24,
            Msg::Shutdown => 8,
        }
    }

    /// Safra counts "basic" messages (application traffic); control
    /// messages (token, ping, shutdown) are excluded from the message
    /// deficit.
    pub fn is_basic(&self) -> bool {
        !matches!(self, Msg::Token(_) | Msg::Shutdown | Msg::Ping)
    }
}

/// A routed message.
#[derive(Clone, Debug)]
pub struct Envelope {
    pub src: NodeId,
    pub dst: NodeId,
    pub msg: Msg,
    /// `--faults` verdict stamped by the fabric (default
    /// [`FaultMark::None`]); see [`FaultMark`] for the receive-side
    /// contract that keeps Safra's message accounting balanced.
    pub fault: FaultMark,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::TaskClass;

    #[test]
    fn wire_bytes_scale_with_payload() {
        let t = TaskDesc::indexed(TaskClass::Gemm, 1, 2, 3);
        let small = Msg::StealReply {
            req: 1,
            tasks: vec![t],
            payload_bytes: 0,
            digest: None,
            denied_by_waiting_time: false,
        };
        let big = Msg::StealReply {
            req: 2,
            tasks: vec![t],
            payload_bytes: 20_000,
            digest: None,
            denied_by_waiting_time: false,
        };
        assert!(big.wire_bytes() > small.wire_bytes() + 19_000);
    }

    #[test]
    fn steal_reply_accounts_digest_wire_cost() {
        let t = TaskDesc::indexed(TaskClass::Gemm, 1, 2, 3);
        let mut digest = EstimateDigest {
            avg_us: 120.0,
            avg_samples: 9,
            class_est_us: [0.0; TaskClass::COUNT],
            class_samples: [0; TaskClass::COUNT],
        };
        digest.class_est_us[TaskClass::Gemm.idx()] = 300.0;
        digest.class_samples[TaskClass::Gemm.idx()] = 9;
        let bare = Msg::StealReply {
            req: 7,
            tasks: vec![t],
            payload_bytes: 512,
            digest: None,
            denied_by_waiting_time: false,
        };
        let shared = Msg::StealReply {
            req: 7,
            tasks: vec![t],
            payload_bytes: 512,
            digest: Some(digest),
            denied_by_waiting_time: false,
        };
        assert_eq!(
            shared.wire_bytes(),
            bare.wire_bytes() + digest.wire_bytes(),
            "the digest is not free on the wire"
        );
        assert_eq!(
            shared.wire_bytes(),
            Msg::steal_reply_wire_bytes(1, 512, Some(&digest)),
            "the shared helper is the single wire model"
        );
        assert!(shared.is_basic(), "a digest-carrying reply is still basic");
    }

    #[test]
    fn denial_flag_and_request_id_are_wire_free() {
        // The outcome tag and the request id ride in the existing
        // 16-byte header.
        let empty = |req, denied| Msg::StealReply {
            req,
            tasks: vec![],
            payload_bytes: 0,
            digest: None,
            denied_by_waiting_time: denied,
        };
        assert_eq!(empty(0, true).wire_bytes(), empty(0, false).wire_bytes());
        assert_eq!(
            empty(0, false).wire_bytes(),
            empty(u64::MAX, false).wire_bytes()
        );
        assert!(empty(0, true).is_basic(), "denials still count for Safra");
        assert_eq!(
            Msg::StealRequest {
                thief: NodeId(0),
                req: u64::MAX
            }
            .wire_bytes(),
            16,
            "request id rides in the 16-byte request header"
        );
    }

    #[test]
    fn transfer_ack_is_a_basic_16_byte_message() {
        for accepted in [false, true] {
            let ack = Msg::TransferAck { req: 42, accepted };
            assert_eq!(ack.wire_bytes(), 16, "priced like a request header");
            assert!(
                ack.is_basic(),
                "acks are application traffic: Safra must count them"
            );
        }
    }

    #[test]
    fn control_messages_are_not_basic() {
        assert!(Msg::Activate {
            task: TaskDesc::indexed(TaskClass::Potrf, 0, 0, 0)
        }
        .is_basic());
        assert!(Msg::ActivateBatch { tasks: vec![] }.is_basic());
        assert!(!Msg::Shutdown.is_basic());
        assert!(!Msg::Ping.is_basic(), "heartbeats are control traffic");
        assert!(
            Msg::Recover { tasks: vec![] }.is_basic(),
            "re-injected work must count in the Safra deficit"
        );
    }

    #[test]
    fn batched_activations_are_cheaper_than_singletons() {
        let tasks: Vec<TaskDesc> = (0..5)
            .map(|i| TaskDesc::indexed(TaskClass::Gemm, i, 0, 0))
            .collect();
        let batch = Msg::ActivateBatch {
            tasks: tasks.clone(),
        };
        let singles: u64 = tasks
            .iter()
            .map(|t| Msg::Activate { task: *t }.wire_bytes())
            .sum();
        assert!(batch.wire_bytes() < singles, "coalescing must save bytes");
    }
}
