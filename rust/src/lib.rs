//! # parsteal — distributed work stealing in a task-based dataflow runtime
//!
//! A from-scratch reproduction of *"Distributed Work Stealing in a
//! Task-Based Dataflow Runtime"* (John, Milthorpe, Strazdins; CS.DC
//! 2022): a PaRSEC-like dataflow runtime with a TTG-style task-graph API,
//! extended with the paper's contribution — a per-node *migrate thread*
//! implementing distributed work stealing with successor-aware thief
//! policies and waiting-time-gated victim policies.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: dataflow engine, node-level
//!   priority schedulers, message-passing fabric, Safra termination
//!   detection, the `migrate` module, workloads, the discrete-event
//!   simulator used for figure regeneration, and the launcher.
//! * **L2/L1 (python/, build time only)** — JAX task bodies composed of
//!   Pallas tile kernels, AOT-lowered to HLO text artifacts.
//! * **runtime bridge** — [`runtime`] loads the artifacts through the
//!   PJRT CPU client and executes them from worker threads; Python never
//!   runs on the request path.
//!
//! See the top-level `README.md` for the CLI quickstart and
//! `docs/ARCHITECTURE.md` for the layer map, the steal-accounting
//! contract and the waiting-time feedback loop.

pub mod comm;
pub mod config;
pub mod dataflow;
pub mod faults;
pub mod figures;
pub mod metrics;
pub mod migrate;
pub mod node;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod stats;
pub mod term;
pub mod topology;
pub mod util;
pub mod workloads;
