//! Run configuration: one struct covering both execution backends, with
//! CLI-flag construction (used by the `repro` launcher, the figure
//! harness and the examples).

use anyhow::Result;

use crate::comm::LinkModel;
use crate::faults::FaultPlan;
use crate::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy, VictimSelect};
use crate::node::ClusterConfig;
use crate::sched::{POOL_FLOOR, SchedBackend};
use crate::sim::SimConfig;
use crate::topology::{StealDomains, Topology};
use crate::util::cli::Args;
use crate::workloads::{CholeskyParams, UtsParams};

/// Which workload a run executes.
#[derive(Clone, Debug, PartialEq)]
pub enum Workload {
    Cholesky(CholeskyParams),
    Uts(UtsParams),
}

/// Full run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub workload: Workload,
    pub workers_per_node: usize,
    pub link: LinkModel,
    pub migrate: MigrateConfig,
    pub seed: u64,
    /// Scheduler backend (`--sched central|sharded|workassist`).
    pub sched: SchedBackend,
    /// Coalesce same-destination activations (`--batch-activations`).
    pub batch_activations: bool,
    /// Sharded steal-pool floor (`--pool-floor`).
    pub pool_floor: usize,
    /// Steal-protocol fault injection (`--faults`, default off).
    pub faults: FaultPlan,
    /// Tiered link model (`--topology`, default flat): per-pair link
    /// parameters for the wire model, timeout formulas and victim
    /// selector in both backends.
    pub topology: Topology,
    /// Steal-domain policy (`--steal-domains flat|hierarchical`).
    pub steal_domains: StealDomains,
}

impl Default for RunConfig {
    /// The empty-flag configuration: `RunConfig::default()` is exactly
    /// `RunConfig::from_args(&Args::parse([]))` — the paper-headline
    /// 200-tile Cholesky on 4 nodes (asserted in the unit tests, so the
    /// two construction paths cannot drift apart).
    fn default() -> Self {
        RunConfig {
            workload: Workload::Cholesky(CholeskyParams {
                tiles: 200,
                tile_size: 50,
                nodes: 4,
                dense_fraction: 0.5,
                seed: 1,
                all_dense: false,
            }),
            workers_per_node: 40,
            link: LinkModel {
                latency_us: 5.0,
                bw_bytes_per_us: 10_000.0,
            },
            migrate: MigrateConfig::default(),
            seed: 1,
            sched: SchedBackend::Central,
            batch_activations: true,
            pool_floor: POOL_FLOOR,
            faults: FaultPlan::default(),
            topology: Topology::flat(),
            steal_domains: StealDomains::Flat,
        }
    }
}

/// Chainable setters (`RunConfig::default().with_seed(7)…`): call
/// sites name only what they change, so adding a config field never
/// again touches every literal in the tree.
impl RunConfig {
    pub fn with_workload(mut self, v: Workload) -> Self {
        self.workload = v;
        self
    }
    pub fn with_workers_per_node(mut self, v: usize) -> Self {
        self.workers_per_node = v;
        self
    }
    pub fn with_link(mut self, v: LinkModel) -> Self {
        self.link = v;
        self
    }
    pub fn with_migrate(mut self, v: MigrateConfig) -> Self {
        self.migrate = v;
        self
    }
    pub fn with_seed(mut self, v: u64) -> Self {
        self.seed = v;
        self
    }
    pub fn with_sched(mut self, v: SchedBackend) -> Self {
        self.sched = v;
        self
    }
    pub fn with_batch_activations(mut self, v: bool) -> Self {
        self.batch_activations = v;
        self
    }
    pub fn with_pool_floor(mut self, v: usize) -> Self {
        self.pool_floor = v;
        self
    }
    pub fn with_faults(mut self, v: FaultPlan) -> Self {
        self.faults = v;
        self
    }
    pub fn with_topology(mut self, v: Topology) -> Self {
        self.topology = v;
        self
    }
    pub fn with_steal_domains(mut self, v: StealDomains) -> Self {
        self.steal_domains = v;
        self
    }
}

impl RunConfig {
    /// Construct from CLI flags. Flags (all optional):
    /// `--workload cholesky|uts --nodes N --workers W --tiles T --tile-size S`
    /// `--dense-fraction F --steal BOOL --victim half|chunk[K]|single`
    /// `--thief ready-only|ready-successors --waiting-time BOOL`
    /// `--exec-ewma BOOL --exec-per-class BOOL --share-estimates BOOL`
    /// `--victim-select uniform|targeted`
    /// `--sched central|sharded|workassist`
    /// `--batch-activations BOOL --pool-floor N`
    /// `--faults SPEC` (e.g. `drop=0.05,delay=3x`; see
    /// [`FaultPlan`] for the grammar),
    /// `--topology SPEC` (e.g.
    /// `socket=4,socket-lat-us=1,socket-bw=40000,cluster-lat-us=20`;
    /// see [`Topology`] for the grammar),
    /// `--steal-domains flat|hierarchical`,
    /// `--latency-us L --bw B --seed X` and the
    /// UTS knobs `--uts-b0/--uts-m/--uts-q/--uts-g`.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let nodes = args.u64_or("nodes", 4)? as u32;
        let seed = args.u64_or("seed", 1)?;
        let workload = match args.str_or("workload", "cholesky").as_str() {
            "uts" => Workload::Uts(UtsParams {
                b0: args.u64_or("uts-b0", 120)? as u32,
                m: args.u64_or("uts-m", 5)? as u32,
                q: args.f64_or("uts-q", 0.200014)?,
                g: args.f64_or("uts-g", 12e6)?,
                seed,
                nodes,
                max_depth: args.u64_or("uts-max-depth", 64)? as u32,
            }),
            _ => Workload::Cholesky(CholeskyParams {
                tiles: args.u64_or("tiles", 200)? as u32,
                tile_size: args.u64_or("tile-size", 50)? as u32,
                nodes,
                dense_fraction: args.f64_or("dense-fraction", 0.5)?,
                seed,
                all_dense: args.bool_or("all-dense", false)?,
            }),
        };
        let migrate = MigrateConfig::default()
            .with_enabled(args.bool_or("steal", true)?)
            .with_thief(
                args.str_or("thief", "ready-successors")
                    .parse::<ThiefPolicy>()
                    .map_err(anyhow::Error::msg)?,
            )
            .with_victim(
                args.str_or("victim", "single")
                    .parse::<VictimPolicy>()
                    .map_err(anyhow::Error::msg)?,
            )
            .with_use_waiting_time(args.bool_or("waiting-time", true)?)
            .with_poll_interval_us(args.f64_or("poll-interval-us", 100.0)?)
            .with_max_inflight(args.u64_or("max-inflight", 1)? as usize)
            .with_migrate_overhead_us(args.f64_or("migrate-overhead-us", 150.0)?)
            // Off = the paper's running-mean estimator (§3); on = gate
            // on an EWMA of observed execution times.
            .with_exec_ewma(args.bool_or("exec-ewma", false)?)
            // Off = one node-wide estimate; on = per-TaskClass table
            // and a queue-composition-weighted waiting time.
            .with_exec_per_class(args.bool_or("exec-per-class", false)?)
            // Off = per-node estimators only (paper-faithful); on =
            // granted steal replies carry the victim's estimate digest
            // and thieves merge it into their tables.
            .with_share_estimates(args.bool_or("share-estimates", false)?)
            // Uniform = the paper's random victim choice; targeted =
            // score victims on decayed steal-outcome history, digest
            // richness and modeled round-trip cost (PR 6).
            .with_victim_select(
                args.str_or("victim-select", "uniform")
                    .parse::<VictimSelect>()
                    .map_err(anyhow::Error::msg)?,
            );
        Ok(RunConfig::default()
            .with_workload(workload)
            .with_workers_per_node(args.u64_or("workers", 40)? as usize)
            .with_link(LinkModel {
                latency_us: args.f64_or("latency-us", 5.0)?,
                bw_bytes_per_us: args.f64_or("bw", 10_000.0)?,
            })
            .with_migrate(migrate)
            .with_seed(seed)
            .with_sched(
                args.str_or("sched", "central")
                    .parse::<SchedBackend>()
                    .map_err(anyhow::Error::msg)?,
            )
            .with_batch_activations(args.bool_or("batch-activations", true)?)
            .with_pool_floor(args.u64_or("pool-floor", POOL_FLOOR as u64)? as usize)
            .with_faults(
                args.str_or("faults", "off")
                    .parse::<FaultPlan>()
                    .map_err(anyhow::Error::msg)?,
            )
            .with_topology(
                args.str_or("topology", "flat")
                    .parse::<Topology>()
                    .map_err(anyhow::Error::msg)?,
            )
            .with_steal_domains(
                args.str_or("steal-domains", "flat")
                    .parse::<StealDomains>()
                    .map_err(anyhow::Error::msg)?,
            ))
    }

    pub fn nodes(&self) -> u32 {
        match &self.workload {
            Workload::Cholesky(p) => p.nodes,
            Workload::Uts(p) => p.nodes,
        }
    }

    pub fn tile_size(&self) -> u32 {
        match &self.workload {
            Workload::Cholesky(p) => p.tile_size,
            Workload::Uts(_) => 0,
        }
    }

    pub fn sim_config(&self) -> SimConfig {
        SimConfig::default()
            .with_workers_per_node(self.workers_per_node)
            .with_link(self.link)
            .with_seed(self.seed)
            .with_max_events(u64::MAX)
            .with_record_polls(true)
            .with_sched(self.sched)
            .with_batch_activations(self.batch_activations)
            .with_pool_floor(self.pool_floor)
            .with_faults(self.faults)
            .with_topology(self.topology)
            .with_steal_domains(self.steal_domains)
    }

    /// [`ClusterConfig`] for the threaded backend, mirroring
    /// [`RunConfig::sim_config`] so both backends honour the same flags.
    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig::default()
            .with_workers_per_node(self.workers_per_node)
            .with_link(self.link)
            .with_migrate(self.migrate)
            .with_seed(self.seed)
            .with_record_polls(true)
            .with_sched(self.sched)
            .with_batch_activations(self.batch_activations)
            .with_pool_floor(self.pool_floor)
            .with_faults(self.faults)
            .with_topology(self.topology)
            .with_steal_domains(self.steal_domains)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn defaults_match_paper_headline() {
        let c = RunConfig::from_args(&args("")).unwrap();
        let Workload::Cholesky(p) = &c.workload else {
            panic!()
        };
        assert_eq!((p.tiles, p.tile_size, p.nodes), (200, 50, 4));
        assert_eq!(p.dense_fraction, 0.5);
        assert_eq!(c.workers_per_node, 40);
        assert!(c.migrate.enabled && c.migrate.use_waiting_time);
        assert_eq!(c.migrate.victim, VictimPolicy::Single);
    }

    #[test]
    fn uts_flags() {
        let c = RunConfig::from_args(&args(
            "--workload uts --uts-b0 64 --uts-q 0.3 --nodes 2 --steal false",
        ))
        .unwrap();
        let Workload::Uts(p) = &c.workload else { panic!() };
        assert_eq!(p.b0, 64);
        assert_eq!(p.q, 0.3);
        assert_eq!(p.nodes, 2);
        assert!(!c.migrate.enabled);
    }

    #[test]
    fn victim_policy_flag() {
        let c = RunConfig::from_args(&args("--victim chunk8 --thief ready-only")).unwrap();
        assert_eq!(c.migrate.victim, VictimPolicy::Chunk(8));
        assert_eq!(c.migrate.thief, ThiefPolicy::ReadyOnly);
    }

    #[test]
    fn bad_policy_errors() {
        assert!(RunConfig::from_args(&args("--victim bogus")).is_err());
    }

    #[test]
    fn sched_backend_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(c.sched, SchedBackend::Central, "central is the default");
        let c = RunConfig::from_args(&args("--sched sharded")).unwrap();
        assert_eq!(c.sched, SchedBackend::Sharded);
        assert_eq!(c.sim_config().sched, SchedBackend::Sharded);
        let c = RunConfig::from_args(&args("--sched workassist")).unwrap();
        assert_eq!(c.sched, SchedBackend::Workassist);
        assert_eq!(c.sim_config().sched, SchedBackend::Workassist);
        let c = RunConfig::from_args(&args("--sched lockfree")).unwrap();
        assert_eq!(c.sched, SchedBackend::Workassist, "alias accepted");
        assert!(RunConfig::from_args(&args("--sched bogus")).is_err());
    }

    #[test]
    fn exec_ewma_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.migrate.exec_ewma, "paper-faithful running mean by default");
        let c = RunConfig::from_args(&args("--exec-ewma true")).unwrap();
        assert!(c.migrate.exec_ewma);
    }

    #[test]
    fn exec_per_class_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.migrate.exec_per_class, "node-wide estimator by default");
        let c = RunConfig::from_args(&args("--exec-per-class true")).unwrap();
        assert!(c.migrate.exec_per_class);
    }

    #[test]
    fn share_estimates_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(
            !c.migrate.share_estimates,
            "per-node estimators by default"
        );
        assert!(!c.migrate.track_per_class());
        let c = RunConfig::from_args(&args("--share-estimates true")).unwrap();
        assert!(c.migrate.share_estimates);
        assert!(
            c.migrate.track_per_class(),
            "sharing keeps the class table maintained even without --exec-per-class"
        );
    }

    #[test]
    fn victim_select_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(
            c.migrate.victim_select,
            VictimSelect::Uniform,
            "paper-faithful uniform choice by default"
        );
        let c = RunConfig::from_args(&args("--victim-select targeted")).unwrap();
        assert_eq!(c.migrate.victim_select, VictimSelect::Targeted);
        assert!(RunConfig::from_args(&args("--victim-select bogus")).is_err());
    }

    #[test]
    fn pool_floor_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(c.pool_floor, POOL_FLOOR, "default pool floor");
        assert_eq!(c.sim_config().pool_floor, POOL_FLOOR);
        let c = RunConfig::from_args(&args("--pool-floor 7")).unwrap();
        assert_eq!(c.pool_floor, 7);
        assert_eq!(c.sim_config().pool_floor, 7);
        let c = RunConfig::from_args(&args("--pool-floor 0")).unwrap();
        assert_eq!(c.pool_floor, 0, "0 disables restocking");
    }

    #[test]
    fn faults_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.faults.enabled, "reliable fabric by default");
        assert!(!c.sim_config().faults.enabled);
        let c = RunConfig::from_args(&args("--faults drop=0.05,delay=3x")).unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.drop_reply, 0.05);
        assert_eq!(c.faults.delay_factor, 3.0);
        assert_eq!(c.sim_config().faults, c.faults);
        let c = RunConfig::from_args(&args("--faults crash-node=2,crash-at-us=1500,drop=0.01"))
            .unwrap();
        assert!(c.faults.enabled && c.faults.has_crash());
        assert_eq!(c.faults.crash_node, Some(2));
        assert_eq!(c.faults.crash_at_us, 1500.0);
        assert_eq!(c.sim_config().faults, c.faults);
        let c = RunConfig::from_args(&args("--faults crash-p=0.5")).unwrap();
        assert!(c.faults.has_crash());
        assert_eq!(c.faults.crash_p, 0.5);
        assert!(RunConfig::from_args(&args("--faults bogus=1")).is_err());
    }

    #[test]
    fn batch_activations_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(c.batch_activations, "batching is the default");
        assert!(c.sim_config().batch_activations);
        let c = RunConfig::from_args(&args("--batch-activations false")).unwrap();
        assert!(!c.batch_activations);
        assert!(!c.sim_config().batch_activations);
    }

    #[test]
    fn topology_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(c.topology.is_flat(), "flat fabric by default");
        assert!(c.sim_config().topology.is_flat());
        let c = RunConfig::from_args(&args(
            "--topology socket=4,socket-lat-us=1,socket-bw=40000,cluster-lat-us=20",
        ))
        .unwrap();
        assert!(!c.topology.is_flat());
        assert_eq!(c.topology.socket_size, 4);
        assert_eq!(c.topology.socket_lat_us, 1.0);
        assert_eq!(c.sim_config().topology, c.topology);
        // The label round-trips back through the parser.
        let back: Topology = c.topology.label().parse().unwrap();
        assert_eq!(back, c.topology);
        assert!(RunConfig::from_args(&args("--topology socket=bogus")).is_err());
        assert!(
            RunConfig::from_args(&args("--topology socket=4,rack=6")).is_err(),
            "tiers must nest"
        );
    }

    #[test]
    fn steal_domains_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(c.steal_domains, StealDomains::Flat, "flat by default");
        let c = RunConfig::from_args(&args("--steal-domains hierarchical")).unwrap();
        assert_eq!(c.steal_domains, StealDomains::Hierarchical);
        assert_eq!(c.sim_config().steal_domains, StealDomains::Hierarchical);
        let c = RunConfig::from_args(&args("--steal-domains hier")).unwrap();
        assert_eq!(c.steal_domains, StealDomains::Hierarchical, "alias");
        assert!(RunConfig::from_args(&args("--steal-domains bogus")).is_err());
    }

    /// `RunConfig::default()` and the empty flag set are the same
    /// configuration — the builder base can never drift from the CLI
    /// defaults without this failing.
    #[test]
    fn default_builder_matches_empty_flags() {
        let d = RunConfig::default();
        let f = RunConfig::from_args(&args("")).unwrap();
        let Workload::Cholesky(dp) = &d.workload else {
            panic!()
        };
        let Workload::Cholesky(fp) = &f.workload else {
            panic!()
        };
        assert_eq!(dp, fp);
        assert_eq!(d.workers_per_node, f.workers_per_node);
        assert_eq!(d.link, f.link);
        assert_eq!(d.migrate, f.migrate);
        assert_eq!(d.seed, f.seed);
        assert_eq!(d.sched, f.sched);
        assert_eq!(d.batch_activations, f.batch_activations);
        assert_eq!(d.pool_floor, f.pool_floor);
        assert_eq!(d.faults, f.faults);
        assert_eq!(d.topology, f.topology);
        assert_eq!(d.steal_domains, f.steal_domains);
    }

    #[test]
    fn builder_setters_equal_exhaustive_literal() {
        // The one place a full RunConfig literal is allowed to live:
        // the builders' own equivalence check.
        let workload = Workload::Uts(UtsParams {
            b0: 32,
            m: 4,
            q: 0.2,
            g: 1_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 12,
        });
        let link = LinkModel {
            latency_us: 4.0,
            bw_bytes_per_us: 2_000.0,
        };
        let migrate = MigrateConfig::default().with_max_inflight(2);
        let faults: FaultPlan = "drop=0.05".parse().unwrap();
        let topology: Topology = "socket=3,socket-lat-us=2".parse().unwrap();
        let built = RunConfig::default()
            .with_workload(workload.clone())
            .with_workers_per_node(6)
            .with_link(link)
            .with_migrate(migrate)
            .with_seed(77)
            .with_sched(SchedBackend::Sharded)
            .with_batch_activations(false)
            .with_pool_floor(3)
            .with_faults(faults)
            .with_topology(topology)
            .with_steal_domains(StealDomains::Hierarchical);
        let literal = RunConfig {
            workload,
            workers_per_node: 6,
            link,
            migrate,
            seed: 77,
            sched: SchedBackend::Sharded,
            batch_activations: false,
            pool_floor: 3,
            faults,
            topology,
            steal_domains: StealDomains::Hierarchical,
        };
        assert_eq!(built, literal);
    }

    /// The two backend-config projections agree on every shared knob,
    /// so `--backend real` and the DES can never silently diverge on
    /// the same flag set.
    #[test]
    fn sim_and_cluster_projections_agree() {
        let c = RunConfig::from_args(&args(
            "--workers 3 --seed 9 --sched sharded --pool-floor 5 \
             --topology socket=2,socket-lat-us=1 --steal-domains hierarchical",
        ))
        .unwrap();
        let s = c.sim_config();
        let k = c.cluster_config();
        assert_eq!(s.workers_per_node, k.workers_per_node);
        assert_eq!(s.link, k.link);
        assert_eq!(s.seed, k.seed);
        assert_eq!(s.sched, k.sched);
        assert_eq!(s.batch_activations, k.batch_activations);
        assert_eq!(s.pool_floor, k.pool_floor);
        assert_eq!(s.faults, k.faults);
        assert_eq!(s.topology, k.topology);
        assert_eq!(s.steal_domains, k.steal_domains);
        assert_eq!(k.migrate, c.migrate);
        assert_eq!(k.topology, c.topology);
        assert_eq!(k.steal_domains, StealDomains::Hierarchical);
    }
}
