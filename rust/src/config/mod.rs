//! Run configuration: one struct covering both execution backends, with
//! CLI-flag construction (used by the `repro` launcher, the figure
//! harness and the examples).

use anyhow::Result;

use crate::comm::LinkModel;
use crate::faults::FaultPlan;
use crate::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy, VictimSelect};
use crate::sched::{POOL_FLOOR, SchedBackend};
use crate::sim::SimConfig;
use crate::util::cli::Args;
use crate::workloads::{CholeskyParams, UtsParams};

/// Which workload a run executes.
#[derive(Clone, Debug)]
pub enum Workload {
    Cholesky(CholeskyParams),
    Uts(UtsParams),
}

/// Full run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub workload: Workload,
    pub workers_per_node: usize,
    pub link: LinkModel,
    pub migrate: MigrateConfig,
    pub seed: u64,
    /// Scheduler backend (`--sched central|sharded|workassist`).
    pub sched: SchedBackend,
    /// Coalesce same-destination activations (`--batch-activations`).
    pub batch_activations: bool,
    /// Sharded steal-pool floor (`--pool-floor`).
    pub pool_floor: usize,
    /// Steal-protocol fault injection (`--faults`, default off).
    pub faults: FaultPlan,
}

impl RunConfig {
    /// Construct from CLI flags. Flags (all optional):
    /// `--workload cholesky|uts --nodes N --workers W --tiles T --tile-size S`
    /// `--dense-fraction F --steal BOOL --victim half|chunk[K]|single`
    /// `--thief ready-only|ready-successors --waiting-time BOOL`
    /// `--exec-ewma BOOL --exec-per-class BOOL --share-estimates BOOL`
    /// `--victim-select uniform|targeted`
    /// `--sched central|sharded|workassist`
    /// `--batch-activations BOOL --pool-floor N`
    /// `--faults SPEC` (e.g. `drop=0.05,delay=3x`; see
    /// [`FaultPlan`] for the grammar),
    /// `--latency-us L --bw B --seed X` and the
    /// UTS knobs `--uts-b0/--uts-m/--uts-q/--uts-g`.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let nodes = args.u64_or("nodes", 4)? as u32;
        let seed = args.u64_or("seed", 1)?;
        let workload = match args.str_or("workload", "cholesky").as_str() {
            "uts" => Workload::Uts(UtsParams {
                b0: args.u64_or("uts-b0", 120)? as u32,
                m: args.u64_or("uts-m", 5)? as u32,
                q: args.f64_or("uts-q", 0.200014)?,
                g: args.f64_or("uts-g", 12e6)?,
                seed,
                nodes,
                max_depth: args.u64_or("uts-max-depth", 64)? as u32,
            }),
            _ => Workload::Cholesky(CholeskyParams {
                tiles: args.u64_or("tiles", 200)? as u32,
                tile_size: args.u64_or("tile-size", 50)? as u32,
                nodes,
                dense_fraction: args.f64_or("dense-fraction", 0.5)?,
                seed,
                all_dense: args.bool_or("all-dense", false)?,
            }),
        };
        let migrate = MigrateConfig {
            enabled: args.bool_or("steal", true)?,
            thief: args
                .str_or("thief", "ready-successors")
                .parse::<ThiefPolicy>()
                .map_err(anyhow::Error::msg)?,
            victim: args
                .str_or("victim", "single")
                .parse::<VictimPolicy>()
                .map_err(anyhow::Error::msg)?,
            use_waiting_time: args.bool_or("waiting-time", true)?,
            poll_interval_us: args.f64_or("poll-interval-us", 100.0)?,
            max_inflight: args.u64_or("max-inflight", 1)? as usize,
            migrate_overhead_us: args.f64_or("migrate-overhead-us", 150.0)?,
            // Off = the paper's running-mean estimator (§3); on = gate
            // on an EWMA of observed execution times.
            exec_ewma: args.bool_or("exec-ewma", false)?,
            // Off = one node-wide estimate; on = per-TaskClass table
            // and a queue-composition-weighted waiting time.
            exec_per_class: args.bool_or("exec-per-class", false)?,
            // Off = per-node estimators only (paper-faithful); on =
            // granted steal replies carry the victim's estimate digest
            // and thieves merge it into their tables.
            share_estimates: args.bool_or("share-estimates", false)?,
            // Uniform = the paper's random victim choice; targeted =
            // score victims on decayed steal-outcome history, digest
            // richness and modeled round-trip cost (PR 6).
            victim_select: args
                .str_or("victim-select", "uniform")
                .parse::<VictimSelect>()
                .map_err(anyhow::Error::msg)?,
        };
        Ok(RunConfig {
            workload,
            workers_per_node: args.u64_or("workers", 40)? as usize,
            link: LinkModel {
                latency_us: args.f64_or("latency-us", 5.0)?,
                bw_bytes_per_us: args.f64_or("bw", 10_000.0)?,
            },
            migrate,
            seed,
            sched: args
                .str_or("sched", "central")
                .parse::<SchedBackend>()
                .map_err(anyhow::Error::msg)?,
            batch_activations: args.bool_or("batch-activations", true)?,
            pool_floor: args.u64_or("pool-floor", POOL_FLOOR as u64)? as usize,
            faults: args
                .str_or("faults", "off")
                .parse::<FaultPlan>()
                .map_err(anyhow::Error::msg)?,
        })
    }

    pub fn nodes(&self) -> u32 {
        match &self.workload {
            Workload::Cholesky(p) => p.nodes,
            Workload::Uts(p) => p.nodes,
        }
    }

    pub fn tile_size(&self) -> u32 {
        match &self.workload {
            Workload::Cholesky(p) => p.tile_size,
            Workload::Uts(_) => 0,
        }
    }

    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            workers_per_node: self.workers_per_node,
            link: self.link,
            seed: self.seed,
            max_events: u64::MAX,
            record_polls: true,
            sched: self.sched,
            batch_activations: self.batch_activations,
            pool_floor: self.pool_floor,
            faults: self.faults,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn defaults_match_paper_headline() {
        let c = RunConfig::from_args(&args("")).unwrap();
        let Workload::Cholesky(p) = &c.workload else {
            panic!()
        };
        assert_eq!((p.tiles, p.tile_size, p.nodes), (200, 50, 4));
        assert_eq!(p.dense_fraction, 0.5);
        assert_eq!(c.workers_per_node, 40);
        assert!(c.migrate.enabled && c.migrate.use_waiting_time);
        assert_eq!(c.migrate.victim, VictimPolicy::Single);
    }

    #[test]
    fn uts_flags() {
        let c = RunConfig::from_args(&args(
            "--workload uts --uts-b0 64 --uts-q 0.3 --nodes 2 --steal false",
        ))
        .unwrap();
        let Workload::Uts(p) = &c.workload else { panic!() };
        assert_eq!(p.b0, 64);
        assert_eq!(p.q, 0.3);
        assert_eq!(p.nodes, 2);
        assert!(!c.migrate.enabled);
    }

    #[test]
    fn victim_policy_flag() {
        let c = RunConfig::from_args(&args("--victim chunk8 --thief ready-only")).unwrap();
        assert_eq!(c.migrate.victim, VictimPolicy::Chunk(8));
        assert_eq!(c.migrate.thief, ThiefPolicy::ReadyOnly);
    }

    #[test]
    fn bad_policy_errors() {
        assert!(RunConfig::from_args(&args("--victim bogus")).is_err());
    }

    #[test]
    fn sched_backend_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(c.sched, SchedBackend::Central, "central is the default");
        let c = RunConfig::from_args(&args("--sched sharded")).unwrap();
        assert_eq!(c.sched, SchedBackend::Sharded);
        assert_eq!(c.sim_config().sched, SchedBackend::Sharded);
        let c = RunConfig::from_args(&args("--sched workassist")).unwrap();
        assert_eq!(c.sched, SchedBackend::Workassist);
        assert_eq!(c.sim_config().sched, SchedBackend::Workassist);
        let c = RunConfig::from_args(&args("--sched lockfree")).unwrap();
        assert_eq!(c.sched, SchedBackend::Workassist, "alias accepted");
        assert!(RunConfig::from_args(&args("--sched bogus")).is_err());
    }

    #[test]
    fn exec_ewma_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.migrate.exec_ewma, "paper-faithful running mean by default");
        let c = RunConfig::from_args(&args("--exec-ewma true")).unwrap();
        assert!(c.migrate.exec_ewma);
    }

    #[test]
    fn exec_per_class_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.migrate.exec_per_class, "node-wide estimator by default");
        let c = RunConfig::from_args(&args("--exec-per-class true")).unwrap();
        assert!(c.migrate.exec_per_class);
    }

    #[test]
    fn share_estimates_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(
            !c.migrate.share_estimates,
            "per-node estimators by default"
        );
        assert!(!c.migrate.track_per_class());
        let c = RunConfig::from_args(&args("--share-estimates true")).unwrap();
        assert!(c.migrate.share_estimates);
        assert!(
            c.migrate.track_per_class(),
            "sharing keeps the class table maintained even without --exec-per-class"
        );
    }

    #[test]
    fn victim_select_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(
            c.migrate.victim_select,
            VictimSelect::Uniform,
            "paper-faithful uniform choice by default"
        );
        let c = RunConfig::from_args(&args("--victim-select targeted")).unwrap();
        assert_eq!(c.migrate.victim_select, VictimSelect::Targeted);
        assert!(RunConfig::from_args(&args("--victim-select bogus")).is_err());
    }

    #[test]
    fn pool_floor_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert_eq!(c.pool_floor, POOL_FLOOR, "default pool floor");
        assert_eq!(c.sim_config().pool_floor, POOL_FLOOR);
        let c = RunConfig::from_args(&args("--pool-floor 7")).unwrap();
        assert_eq!(c.pool_floor, 7);
        assert_eq!(c.sim_config().pool_floor, 7);
        let c = RunConfig::from_args(&args("--pool-floor 0")).unwrap();
        assert_eq!(c.pool_floor, 0, "0 disables restocking");
    }

    #[test]
    fn faults_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(!c.faults.enabled, "reliable fabric by default");
        assert!(!c.sim_config().faults.enabled);
        let c = RunConfig::from_args(&args("--faults drop=0.05,delay=3x")).unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.drop_reply, 0.05);
        assert_eq!(c.faults.delay_factor, 3.0);
        assert_eq!(c.sim_config().faults, c.faults);
        let c = RunConfig::from_args(&args("--faults crash-node=2,crash-at-us=1500,drop=0.01"))
            .unwrap();
        assert!(c.faults.enabled && c.faults.has_crash());
        assert_eq!(c.faults.crash_node, Some(2));
        assert_eq!(c.faults.crash_at_us, 1500.0);
        assert_eq!(c.sim_config().faults, c.faults);
        let c = RunConfig::from_args(&args("--faults crash-p=0.5")).unwrap();
        assert!(c.faults.has_crash());
        assert_eq!(c.faults.crash_p, 0.5);
        assert!(RunConfig::from_args(&args("--faults bogus=1")).is_err());
    }

    #[test]
    fn batch_activations_flag() {
        let c = RunConfig::from_args(&args("")).unwrap();
        assert!(c.batch_activations, "batching is the default");
        assert!(c.sim_config().batch_activations);
        let c = RunConfig::from_args(&args("--batch-activations false")).unwrap();
        assert!(!c.batch_activations);
        assert!(!c.sim_config().batch_activations);
    }
}
