//! Tile data plane for real-mode execution.
//!
//! Tiles are square `f64` blocks (the paper's 64-bit elements). The
//! [`TileStore`] is logically partitioned across nodes — each tile has a
//! home node from the cyclic distribution — and physically shared inside
//! this process (the transport cost of remote reads is modeled by the
//! comm latency layer; see DESIGN.md substitution table). Per-tile locks
//! serialize access; the DAG guarantees a single writer at a time.

use std::sync::Mutex;

use crate::util::hash::FxHashMap;

use super::task::NodeId;

/// A square f64 tile.
#[derive(Clone, Debug, PartialEq)]
pub struct Tile {
    pub n: usize,
    pub data: Vec<f64>,
}

impl Tile {
    pub fn zeros(n: usize) -> Self {
        Tile {
            n,
            data: vec![0.0; n * n],
        }
    }

    pub fn identity(n: usize, scale: f64) -> Self {
        let mut t = Tile::zeros(n);
        for i in 0..n {
            t.data[i * n + i] = scale;
        }
        t
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.n + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.n + c] = v;
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f64>()) as u64
    }

    /// Max-abs difference (verification helper).
    pub fn max_abs_diff(&self, other: &Tile) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// `self - a @ b^T` in place (pure-Rust oracle for tests and the
    /// no-PJRT fallback executor).
    pub fn gemm_update(&mut self, a: &Tile, b: &Tile) {
        let n = self.n;
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..a.n {
                    acc += a.at(i, k) * b.at(j, k);
                }
                let v = self.at(i, j) - acc;
                self.set(i, j, v);
            }
        }
    }
}

/// Key identifying one tile of the global matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileKey {
    pub row: u32,
    pub col: u32,
}

/// The distributed tile repository. Tile lookups sit on the kernel
/// dispatch path, so the maps use the FxHash hasher
/// ([`crate::util::hash`]) rather than SipHash.
pub struct TileStore {
    tiles: FxHashMap<TileKey, Mutex<Tile>>,
    homes: FxHashMap<TileKey, NodeId>,
    /// Bytes "transferred" between distinct home nodes (accounting only).
    remote_reads: Mutex<u64>,
}

impl TileStore {
    pub fn new() -> Self {
        Self {
            tiles: FxHashMap::default(),
            homes: FxHashMap::default(),
            remote_reads: Mutex::new(0),
        }
    }

    pub fn insert(&mut self, key: TileKey, home: NodeId, tile: Tile) {
        self.tiles.insert(key, Mutex::new(tile));
        self.homes.insert(key, home);
    }

    pub fn home(&self, key: TileKey) -> Option<NodeId> {
        self.homes.get(&key).copied()
    }

    /// Snapshot a tile's contents (a "receive" when reader != home).
    pub fn read(&self, key: TileKey, reader: NodeId) -> Tile {
        let tile = self.tiles[&key].lock().unwrap().clone();
        if self.homes[&key] != reader {
            *self.remote_reads.lock().unwrap() += tile.bytes();
        }
        tile
    }

    /// Replace a tile's contents.
    pub fn write(&self, key: TileKey, tile: Tile) {
        *self.tiles[&key].lock().unwrap() = tile;
    }

    pub fn contains(&self, key: TileKey) -> bool {
        self.tiles.contains_key(&key)
    }

    pub fn remote_read_bytes(&self) -> u64 {
        *self.remote_reads.lock().unwrap()
    }

    pub fn len(&self) -> usize {
        self.tiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tiles.is_empty()
    }
}

impl Default for TileStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_accessors() {
        let mut t = Tile::zeros(3);
        t.set(1, 2, 5.0);
        assert_eq!(t.at(1, 2), 5.0);
        assert_eq!(t.bytes(), 72);
    }

    #[test]
    fn gemm_update_matches_manual() {
        // c = I(2), a = [[1,2],[3,4]], b = [[1,0],[0,1]] => c - a@b^T = I - a
        let mut c = Tile::identity(2, 1.0);
        let a = Tile {
            n: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let b = Tile::identity(2, 1.0);
        c.gemm_update(&a, &b);
        assert_eq!(c.data, vec![0.0, -2.0, -3.0, -3.0]);
    }

    #[test]
    fn store_tracks_remote_reads() {
        let mut s = TileStore::new();
        let k = TileKey { row: 0, col: 0 };
        s.insert(k, NodeId(0), Tile::zeros(4));
        let _ = s.read(k, NodeId(0));
        assert_eq!(s.remote_read_bytes(), 0);
        let _ = s.read(k, NodeId(1));
        assert_eq!(s.remote_read_bytes(), 128);
        assert_eq!(s.home(k), Some(NodeId(0)));
    }
}
