//! Task identity: classes and descriptors.

use std::fmt;

/// A compute node (one MPI rank in the paper's deployment; in this
/// reproduction an in-process runtime domain with its own scheduler,
/// workers and migrate thread).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Task classes across all built-in workloads. All tasks of a class share
/// properties (body, cost shape, stealability rule) and differ only in
/// their index tuple and input data — exactly PaRSEC's task-class model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum TaskClass {
    /// Cholesky: factorize diagonal tile `(k,k)`.
    Potrf = 0,
    /// Cholesky: panel solve of tile `(i,k)` against `POTRF(k)`.
    Trsm = 1,
    /// Cholesky: symmetric trailing update of `(i,i)` from `(i,k)`.
    Syrk = 2,
    /// Cholesky: trailing update of `(i,j)` from `(i,k)` and `(j,k)`.
    Gemm = 3,
    /// One UTS tree node expansion.
    UtsNode = 4,
    /// Synthetic/user-defined class (dynamic TTG graphs).
    Synthetic = 5,
}

impl TaskClass {
    pub fn name(self) -> &'static str {
        match self {
            TaskClass::Potrf => "POTRF",
            TaskClass::Trsm => "TRSM",
            TaskClass::Syrk => "SYRK",
            TaskClass::Gemm => "GEMM",
            TaskClass::UtsNode => "UTS",
            TaskClass::Synthetic => "SYN",
        }
    }

    pub const ALL: [TaskClass; 6] = [
        TaskClass::Potrf,
        TaskClass::Trsm,
        TaskClass::Syrk,
        TaskClass::Gemm,
        TaskClass::UtsNode,
        TaskClass::Synthetic,
    ];

    /// Number of task classes — the size of every per-class table
    /// (scheduler class counts, the per-class execution-time estimators).
    pub const COUNT: usize = Self::ALL.len();

    /// Discriminant as a table index (`0..TaskClass::COUNT`).
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

/// A task instance: class + index tuple + unique id.
///
/// For the Cholesky DAG the indices are `(i, j, k)` (tile row, tile col,
/// panel); `uid` is a packing of those. For UTS, `uid` is the tree-node
/// hash, `i` the depth and `j` the child index. A stolen task is
/// *recreated* on the thief **with the same uid** (§3 of the paper), so
/// uid equality is task identity across the whole cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskDesc {
    pub class: TaskClass,
    pub i: u32,
    pub j: u32,
    pub k: u32,
    pub uid: u64,
}

impl TaskDesc {
    /// Canonical constructor for statically-indexed (Cholesky-like) tasks:
    /// uid packs (class, i, j, k) so it is unique and deterministic.
    pub fn indexed(class: TaskClass, i: u32, j: u32, k: u32) -> Self {
        let uid = ((class as u64) << 60)
            | ((i as u64 & 0xFFFFF) << 40)
            | ((j as u64 & 0xFFFFF) << 20)
            | (k as u64 & 0xFFFFF);
        TaskDesc { class, i, j, k, uid }
    }

    /// Constructor for dynamically-derived (UTS-like) tasks.
    pub fn dynamic(class: TaskClass, uid: u64, depth: u32, child: u32) -> Self {
        TaskDesc {
            class,
            i: depth,
            j: child,
            k: 0,
            uid,
        }
    }
}

impl fmt::Display for TaskDesc {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.class {
            TaskClass::Potrf => write!(f, "POTRF({})", self.k),
            TaskClass::Trsm => write!(f, "TRSM({},{})", self.i, self.k),
            TaskClass::Syrk => write!(f, "SYRK({},{})", self.i, self.k),
            TaskClass::Gemm => write!(f, "GEMM({},{},{})", self.i, self.j, self.k),
            TaskClass::UtsNode => write!(f, "UTS(d{},#{:x})", self.i, self.uid),
            TaskClass::Synthetic => write!(f, "SYN({},{},{})", self.i, self.j, self.k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexed_uid_unique_across_classes_and_indices() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for class in [TaskClass::Potrf, TaskClass::Trsm, TaskClass::Syrk, TaskClass::Gemm] {
            for i in 0..12 {
                for j in 0..12 {
                    for k in 0..12 {
                        assert!(seen.insert(TaskDesc::indexed(class, i, j, k).uid));
                    }
                }
            }
        }
    }

    #[test]
    fn display_forms() {
        assert_eq!(TaskDesc::indexed(TaskClass::Gemm, 3, 2, 1).to_string(), "GEMM(3,2,1)");
        assert_eq!(TaskDesc::indexed(TaskClass::Potrf, 0, 0, 5).to_string(), "POTRF(5)");
    }

    #[test]
    fn desc_is_copy_and_ord() {
        let a = TaskDesc::indexed(TaskClass::Trsm, 1, 0, 0);
        let b = a;
        assert_eq!(a, b);
        assert!(TaskDesc::indexed(TaskClass::Potrf, 0, 0, 0) < TaskDesc::indexed(TaskClass::Trsm, 0, 0, 0));
    }
}
