//! Activation tracking: turning completed predecessors into ready tasks.
//!
//! Each node owns one `ActivationTracker` for the tasks that will run
//! there. An `Activate(t)` (local or remote) decrements `t`'s remaining
//! input count, lazily initialized from [`TaskGraph::in_degree`]; the
//! transition to zero makes the task ready exactly once. This is the
//! data-driven heart of the dataflow model — there is no global DAG
//! materialization, everything is derived on the fly from the graph's
//! algebraic description, PaRSEC-style.

use crate::util::hash::FxHashMap;
#[cfg(debug_assertions)]
use crate::util::hash::FxHashSet;

use super::task::TaskDesc;
use super::ttg::TaskGraph;

/// Per-node dependency bookkeeping.
///
/// The maps are FxHash-keyed ([`crate::util::hash`]): the tracker is
/// touched once per dependency edge, making it the hottest `TaskDesc`
/// map in the system, and the descriptors are runtime-generated (never
/// attacker-controlled), so SipHash buys nothing. The double-fire set
/// exists only in debug builds — release builds carry no bookkeeping
/// beyond the remaining-count map.
#[derive(Default, Debug)]
pub struct ActivationTracker {
    remaining: FxHashMap<TaskDesc, u32>,
    /// Tasks that reached zero and were handed out (debug-only
    /// double-fire check).
    #[cfg(debug_assertions)]
    fired: FxHashSet<TaskDesc>,
    activations_received: u64,
}

impl ActivationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one satisfied input dependency of `t`. Returns `true` when
    /// this was the last missing input (the task is now ready).
    pub fn activate(&mut self, graph: &dyn TaskGraph, t: TaskDesc) -> bool {
        self.activations_received += 1;
        #[cfg(debug_assertions)]
        assert!(
            !self.fired.contains(&t),
            "activation for already-ready task {t}"
        );
        let entry = self
            .remaining
            .entry(t)
            .or_insert_with(|| graph.in_degree(t).max(1));
        debug_assert!(*entry > 0);
        *entry -= 1;
        if *entry == 0 {
            self.remaining.remove(&t);
            #[cfg(debug_assertions)]
            self.fired.insert(t);
            true
        } else {
            false
        }
    }

    /// Roots have no predecessors; mark them ready without activation.
    pub fn mark_root(&mut self, t: TaskDesc) {
        #[cfg(debug_assertions)]
        self.fired.insert(t);
        #[cfg(not(debug_assertions))]
        let _ = t;
    }

    /// Number of tasks with partially-satisfied dependencies.
    pub fn pending(&self) -> usize {
        self.remaining.len()
    }

    pub fn activations_received(&self) -> u64 {
        self.activations_received
    }

    /// True if no task is waiting on further activations (used by the
    /// termination detector's local-quiescence check).
    pub fn is_quiescent(&self) -> bool {
        self.remaining.is_empty()
    }

    /// Crash recovery: hand over every partially-activated task with the
    /// number of dependency edges already satisfied for it, leaving this
    /// tracker quiescent. The recovery coordinator replays each entry as
    /// `satisfied` activations at the rehash survivor's tracker (whose
    /// lazy in-degree init reproduces the state exactly); the remaining
    /// edges arrive there later via rerouted activations. Sorted by
    /// descriptor so recovery is deterministic regardless of hash order.
    pub fn drain_partial(&mut self, graph: &dyn TaskGraph) -> Vec<(TaskDesc, u32)> {
        let mut out: Vec<(TaskDesc, u32)> = self
            .remaining
            .drain()
            .map(|(t, remaining)| {
                let satisfied = graph.in_degree(t).max(1) - remaining;
                debug_assert!(satisfied > 0, "untouched task in the remaining map");
                (t, satisfied)
            })
            .collect();
        out.sort_unstable_by_key(|(t, _)| *t);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{NodeId, TaskClass};
    use crate::dataflow::ttg::TtgBuilder;

    fn diamond() -> impl TaskGraph {
        // a -> b, a -> c, {b,c} -> d
        let t = |i| TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
        TtgBuilder::new("diamond", 1)
            .with_roots(vec![t(0)])
            .wrap_g(
                "n",
                |_| true,
                move |x| match x.i {
                    0 => vec![t(1), t(2)],
                    1 | 2 => vec![t(3)],
                    _ => vec![],
                },
                |x| match x.i {
                    0 => 0,
                    1 | 2 => 1,
                    _ => 2,
                },
                |_| NodeId(0),
                |_| 1.0,
            )
            .build()
    }

    #[test]
    fn diamond_activates_once() {
        let g = diamond();
        let t = |i| TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
        let mut tr = ActivationTracker::new();
        assert!(tr.activate(&g, t(1)), "in-degree 1 fires immediately");
        assert!(!tr.activate(&g, t(3)), "first of two activations");
        assert_eq!(tr.pending(), 1);
        assert!(tr.activate(&g, t(3)), "second fires");
        assert_eq!(tr.pending(), 0);
        assert!(tr.is_quiescent());
        assert_eq!(tr.activations_received(), 3);
    }

    #[test]
    fn drain_partial_replays_into_a_fresh_tracker() {
        let g = diamond();
        let t = |i| TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
        let mut dead = ActivationTracker::new();
        assert!(!dead.activate(&g, t(3)), "one of two inputs satisfied");
        let partial = dead.drain_partial(&g);
        assert!(dead.is_quiescent(), "the dead tracker is emptied");
        assert_eq!(partial, vec![(t(3), 1)]);
        // Replaying at a survivor reproduces the state: the next (last)
        // activation fires the task exactly once.
        let mut survivor = ActivationTracker::new();
        for (task, satisfied) in partial {
            for _ in 0..satisfied {
                assert!(!survivor.activate(&g, task));
            }
        }
        assert!(survivor.activate(&g, t(3)), "remaining edge fires it");
    }

    #[test]
    #[should_panic(expected = "already-ready")]
    #[cfg(debug_assertions)]
    fn double_fire_detected() {
        let g = diamond();
        let t = TaskDesc::indexed(TaskClass::Synthetic, 1, 0, 0);
        let mut tr = ActivationTracker::new();
        assert!(tr.activate(&g, t));
        let _ = tr.activate(&g, t);
    }
}
