//! The task-based dataflow substrate (PaRSEC-like core).
//!
//! A program is a set of *task classes*; a *task* is an instance of a
//! class identified by its index tuple. Dependencies are derived from the
//! flow of data between tasks ([`TaskGraph::successors`]); a task becomes
//! *ready* when all of its input dependencies have been satisfied
//! ([`graph::ActivationTracker`]). Execution is fully distributed: every
//! node tracks activations only for the tasks it will run, and
//! cross-node dependencies travel as `Activate` messages through
//! [`crate::comm`].
//!
//! The paper's TTG extension — a per-task-class `is_stealable` predicate
//! supplied by the programmer (Listing 1.1) — is part of the graph
//! contract here ([`TaskGraph::is_stealable`]) and of the dynamic
//! builder ([`ttg::TtgBuilder::wrap_g`]).

pub mod data;
pub mod graph;
pub mod task;
pub mod ttg;

pub use graph::ActivationTracker;
pub use task::{NodeId, TaskClass, TaskDesc};
pub use ttg::{TaskGraph, TtgBuilder};
