//! The task-graph contract and a TTG-style dynamic builder.
//!
//! [`TaskGraph`] is what every workload implements: it describes tasks,
//! their dependency structure, their initial placement, and — the
//! paper's TTG extension — whether a given task may be stolen.
//!
//! [`TtgBuilder`] mirrors the paper's Listing 1.1 (`ttg::wrapG`): a user
//! registers task classes from closures, including the `is_stealable`
//! predicate that has access to the same task description as the body.

use std::sync::Arc;

use super::task::{NodeId, TaskClass, TaskDesc};

/// A dataflow task graph: the program, from the runtime's point of view.
///
/// All methods must be pure functions of the task descriptor (plus the
/// graph's own immutable parameters): the runtime recreates stolen tasks
/// on the thief node from the descriptor alone, and both the thief and
/// the victim must agree on the task's successors, cost and stealability.
pub trait TaskGraph: Send + Sync {
    /// Human-readable workload name (reports, traces).
    fn name(&self) -> &str;

    /// Number of runtime domains ("nodes" in the paper) the static
    /// mapping targets.
    fn num_nodes(&self) -> usize;

    /// Tasks with zero input dependencies (DAG sources).
    fn roots(&self) -> Vec<TaskDesc>;

    /// Tasks activated by the completion of `t` (each exactly once; a
    /// successor with in-degree d receives d activations from d distinct
    /// predecessors).
    fn successors(&self, t: TaskDesc) -> Vec<TaskDesc>;

    /// Number of activations `t` must receive before becoming ready.
    fn in_degree(&self, t: TaskDesc) -> u32;

    /// Static owner mapping (the paper's cyclic tile distribution).
    fn owner(&self, t: TaskDesc) -> NodeId;

    /// If true, a successor runs on the node where its *activating
    /// predecessor* ran rather than `owner()` — UTS's child-follows-parent
    /// mapping. (With multiple predecessors the last activator wins, which
    /// only applies to in-degree-1 graphs like UTS anyway.)
    fn dynamic_placement(&self) -> bool {
        false
    }

    /// The paper's TTG extension: may this task be migrated to a thief?
    fn is_stealable(&self, t: TaskDesc) -> bool;

    /// Scheduling priority (larger runs first; the paper's runs use a
    /// critical-path heuristic for Cholesky).
    fn priority(&self, t: TaskDesc) -> i64 {
        let _ = t;
        0
    }

    /// Abstract work in "tile-op units"; the [`crate::sim::CostModel`]
    /// converts units to time. For Cholesky one unit is one dense tile
    /// op of the task's class at the workload's tile size.
    fn work_units(&self, t: TaskDesc) -> f64;

    /// Bytes that must move to migrate this task's inputs to a thief.
    fn payload_bytes(&self, t: TaskDesc) -> u64;

    /// Total task count if statically known (None for UTS).
    fn total_tasks(&self) -> Option<u64> {
        None
    }
}

/// One dynamically-registered task class (TTG DSL style).
pub struct TaskClassDef {
    pub name: String,
    /// Successor derivation for instances of this class.
    pub successors: Arc<dyn Fn(TaskDesc) -> Vec<TaskDesc> + Send + Sync>,
    pub in_degree: Arc<dyn Fn(TaskDesc) -> u32 + Send + Sync>,
    pub owner: Arc<dyn Fn(TaskDesc) -> NodeId + Send + Sync>,
    /// The paper's `is_stealable` hook: same signature family as the
    /// body, full access to the task description (Listing 1.1).
    pub is_stealable: Arc<dyn Fn(TaskDesc) -> bool + Send + Sync>,
    pub priority: Arc<dyn Fn(TaskDesc) -> i64 + Send + Sync>,
    pub work_units: Arc<dyn Fn(TaskDesc) -> f64 + Send + Sync>,
    pub payload_bytes: Arc<dyn Fn(TaskDesc) -> u64 + Send + Sync>,
}

/// Builder mirroring `ttg::wrapG(task_body, is_stealable, edges, ...)`:
/// assembles a [`TaskGraph`] out of per-class closures. Used by the
/// quickstart example and by tests that need bespoke DAG shapes.
pub struct TtgBuilder {
    name: String,
    num_nodes: usize,
    roots: Vec<TaskDesc>,
    classes: Vec<TaskClassDef>,
    total: Option<u64>,
}

impl TtgBuilder {
    pub fn new(name: &str, num_nodes: usize) -> Self {
        Self {
            name: name.to_string(),
            num_nodes,
            roots: Vec::new(),
            classes: Vec::new(),
            total: None,
        }
    }

    /// Register a task class. `class_slot` must equal the number of
    /// classes registered so far; instances use `TaskDesc.k` *unchanged*
    /// and select their class via `TaskDesc.class == Synthetic` plus the
    /// high bits of `uid`. For simplicity every dynamic class shares
    /// `TaskClass::Synthetic` and is distinguished by `desc.i` ranges the
    /// user controls; the builder does not constrain that.
    #[allow(clippy::too_many_arguments)]
    pub fn wrap_g(
        mut self,
        name: &str,
        is_stealable: impl Fn(TaskDesc) -> bool + Send + Sync + 'static,
        successors: impl Fn(TaskDesc) -> Vec<TaskDesc> + Send + Sync + 'static,
        in_degree: impl Fn(TaskDesc) -> u32 + Send + Sync + 'static,
        owner: impl Fn(TaskDesc) -> NodeId + Send + Sync + 'static,
        work_units: impl Fn(TaskDesc) -> f64 + Send + Sync + 'static,
    ) -> Self {
        self.classes.push(TaskClassDef {
            name: name.to_string(),
            successors: Arc::new(successors),
            in_degree: Arc::new(in_degree),
            owner: Arc::new(owner),
            is_stealable: Arc::new(is_stealable),
            priority: Arc::new(|_| 0),
            work_units: Arc::new(work_units),
            payload_bytes: Arc::new(|_| 0),
        });
        self
    }

    pub fn with_roots(mut self, roots: Vec<TaskDesc>) -> Self {
        self.roots = roots;
        self
    }

    pub fn with_total_tasks(mut self, n: u64) -> Self {
        self.total = Some(n);
        self
    }

    pub fn with_priority(
        mut self,
        f: impl Fn(TaskDesc) -> i64 + Send + Sync + 'static,
    ) -> Self {
        if let Some(c) = self.classes.last_mut() {
            c.priority = Arc::new(f);
        }
        self
    }

    pub fn with_payload(
        mut self,
        f: impl Fn(TaskDesc) -> u64 + Send + Sync + 'static,
    ) -> Self {
        if let Some(c) = self.classes.last_mut() {
            c.payload_bytes = Arc::new(f);
        }
        self
    }

    pub fn build(self) -> DynGraph {
        assert!(
            !self.classes.is_empty(),
            "TtgBuilder: register at least one task class via wrap_g"
        );
        DynGraph {
            name: self.name,
            num_nodes: self.num_nodes,
            roots: self.roots,
            classes: self.classes,
            total: self.total,
        }
    }
}

/// A [`TaskGraph`] assembled from closures. Dynamic classes all use
/// `TaskDesc.class == Synthetic`; the class *slot* is `desc.j >> 16`
/// when the user registers several (the built-in workloads use typed
/// classes instead and don't go through this path).
pub struct DynGraph {
    name: String,
    num_nodes: usize,
    roots: Vec<TaskDesc>,
    classes: Vec<TaskClassDef>,
    total: Option<u64>,
}

impl DynGraph {
    fn class_of(&self, t: TaskDesc) -> &TaskClassDef {
        let slot = (t.j >> 16) as usize;
        &self.classes[slot.min(self.classes.len() - 1)]
    }

    /// Encode a class slot into a task index `j` (upper half-word).
    pub fn slot_j(slot: u32, j: u32) -> u32 {
        (slot << 16) | (j & 0xFFFF)
    }
}

impl TaskGraph for DynGraph {
    fn name(&self) -> &str {
        &self.name
    }

    fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn roots(&self) -> Vec<TaskDesc> {
        self.roots.clone()
    }

    fn successors(&self, t: TaskDesc) -> Vec<TaskDesc> {
        (self.class_of(t).successors)(t)
    }

    fn in_degree(&self, t: TaskDesc) -> u32 {
        (self.class_of(t).in_degree)(t)
    }

    fn owner(&self, t: TaskDesc) -> NodeId {
        (self.class_of(t).owner)(t)
    }

    fn is_stealable(&self, t: TaskDesc) -> bool {
        (self.class_of(t).is_stealable)(t)
    }

    fn priority(&self, t: TaskDesc) -> i64 {
        (self.class_of(t).priority)(t)
    }

    fn work_units(&self, t: TaskDesc) -> f64 {
        (self.class_of(t).work_units)(t)
    }

    fn payload_bytes(&self, t: TaskDesc) -> u64 {
        (self.class_of(t).payload_bytes)(t)
    }

    fn total_tasks(&self) -> Option<u64> {
        self.total
    }
}

/// A linear chain graph (for tests): task i activates task i+1.
pub fn chain(len: u32, num_nodes: usize) -> DynGraph {
    let nn = num_nodes as u32;
    TtgBuilder::new("chain", num_nodes)
        .with_roots(vec![TaskDesc::indexed(TaskClass::Synthetic, 0, 0, 0)])
        .wrap_g(
            "link",
            |_| true,
            move |t| {
                if t.i + 1 < len {
                    vec![TaskDesc::indexed(TaskClass::Synthetic, t.i + 1, 0, 0)]
                } else {
                    vec![]
                }
            },
            |t| u32::from(t.i > 0),
            move |t| NodeId(t.i % nn),
            |_| 1.0,
        )
        .with_total_tasks(len as u64)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_graph_shape() {
        let g = chain(5, 2);
        assert_eq!(g.roots().len(), 1);
        let r = g.roots()[0];
        assert_eq!(g.in_degree(r), 0);
        let s = g.successors(r);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].i, 1);
        assert_eq!(g.owner(s[0]), NodeId(1));
        let last = TaskDesc::indexed(TaskClass::Synthetic, 4, 0, 0);
        assert!(g.successors(last).is_empty());
        assert_eq!(g.total_tasks(), Some(5));
    }

    #[test]
    fn wrap_g_stealable_hook() {
        let g = TtgBuilder::new("t", 1)
            .wrap_g(
                "c",
                |t| t.i % 2 == 0, // programmer-controlled stealability
                |_| vec![],
                |_| 0,
                |_| NodeId(0),
                |_| 1.0,
            )
            .build();
        assert!(g.is_stealable(TaskDesc::indexed(TaskClass::Synthetic, 2, 0, 0)));
        assert!(!g.is_stealable(TaskDesc::indexed(TaskClass::Synthetic, 3, 0, 0)));
    }
}
