//! Deterministic fault injection for the steal protocol (`--faults`).
//!
//! A [`FaultPlan`] describes, per steal-protocol message class
//! (StealRequest / StealReply / TransferAck), the probability that the
//! fabric drops, duplicates or delays a message, plus an optional
//! straggler window during which one node's steal traffic is slowed or
//! stalled outright. The plan is injected at the two existing
//! chokepoints — the threaded comm fabric (`comm::Network::send`) and
//! the DES wire model (`sim::Simulator` deliver scheduling) — and is
//! *scoped to steal traffic only*: Safra tokens, activations and
//! shutdown messages are never faulted, so termination detection and
//! the dataflow itself stay reliable while the steal protocol has to
//! heal itself (timeouts + retries on the thief, a transfer ledger +
//! ack handshake on the victim; see `docs/ARCHITECTURE.md`,
//! "Fault model & recovery").
//!
//! Determinism: the plan owns no state; each fabric derives a dedicated
//! RNG stream (`util::rng::fault_rng`) so a disabled plan draws nothing
//! and an enabled one never perturbs the scheduler's RNG. With the plan
//! off (the default) both runtimes are byte-identical to a build
//! without this module.

use std::fmt;
use std::str::FromStr;

use crate::util::rng::Rng;

/// Drop/duplicate probabilities are clamped here: a drop probability of
/// 1.0 would make the retransmit loop diverge (no retry can ever land),
/// so the parser caps every probability at this value.
pub const MAX_FAULT_P: f64 = 0.95;

/// Steal-protocol message classes a [`FaultPlan`] distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// Thief → victim `StealRequest`.
    Request,
    /// Victim → thief `StealReply` (grant or denial).
    Reply,
    /// Thief → victim `TransferAck` (ack or nack).
    Ack,
}

/// How the fabric tagged one delivered message.
///
/// The threaded runtime cannot silently lose a basic message — the
/// Safra detector counts every send, so an unmatched send would leave a
/// permanent deficit and the run would never terminate. A "dropped"
/// message is therefore still *delivered*, marked [`FaultMark::Dropped`]:
/// the receiver balances the message accounting and then discards it
/// unprocessed. A duplicate is the inverse: an extra copy marked
/// [`FaultMark::Duplicate`] that the receiver processes (protocol-level
/// dedup makes it harmless) but does *not* count as a receive, because
/// no send was counted for it. The DES has no Safra detector (it is
/// omniscient), so it drops messages for real and only uses
/// [`FaultMark::Duplicate`] bookkeeping internally.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultMark {
    /// Normal delivery.
    #[default]
    None,
    /// Deliver only to balance accounting; receiver must discard.
    Dropped,
    /// Injected extra copy; process but do not count the receive.
    Duplicate,
}

/// The fabric's verdict on one steal-class message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultDecision {
    /// Message is lost (threaded: delivered marked-dropped).
    pub dropped: bool,
    /// One extra copy is delivered alongside the original.
    pub duplicate: bool,
    /// Multiplier on the modeled wire time (≥ 1.0; a no-op on ideal
    /// links, which model zero wire time).
    pub delay_mult: f64,
}

impl FaultDecision {
    /// The undisturbed verdict (also what a disabled plan returns).
    pub fn pass() -> FaultDecision {
        FaultDecision {
            dropped: false,
            duplicate: false,
            delay_mult: 1.0,
        }
    }
}

/// A seeded, declarative fault schedule (`--faults`), default off.
///
/// Spec grammar (comma-separated `key=value` entries):
///
/// ```text
/// off | none                  disabled (the default)
/// on                          protocol hardening active, no injected faults
/// drop=P                      drop all three classes with probability P
/// drop-request|drop-reply|drop-ack=P    per-class drop probability
/// dup=P, dup-request|dup-reply|dup-ack=P  duplicate probabilities
/// delay=Fx (or F)             multiply steal-message wire time by F
/// delay-p=P                   fraction of steal messages delayed (default 1)
/// slow-node=N                 straggler node id for the window below
/// slow-factor=F               extra delay on the straggler's steal traffic
/// slow-from-us=T,slow-until-us=T   straggler window in run time (µs)
/// stall                       straggler drops (instead of delays) in-window
/// crash-node=N                crash-stop node N (never 0, the ring leader)
/// crash-at-us=T               crash time on the run clock (µs)
/// crash-p=P                   probabilistic crash: with probability P one
///                             node (crash-node, or a uniform draw over
///                             1..n) crash-stops at crash-at-us (or a
///                             drawn time) — all draws from the dedicated
///                             fault stream, so zero draws when off
/// ```
///
/// Example: `--faults drop=0.05,delay=3x` or
/// `--faults crash-node=2,crash-at-us=30000,drop=0.02`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Master switch; `false` means no draws, no marks, no extra
    /// messages, no timeout machinery — byte-identical to PR 6.
    pub enabled: bool,
    pub drop_request: f64,
    pub drop_reply: f64,
    pub drop_ack: f64,
    pub dup_request: f64,
    pub dup_reply: f64,
    pub dup_ack: f64,
    /// Wire-time multiplier for delayed steal messages (≥ 1.0).
    pub delay_factor: f64,
    /// Probability a steal message is delayed (only if `delay_factor > 1`).
    pub delay_p: f64,
    /// Straggler node: its steal traffic (either direction) is slowed by
    /// `slow_factor` — or stalled outright with `stall` — while the run
    /// clock is inside `[slow_from_us, slow_until_us)`.
    pub slow_node: Option<u32>,
    pub slow_factor: f64,
    pub slow_from_us: f64,
    pub slow_until_us: f64,
    pub stall: bool,
    /// Crash-stop victim. Node 0 is never crashable: it is the Safra
    /// ring leader and the recovery coordinator (the parser rejects it).
    pub crash_node: Option<u32>,
    /// Crash time on the run clock (µs). 0 with `crash_node` set means
    /// "crash immediately"; 0 with only `crash_p` set means "draw one".
    pub crash_at_us: f64,
    /// Probabilistic crash: with this probability, one node crash-stops
    /// (the node is `crash_node` if set, else a uniform draw over
    /// `1..n`; the time is `crash_at_us` if > 0, else a uniform draw).
    pub crash_p: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            enabled: false,
            drop_request: 0.0,
            drop_reply: 0.0,
            drop_ack: 0.0,
            dup_request: 0.0,
            dup_reply: 0.0,
            dup_ack: 0.0,
            delay_factor: 1.0,
            delay_p: 1.0,
            slow_node: None,
            slow_factor: 1.0,
            slow_from_us: 0.0,
            slow_until_us: f64::INFINITY,
            stall: false,
            crash_node: None,
            crash_at_us: 0.0,
            crash_p: 0.0,
        }
    }
}

impl FaultPlan {
    /// Decide the fate of one steal-class message. `now_us` is the run
    /// clock (sim time in the DES, wall time since fabric start in the
    /// threaded runtime) used only for the straggler window. Draws from
    /// `rng` only when the plan is enabled.
    pub fn decide(
        &self,
        class: FaultClass,
        src: u32,
        dst: u32,
        now_us: f64,
        rng: &mut Rng,
    ) -> FaultDecision {
        let mut d = FaultDecision::pass();
        if !self.enabled {
            return d;
        }
        if let Some(s) = self.slow_node {
            if (src == s || dst == s) && now_us >= self.slow_from_us && now_us < self.slow_until_us
            {
                if self.stall {
                    d.dropped = true;
                    return d;
                }
                d.delay_mult *= self.slow_factor.max(1.0);
            }
        }
        let (p_drop, p_dup) = match class {
            FaultClass::Request => (self.drop_request, self.dup_request),
            FaultClass::Reply => (self.drop_reply, self.dup_reply),
            FaultClass::Ack => (self.drop_ack, self.dup_ack),
        };
        if p_drop > 0.0 && rng.uniform() < p_drop {
            d.dropped = true;
            return d;
        }
        if p_dup > 0.0 && rng.uniform() < p_dup {
            d.duplicate = true;
        }
        if self.delay_factor > 1.0 && self.delay_p > 0.0 && rng.uniform() < self.delay_p {
            d.delay_mult *= self.delay_factor;
        }
        d
    }

    /// Whether this plan can crash-stop a node at all.
    pub fn has_crash(&self) -> bool {
        self.enabled && (self.crash_node.is_some() || self.crash_p > 0.0)
    }

    /// Resolve the crash schedule for a run of `num_nodes` nodes:
    /// `Some((node, at_us))` if a node crash-stops, `None` otherwise.
    ///
    /// Both runtimes call this once at startup with the *same* dedicated
    /// stream (`fault_rng(seed, 1)`), so the DES and the threaded fabric
    /// agree on who dies and when. A plan with no crash spec makes zero
    /// draws (byte-identity when off); a deterministic `crash-node` +
    /// `crash-at-us` pair makes zero draws too. Node 0 never crashes —
    /// it is the ring leader and the recovery coordinator.
    pub fn crash_schedule(&self, num_nodes: usize, rng: &mut Rng) -> Option<(u32, f64)> {
        if !self.has_crash() || num_nodes < 2 {
            return None;
        }
        if self.crash_p > 0.0 && rng.uniform() >= self.crash_p {
            return None;
        }
        let node = match self.crash_node {
            Some(n) if n > 0 && (n as usize) < num_nodes => n,
            Some(_) => return None, // out of range for this run
            None => 1 + rng.below((num_nodes - 1) as u64) as u32,
        };
        let at_us = if self.crash_at_us > 0.0 {
            self.crash_at_us
        } else if self.crash_node.is_some() && self.crash_p == 0.0 {
            0.0 // deterministic immediate crash, no draw
        } else {
            1_000.0 + rng.uniform() * 19_000.0
        };
        Some((node, at_us))
    }

    /// Canonical spec string; `plan.label().parse()` round-trips.
    pub fn label(&self) -> String {
        if !self.enabled {
            return "off".to_string();
        }
        let mut parts: Vec<String> = Vec::new();
        let triple = |parts: &mut Vec<String>, key: &str, a: f64, b: f64, c: f64| {
            if a == b && b == c {
                if a > 0.0 {
                    parts.push(format!("{key}={a}"));
                }
            } else {
                for (suffix, p) in [("request", a), ("reply", b), ("ack", c)] {
                    if p > 0.0 {
                        parts.push(format!("{key}-{suffix}={p}"));
                    }
                }
            }
        };
        triple(
            &mut parts,
            "drop",
            self.drop_request,
            self.drop_reply,
            self.drop_ack,
        );
        triple(
            &mut parts,
            "dup",
            self.dup_request,
            self.dup_reply,
            self.dup_ack,
        );
        if self.delay_factor > 1.0 {
            parts.push(format!("delay={}x", self.delay_factor));
            if self.delay_p < 1.0 {
                parts.push(format!("delay-p={}", self.delay_p));
            }
        }
        if let Some(s) = self.slow_node {
            parts.push(format!("slow-node={s}"));
            if self.slow_factor > 1.0 {
                parts.push(format!("slow-factor={}", self.slow_factor));
            }
            if self.slow_from_us > 0.0 {
                parts.push(format!("slow-from-us={}", self.slow_from_us));
            }
            if self.slow_until_us.is_finite() {
                parts.push(format!("slow-until-us={}", self.slow_until_us));
            }
            if self.stall {
                parts.push("stall".to_string());
            }
        }
        if let Some(n) = self.crash_node {
            parts.push(format!("crash-node={n}"));
        }
        if self.crash_at_us > 0.0 {
            parts.push(format!("crash-at-us={}", self.crash_at_us));
        }
        if self.crash_p > 0.0 {
            parts.push(format!("crash-p={}", self.crash_p));
        }
        if parts.is_empty() {
            "on".to_string()
        } else {
            parts.join(",")
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

fn parse_prob(key: &str, v: &str) -> Result<f64, String> {
    let p: f64 = v
        .parse()
        .map_err(|_| format!("--faults: '{key}={v}' is not a probability"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("--faults: '{key}={v}' must be in [0, 1]"));
    }
    Ok(p.min(MAX_FAULT_P))
}

fn parse_factor(key: &str, v: &str) -> Result<f64, String> {
    let raw = v.strip_suffix(['x', 'X']).unwrap_or(v);
    let f: f64 = raw
        .parse()
        .map_err(|_| format!("--faults: '{key}={v}' is not a factor"))?;
    if f < 1.0 {
        return Err(format!("--faults: '{key}={v}' must be >= 1"));
    }
    Ok(f)
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let spec = s.trim();
        let mut plan = FaultPlan::default();
        if spec.is_empty() || spec.eq_ignore_ascii_case("off") || spec.eq_ignore_ascii_case("none")
        {
            return Ok(plan);
        }
        plan.enabled = true;
        if spec.eq_ignore_ascii_case("on") {
            return Ok(plan);
        }
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (key, value) = match entry.split_once('=') {
                Some((k, v)) => (k.trim(), v.trim()),
                None => (entry, ""),
            };
            match key.to_ascii_lowercase().as_str() {
                "drop" => {
                    let p = parse_prob(key, value)?;
                    plan.drop_request = p;
                    plan.drop_reply = p;
                    plan.drop_ack = p;
                }
                "drop-request" => plan.drop_request = parse_prob(key, value)?,
                "drop-reply" => plan.drop_reply = parse_prob(key, value)?,
                "drop-ack" => plan.drop_ack = parse_prob(key, value)?,
                "dup" => {
                    let p = parse_prob(key, value)?;
                    plan.dup_request = p;
                    plan.dup_reply = p;
                    plan.dup_ack = p;
                }
                "dup-request" => plan.dup_request = parse_prob(key, value)?,
                "dup-reply" => plan.dup_reply = parse_prob(key, value)?,
                "dup-ack" => plan.dup_ack = parse_prob(key, value)?,
                "delay" => plan.delay_factor = parse_factor(key, value)?,
                "delay-p" => plan.delay_p = parse_prob(key, value)?,
                "slow-node" => {
                    plan.slow_node = Some(value.parse().map_err(|_| {
                        format!("--faults: 'slow-node={value}' is not a node id")
                    })?)
                }
                "slow-factor" => plan.slow_factor = parse_factor(key, value)?,
                "slow-from-us" => {
                    plan.slow_from_us = value.parse().map_err(|_| {
                        format!("--faults: 'slow-from-us={value}' is not a time")
                    })?
                }
                "slow-until-us" => {
                    plan.slow_until_us = value.parse().map_err(|_| {
                        format!("--faults: 'slow-until-us={value}' is not a time")
                    })?
                }
                "stall" => plan.stall = value.is_empty() || value.parse().unwrap_or(false),
                "crash-node" => {
                    let n: u32 = value.parse().map_err(|_| {
                        format!("--faults: 'crash-node={value}' is not a node id")
                    })?;
                    if n == 0 {
                        // Node 0 is the ring leader and recovery coordinator.
                        return Err("--faults: crash-node=0 is not allowed".to_string());
                    }
                    plan.crash_node = Some(n);
                }
                "crash-at-us" => {
                    let t: f64 = value.parse().map_err(|_| {
                        format!("--faults: 'crash-at-us={value}' is not a time")
                    })?;
                    if t < 0.0 {
                        return Err(format!("--faults: 'crash-at-us={value}' must be >= 0"));
                    }
                    plan.crash_at_us = t;
                }
                "crash-p" => {
                    // Deliberately not clamped to MAX_FAULT_P: a certain
                    // crash (p = 1) is a valid, recoverable schedule —
                    // unlike certain message loss, which would diverge.
                    let p: f64 = value.parse().map_err(|_| {
                        format!("--faults: 'crash-p={value}' is not a probability")
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("--faults: 'crash-p={value}' must be in [0, 1]"));
                    }
                    plan.crash_p = p;
                }
                other => return Err(format!("--faults: unknown key '{other}'")),
            }
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::fault_rng;

    #[test]
    fn default_is_off_and_decides_nothing() {
        let plan = FaultPlan::default();
        assert!(!plan.enabled);
        assert_eq!(plan.label(), "off");
        let mut rng = fault_rng(1, 0);
        let before = rng.next_u64();
        let mut rng = fault_rng(1, 0);
        let d = plan.decide(FaultClass::Reply, 0, 1, 0.0, &mut rng);
        assert_eq!(d, FaultDecision::pass());
        // A disabled plan must not consume the RNG stream.
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn spec_parses_and_clamps() {
        let plan: FaultPlan = "drop=0.05,delay=3x".parse().unwrap();
        assert!(plan.enabled);
        assert_eq!(plan.drop_request, 0.05);
        assert_eq!(plan.drop_reply, 0.05);
        assert_eq!(plan.drop_ack, 0.05);
        assert_eq!(plan.delay_factor, 3.0);
        let clamped: FaultPlan = "drop-reply=1.0".parse().unwrap();
        assert_eq!(
            clamped.drop_reply, MAX_FAULT_P,
            "certain loss would make retransmission diverge"
        );
        assert!("drop=2".parse::<FaultPlan>().is_err());
        assert!("delay=0.5x".parse::<FaultPlan>().is_err());
        assert!("bogus=1".parse::<FaultPlan>().is_err());
        assert!(!"off".parse::<FaultPlan>().unwrap().enabled);
        let on: FaultPlan = "on".parse().unwrap();
        assert!(on.enabled && on.label() == "on");
    }

    #[test]
    fn label_round_trips() {
        for spec in [
            "on",
            "drop=0.2",
            "drop-reply=0.3,dup-ack=0.1",
            "drop=0.05,delay=3x",
            "delay=2x,delay-p=0.25",
            "slow-node=2,slow-factor=4,slow-from-us=100,slow-until-us=5000",
            "drop=0.1,slow-node=0,stall",
            "crash-node=2,crash-at-us=30000",
            "crash-p=0.5",
            "drop=0.05,crash-node=3,crash-at-us=1000,crash-p=1",
        ] {
            let plan: FaultPlan = spec.parse().unwrap();
            let relabeled: FaultPlan = plan.label().parse().unwrap();
            assert_eq!(plan, relabeled, "spec '{spec}' label '{}'", plan.label());
        }
    }

    #[test]
    fn drop_rate_is_roughly_honored() {
        let plan: FaultPlan = "drop-reply=0.5".parse().unwrap();
        let mut rng = fault_rng(42, 3);
        let dropped = (0..10_000)
            .filter(|_| plan.decide(FaultClass::Reply, 1, 0, 0.0, &mut rng).dropped)
            .count();
        assert!((4_500..5_500).contains(&dropped), "dropped {dropped}/10000");
        // Other classes are untouched by a reply-only plan.
        let d = plan.decide(FaultClass::Request, 1, 0, 0.0, &mut rng);
        assert!(!d.dropped && !d.duplicate && d.delay_mult == 1.0);
    }

    #[test]
    fn straggler_window_stalls_only_inside() {
        let plan: FaultPlan = "slow-node=1,slow-from-us=100,slow-until-us=200,stall"
            .parse()
            .unwrap();
        let mut rng = fault_rng(7, 0);
        assert!(plan.decide(FaultClass::Request, 1, 0, 150.0, &mut rng).dropped);
        assert!(plan.decide(FaultClass::Request, 0, 1, 150.0, &mut rng).dropped);
        assert!(!plan.decide(FaultClass::Request, 1, 0, 50.0, &mut rng).dropped);
        assert!(!plan.decide(FaultClass::Request, 1, 0, 200.0, &mut rng).dropped);
        assert!(!plan.decide(FaultClass::Request, 2, 0, 150.0, &mut rng).dropped);
        let slow: FaultPlan = "slow-node=1,slow-factor=4".parse().unwrap();
        let d = slow.decide(FaultClass::Reply, 1, 0, 0.0, &mut rng);
        assert_eq!(d.delay_mult, 4.0);
        assert!(!d.dropped);
    }

    #[test]
    fn crash_schedule_is_deterministic_and_draw_free_when_off() {
        // No crash spec: zero draws, even with other faults enabled.
        let plan: FaultPlan = "drop=0.2".parse().unwrap();
        let mut rng = fault_rng(11, 1);
        let before = rng.next_u64();
        let mut rng = fault_rng(11, 1);
        assert_eq!(plan.crash_schedule(8, &mut rng), None);
        assert_eq!(rng.next_u64(), before, "no-crash plan must not draw");

        // Deterministic node+time: zero draws as well.
        let det: FaultPlan = "crash-node=2,crash-at-us=30000".parse().unwrap();
        let mut rng = fault_rng(11, 1);
        assert_eq!(det.crash_schedule(8, &mut rng), Some((2, 30_000.0)));
        assert_eq!(rng.next_u64(), before);

        // Out-of-range victim: the plan is a no-op for this run size.
        assert_eq!(det.crash_schedule(2, &mut fault_rng(11, 1)), None);
        // Single-node runs have no one to fail over to.
        assert_eq!(det.crash_schedule(1, &mut fault_rng(11, 1)), None);

        // Probabilistic form: same seed, same schedule; node 0 never
        // drawn; a drawn time lands in the documented window.
        let p: FaultPlan = "crash-p=1".parse().unwrap();
        let a = p.crash_schedule(8, &mut fault_rng(42, 1)).unwrap();
        let b = p.crash_schedule(8, &mut fault_rng(42, 1)).unwrap();
        assert_eq!(a, b);
        for seed in 0..200u64 {
            if let Some((n, t)) = p.crash_schedule(4, &mut fault_rng(seed, 1)) {
                assert!((1..4).contains(&n), "node 0 must never crash");
                assert!((1_000.0..20_000.0).contains(&t));
            } else {
                panic!("crash-p=1 must always schedule a crash");
            }
        }
        // crash-p=0.5 hits roughly half the seeds.
        let half: FaultPlan = "crash-p=0.5".parse().unwrap();
        let hits = (0..1_000u64)
            .filter(|&s| half.crash_schedule(4, &mut fault_rng(s, 1)).is_some())
            .count();
        assert!((400..600).contains(&hits), "hits {hits}/1000");
        assert!("crash-node=0".parse::<FaultPlan>().is_err());
        assert!("crash-p=1.5".parse::<FaultPlan>().is_err());
        assert!("crash-at-us=-5".parse::<FaultPlan>().is_err());
        assert!(det.has_crash() && p.has_crash() && !plan.has_crash());
        assert!(!FaultPlan::default().has_crash());
    }

    #[test]
    fn duplicates_and_delays_compose() {
        let plan: FaultPlan = "dup=0.95,delay=3x".parse().unwrap();
        let mut rng = fault_rng(9, 1);
        let mut dup_seen = false;
        let mut delay_seen = false;
        for _ in 0..200 {
            let d = plan.decide(FaultClass::Ack, 0, 1, 0.0, &mut rng);
            assert!(!d.dropped);
            dup_seen |= d.duplicate;
            delay_seen |= d.delay_mult == 3.0;
        }
        assert!(dup_seen && delay_seen);
    }
}
