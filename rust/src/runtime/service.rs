//! Multi-threaded access to the PJRT engines.
//!
//! `xla` handles are `!Send`, so each service thread builds its *own*
//! [`TileEngine`] (own PJRT client + compiled executables) and drains a
//! shared request queue. Worker threads of the real runtime hold a
//! cloneable [`KernelService`] handle and block per call — exactly the
//! shape of a task body invoking a BLAS kernel.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::dataflow::data::Tile;

use super::pjrt::TileEngine;

struct Request {
    op: String,
    tile: u32,
    inputs: Vec<Tile>,
    reply: Sender<Result<Vec<Tile>>>,
}

/// Cloneable handle to the kernel thread pool.
#[derive(Clone)]
pub struct KernelService {
    tx: Sender<Request>,
    inner: Arc<ServiceInner>,
}

struct ServiceInner {
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Kept so the queue closes when the last handle drops.
    _keep: (),
}

impl KernelService {
    /// Spawn `threads` engine threads, each loading the artifacts in
    /// `dir` (optionally restricted to `only_tiles`). Fails fast if the
    /// first engine cannot load.
    pub fn start(dir: PathBuf, only_tiles: Option<Vec<u32>>, threads: usize) -> Result<Self> {
        assert!(threads >= 1);
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        // Probe-load one engine on the calling thread so configuration
        // errors surface immediately rather than inside the pool.
        {
            let probe = TileEngine::load(&dir, only_tiles.as_deref())?;
            drop(probe);
        }
        let mut handles = Vec::new();
        for i in 0..threads {
            let rx = rx.clone();
            let dir = dir.clone();
            let tiles = only_tiles.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-{i}"))
                    .spawn(move || serve(rx, dir, tiles))
                    .unwrap(),
            );
        }
        Ok(KernelService {
            tx,
            inner: Arc::new(ServiceInner {
                handles: Mutex::new(handles),
                _keep: (),
            }),
        })
    }

    /// Execute a tile op on some engine thread; blocks for the result.
    pub fn execute(&self, op: &str, tile: u32, inputs: Vec<Tile>) -> Result<Vec<Tile>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request {
                op: op.to_string(),
                tile,
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("kernel service stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("kernel service dropped request"))?
    }

    /// Join the pool (drop all handles first). Called implicitly on drop
    /// of the last clone.
    pub fn shutdown(self) {
        drop(self.tx);
        if let Ok(mut hs) = self.inner.handles.lock() {
            for h in hs.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn serve(rx: Arc<Mutex<Receiver<Request>>>, dir: PathBuf, tiles: Option<Vec<u32>>) {
    let engine = match TileEngine::load(&dir, tiles.as_deref()) {
        Ok(e) => e,
        Err(err) => {
            // Propagate by failing every request we can grab.
            loop {
                let req = { rx.lock().unwrap().recv() };
                match req {
                    Ok(r) => {
                        let _ = r.reply.send(Err(anyhow!("engine failed to load: {err}")));
                    }
                    Err(_) => return,
                }
            }
        }
    };
    loop {
        // Hold the receiver lock only while pulling one request.
        let req = { rx.lock().unwrap().recv() };
        match req {
            Ok(r) => {
                let result = engine.execute(&r.op, r.tile, &r.inputs);
                let _ = r.reply.send(result);
            }
            Err(_) => return, // all senders gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn concurrent_requests_from_many_threads() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let svc = KernelService::start(artifacts_dir(), Some(vec![8]), 2).unwrap();
        let mut joins = Vec::new();
        for t in 0..6 {
            let svc = svc.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = Tile::zeros(8);
                let mut a = Tile::zeros(8);
                for i in 0..8 {
                    a.set(i, i, (t + 1) as f64);
                    c.set(i, i, 1.0);
                }
                let out = svc.execute("syrk", 8, vec![c, a.clone()]).unwrap();
                // c - a aᵀ diagonal: 1 - (t+1)^2
                let want = 1.0 - ((t + 1) as f64).powi(2);
                assert!((out[0].at(3, 3) - want).abs() < 1e-12);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        svc.shutdown();
    }

    #[test]
    fn missing_dir_fails_fast() {
        let r = KernelService::start(PathBuf::from("/nonexistent"), None, 1);
        assert!(r.is_err());
    }
}
