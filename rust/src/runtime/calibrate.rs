//! Cost-model calibration: measure the real PJRT per-op timings at every
//! artifact tile size and fit `t(n) = c3·n³ + c0` per task class by
//! least squares. The result feeds the DES so virtual-time figures run
//! on *measured* granularities (`repro calibrate`).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::dataflow::data::Tile;
use crate::sim::{ClassCost, CostModel};
use crate::util::rng::Rng;

use super::pjrt::TileEngine;

/// Measured mean execution time for one (op, tile) pair.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub op: String,
    pub tile: u32,
    pub mean_us: f64,
    pub reps: usize,
}

fn spd_tile(n: usize, seed: u64) -> Tile {
    let mut rng = Rng::new(seed);
    let mut t = Tile::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal() * 0.1;
            t.set(i, j, v);
            t.set(j, i, v);
        }
        let d = t.at(i, i).abs() + n as f64;
        t.set(i, i, d);
    }
    t
}

fn rand_tile(n: usize, seed: u64) -> Tile {
    let mut rng = Rng::new(seed);
    let mut t = Tile::zeros(n);
    for v in &mut t.data {
        *v = rng.normal();
    }
    t
}

/// Time every (op, tile) artifact; `reps` executions after one warmup.
pub fn measure(engine: &TileEngine, reps: usize) -> Result<Vec<Measurement>> {
    let mut out = Vec::new();
    let entries: Vec<_> = engine.manifest().entries.clone();
    for e in entries {
        if !engine.has(&e.op, e.tile) {
            continue;
        }
        let n = e.tile as usize;
        let inputs: Vec<Tile> = match e.op.as_str() {
            "potrf" => vec![spd_tile(n, 1)],
            "trsm" => vec![spd_tile(n, 2), rand_tile(n, 3)],
            "syrk" => vec![rand_tile(n, 4), rand_tile(n, 5)],
            "gemm" => vec![rand_tile(n, 6), rand_tile(n, 7), rand_tile(n, 8)],
            "potrf_trsm" => vec![spd_tile(n, 9), rand_tile(n, 10)],
            _ => continue,
        };
        // warmup
        engine.execute(&e.op, e.tile, &inputs)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            engine.execute(&e.op, e.tile, &inputs)?;
        }
        let mean_us = t0.elapsed().as_nanos() as f64 / 1e3 / reps as f64;
        out.push(Measurement {
            op: e.op.clone(),
            tile: e.tile,
            mean_us,
            reps,
        });
    }
    Ok(out)
}

/// Least-squares fit of `t = c3·n³ + c0` from (n, t) samples.
pub fn fit_cubic(samples: &[(u32, f64)]) -> ClassCost {
    // Linear regression on x = n³: minimize Σ (c3 x + c0 − t)².
    let m = samples.len() as f64;
    if samples.is_empty() {
        return ClassCost { c3: 0.0, c0: 0.0 };
    }
    if samples.len() == 1 {
        return ClassCost {
            c3: 0.0,
            c0: samples[0].1,
        };
    }
    let xs: Vec<f64> = samples.iter().map(|(n, _)| (*n as f64).powi(3)).collect();
    let ts: Vec<f64> = samples.iter().map(|(_, t)| *t).collect();
    let sx: f64 = xs.iter().sum();
    let st: f64 = ts.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxt: f64 = xs.iter().zip(&ts).map(|(x, t)| x * t).sum();
    let denom = m * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return ClassCost {
            c3: 0.0,
            c0: st / m,
        };
    }
    let c3 = ((m * sxt - sx * st) / denom).max(0.0);
    let c0 = ((st - c3 * sx) / m).max(0.0);
    ClassCost { c3, c0 }
}

/// Full calibration: measure, fit, assemble a [`CostModel`] (keeping the
/// default UTS and noise parameters), optionally writing it to `out`.
pub fn calibrate(artifacts_dir: &Path, reps: usize, out: Option<&Path>) -> Result<CostModel> {
    let engine = TileEngine::load(artifacts_dir, None)?;
    let measurements = measure(&engine, reps)?;
    let mut model = CostModel::default_calibrated();
    for (idx, op) in ["potrf", "trsm", "syrk", "gemm"].iter().enumerate() {
        let samples: Vec<(u32, f64)> = measurements
            .iter()
            .filter(|m| m.op == *op)
            .map(|m| (m.tile, m.mean_us))
            .collect();
        if !samples.is_empty() {
            model.dense[idx] = fit_cubic(&samples);
        }
    }
    if let Some(path) = out {
        std::fs::write(path, model.to_json().pretty())?;
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_fit_recovers_coefficients() {
        let truth = ClassCost { c3: 3e-4, c0: 12.0 };
        let samples: Vec<(u32, f64)> =
            [8u32, 16, 24, 32, 50].iter().map(|&n| (n, truth.eval_us(n))).collect();
        let fit = fit_cubic(&samples);
        assert!((fit.c3 - truth.c3).abs() < 1e-8);
        assert!((fit.c0 - truth.c0).abs() < 1e-6);
    }

    #[test]
    fn fit_handles_degenerate_inputs() {
        assert_eq!(fit_cubic(&[]).c0, 0.0);
        let one = fit_cubic(&[(8, 42.0)]);
        assert_eq!((one.c3, one.c0), (0.0, 42.0));
        // same-n duplicates: average into c0
        let dup = fit_cubic(&[(8, 10.0), (8, 20.0)]);
        assert!(dup.c3 == 0.0 && (dup.c0 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn fit_clamps_negative() {
        // decreasing times (nonsense input) must not yield negative cost
        let fit = fit_cubic(&[(8, 100.0), (50, 1.0)]);
        assert!(fit.c3 >= 0.0 && fit.c0 >= 0.0);
    }
}
