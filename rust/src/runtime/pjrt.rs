//! PJRT loading and execution of the HLO-text artifacts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::dataflow::data::Tile;
use crate::util::json::Json;

/// One artifact from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub op: String,
    pub tile: u32,
    pub inputs: usize,
    pub outputs: usize,
    pub file: String,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)?;
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest has no entries"))?
            .iter()
            .map(|e| {
                Ok(ManifestEntry {
                    name: e.req_str("name")?.to_string(),
                    op: e.req_str("op")?.to_string(),
                    tile: e.req_u64("tile")? as u32,
                    inputs: e.req_u64("inputs")? as usize,
                    outputs: e.req_u64("outputs")? as usize,
                    file: e.req_str("file")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype: j.req_str("dtype")?.to_string(),
            entries,
        })
    }

    pub fn tile_sizes(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.entries.iter().map(|e| e.tile).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn find(&self, op: &str, tile: u32) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.op == op && e.tile == tile)
    }
}

/// A PJRT CPU client plus the compiled executables of every artifact.
/// `!Send` (raw PJRT handles) — see [`super::service::KernelService`]
/// for the multi-threaded wrapper.
pub struct TileEngine {
    client: xla::PjRtClient,
    exes: HashMap<(String, u32), xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl TileEngine {
    /// Load and compile every artifact in `dir` (or only those whose tile
    /// size is in `only_tiles`, to cut startup time).
    pub fn load(dir: &Path, only_tiles: Option<&[u32]>) -> Result<TileEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        for entry in &manifest.entries {
            if let Some(filter) = only_tiles {
                if !filter.contains(&entry.tile) {
                    continue;
                }
            }
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            exes.insert((entry.op.clone(), entry.tile), exe);
        }
        Ok(TileEngine {
            client,
            exes,
            manifest,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, op: &str, tile: u32) -> bool {
        self.exes.contains_key(&(op.to_string(), tile))
    }

    /// Execute one tile op. Inputs/outputs are square `tile`-sized f64
    /// tiles in the artifact's parameter order.
    pub fn execute(&self, op: &str, tile: u32, inputs: &[Tile]) -> Result<Vec<Tile>> {
        let entry = self
            .manifest
            .find(op, tile)
            .ok_or_else(|| anyhow!("no artifact for {op} @ n={tile}"))?;
        if inputs.len() != entry.inputs {
            bail!(
                "{op}@{tile} expects {} inputs, got {}",
                entry.inputs,
                inputs.len()
            );
        }
        let n = tile as usize;
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            if t.n != n {
                bail!("input tile is {}x{}, artifact wants {n}x{n}", t.n, t.n);
            }
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&[n as i64, n as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let exe = &self.exes[&(op.to_string(), tile)];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {op}@{tile}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let outs = result.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if outs.len() != entry.outputs {
            bail!("{op}@{tile}: expected {} outputs, got {}", entry.outputs, outs.len());
        }
        outs.into_iter()
            .map(|lit| {
                let data = lit
                    .to_vec::<f64>()
                    .map_err(|e| anyhow!("to_vec: {e:?}"))?;
                if data.len() != n * n {
                    bail!("output size {} != {}", data.len(), n * n);
                }
                Ok(Tile { n, data })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::kernels as cpu;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn spd_tile(n: usize, seed: u64) -> Tile {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut m = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, rng.normal());
            }
        }
        let mut a = Tile::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut acc = if i == j { n as f64 } else { 0.0 };
                for k in 0..n {
                    acc += m.at(i, k) * m.at(j, k);
                }
                a.set(i, j, acc);
            }
        }
        a
    }

    fn rand_tile(n: usize, seed: u64) -> Tile {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut t = Tile::zeros(n);
        for i in 0..n * n {
            t.data[i] = rng.normal();
        }
        t
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.dtype, "f64");
        assert!(m.find("gemm", 8).is_some());
        assert!(m.find("potrf", 8).is_some());
        assert!(m.find("gemm", 9999).is_none());
    }

    /// The PJRT path must match the pure-Rust oracle on every op —
    /// the L1/L2/L3 numerical contract.
    #[test]
    fn pjrt_matches_cpu_oracle() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = TileEngine::load(&artifacts_dir(), Some(&[8, 16])).unwrap();
        for n in [8usize, 16] {
            let a = spd_tile(n, 1);
            // POTRF
            let l = &eng.execute("potrf", n as u32, &[a.clone()]).unwrap()[0];
            let l_ref = cpu::potrf(&a);
            assert!(l.max_abs_diff(&l_ref) < 1e-9, "potrf n={n}");
            // TRSM
            let b = rand_tile(n, 2);
            let x = &eng.execute("trsm", n as u32, &[l.clone(), b.clone()]).unwrap()[0];
            assert!(x.max_abs_diff(&cpu::trsm(&l_ref, &b)) < 1e-9, "trsm n={n}");
            // SYRK
            let mut c = rand_tile(n, 3);
            let s = &eng.execute("syrk", n as u32, &[c.clone(), x.clone()]).unwrap()[0];
            let mut c_ref = c.clone();
            cpu::syrk(&mut c_ref, x);
            assert!(s.max_abs_diff(&c_ref) < 1e-9, "syrk n={n}");
            // GEMM
            let d = rand_tile(n, 4);
            let g = &eng
                .execute("gemm", n as u32, &[c.clone(), x.clone(), d.clone()])
                .unwrap()[0];
            cpu::gemm(&mut c, x, &d);
            assert!(g.max_abs_diff(&c) < 1e-9, "gemm n={n}");
        }
    }

    #[test]
    fn fused_potrf_trsm_two_outputs() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = TileEngine::load(&artifacts_dir(), Some(&[8])).unwrap();
        let a = spd_tile(8, 5);
        let b = rand_tile(8, 6);
        let outs = eng.execute("potrf_trsm", 8, &[a.clone(), b.clone()]).unwrap();
        assert_eq!(outs.len(), 2);
        let l_ref = cpu::potrf(&a);
        assert!(outs[0].max_abs_diff(&l_ref) < 1e-9);
        assert!(outs[1].max_abs_diff(&cpu::trsm(&l_ref, &b)) < 1e-9);
    }

    #[test]
    fn wrong_arity_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = TileEngine::load(&artifacts_dir(), Some(&[8])).unwrap();
        assert!(eng.execute("gemm", 8, &[Tile::zeros(8)]).is_err());
        assert!(eng.execute("nope", 8, &[]).is_err());
    }
}
