//! Runtime bridge to the AOT-compiled XLA artifacts (L2/L1 outputs).
//!
//! `make artifacts` lowers every (op, tile-size) pair to HLO *text* (see
//! `python/compile/aot.py` for why text, not serialized protos). This
//! module loads them through the PJRT CPU client, compiles once per
//! entry, and exposes:
//!
//! * [`TileEngine`] — single-threaded load + execute (one PJRT client);
//! * [`KernelService`] — a pool of engine-owning threads behind a
//!   channel, because the `xla` crate's handles are `!Send`; worker
//!   threads of the real runtime submit tile ops and block for results;
//! * [`executor`] — [`crate::node::TaskExecutor`] impls with a real tile
//!   data plane (PJRT-backed and pure-Rust);
//! * [`calibrate`] — measures per-op timings and fits the DES cost model.

pub mod calibrate;
pub mod executor;
pub mod pjrt;
pub mod service;

pub use calibrate::calibrate;
pub use executor::{CpuCholeskyExecutor, PjrtCholeskyExecutor};
pub use pjrt::{Manifest, ManifestEntry, TileEngine};
pub use service::KernelService;
