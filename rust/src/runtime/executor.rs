//! Cholesky task-body executors with a real tile data plane.
//!
//! Both executors materialize the tiled SPD input matrix from the
//! workload seed, execute POTRF/TRSM/SYRK/GEMM task bodies against the
//! shared [`TileStore`], and support verification of the finished
//! factorization. The PJRT variant calls the AOT artifacts through the
//! [`KernelService`]; the CPU variant uses the pure-Rust oracle kernels
//! (same data plane, no XLA dependency — used in fast tests and as a
//! cross-check).

use std::sync::Arc;

use crate::dataflow::data::{Tile, TileKey, TileStore};
use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::node::TaskExecutor;
use crate::workloads::cholesky::{spd_tile_entry, CholeskyGraph, TileKind};
use crate::workloads::kernels as cpu;

use super::service::KernelService;

/// Build the tile store for a Cholesky run: every lower-triangle tile
/// materialized from the seed (dense) or zero (sparse), homed by the
/// cyclic distribution.
pub fn build_tile_store(graph: &CholeskyGraph) -> TileStore {
    let p = graph.params();
    let (t, n) = (p.tiles, p.tile_size);
    let mut store = TileStore::new();
    for i in 0..t {
        for j in 0..=i {
            let mut tile = Tile::zeros(n as usize);
            if graph.tile_kind(i, j) == TileKind::Dense {
                for r in 0..n {
                    for c in 0..n {
                        let gi = (i * n + r) as u64;
                        let gj = (j * n + c) as u64;
                        tile.set(r as usize, c as usize, spd_tile_entry(p.seed, t, n, gi, gj));
                    }
                }
            }
            store.insert(TileKey { row: i, col: j }, graph.tile_owner(i, j), tile);
        }
    }
    store
}

/// Which tiles a task reads and writes.
fn io_of(task: TaskDesc) -> (Vec<TileKey>, TileKey) {
    let key = |r: u32, c: u32| TileKey { row: r, col: c };
    match task.class {
        TaskClass::Potrf => (vec![key(task.k, task.k)], key(task.k, task.k)),
        TaskClass::Trsm => (
            vec![key(task.k, task.k), key(task.i, task.k)],
            key(task.i, task.k),
        ),
        TaskClass::Syrk => (
            vec![key(task.i, task.i), key(task.i, task.k)],
            key(task.i, task.i),
        ),
        TaskClass::Gemm => (
            vec![key(task.i, task.j), key(task.i, task.k), key(task.j, task.k)],
            key(task.i, task.j),
        ),
        _ => unreachable!("not a cholesky task"),
    }
}

/// Shared plumbing for both executor variants.
struct CholeskyPlane {
    graph: Arc<CholeskyGraph>,
    store: TileStore,
}

impl CholeskyPlane {
    /// Skip compute when the output tile is sparse (paper §4.4: those
    /// tasks do no useful work, they only flow through the queues).
    fn is_noop(&self, task: TaskDesc) -> bool {
        !self.graph.is_dense_task(task)
    }

    /// Verify ‖L·Lᵀ − A‖∞ over every dense tile (all-dense runs only,
    /// where the factorization is numerically meaningful end to end).
    fn verify(&self, reference: &TileStore) -> f64 {
        let p = self.graph.params();
        let (t, n) = (p.tiles as usize, p.tile_size as usize);
        let mut worst: f64 = 0.0;
        for bi in 0..t {
            for bj in 0..=bi {
                // (L Lᵀ)[bi][bj] = Σ_k L[bi][k] · L[bj][k]ᵀ, k ≤ bj
                let mut acc = Tile::zeros(n);
                for k in 0..=bj {
                    let l_ik = self.store.read(TileKey { row: bi as u32, col: k as u32 }, NodeId(0));
                    let l_jk = self.store.read(TileKey { row: bj as u32, col: k as u32 }, NodeId(0));
                    for r in 0..n {
                        for c in 0..n {
                            let mut s = 0.0;
                            for m in 0..n {
                                // strictly-lower semantics: POTRF output
                                // is already lower-triangular
                                s += l_ik.at(r, m) * l_jk.at(c, m);
                            }
                            acc.set(r, c, acc.at(r, c) + s);
                        }
                    }
                }
                let a = reference.read(TileKey { row: bi as u32, col: bj as u32 }, NodeId(0));
                worst = worst.max(acc.max_abs_diff(&a));
            }
        }
        worst
    }
}

/// PJRT-backed executor: task bodies run the AOT Pallas/JAX artifacts.
pub struct PjrtCholeskyExecutor {
    plane: CholeskyPlane,
    svc: KernelService,
}

impl PjrtCholeskyExecutor {
    pub fn new(graph: Arc<CholeskyGraph>, svc: KernelService) -> Self {
        let store = build_tile_store(&graph);
        PjrtCholeskyExecutor {
            plane: CholeskyPlane { graph, store },
            svc,
        }
    }

    pub fn verify(&self, reference: &TileStore) -> f64 {
        self.plane.verify(reference)
    }

    pub fn store(&self) -> &TileStore {
        &self.plane.store
    }
}

impl TaskExecutor for PjrtCholeskyExecutor {
    fn execute(&self, node: NodeId, task: TaskDesc) {
        if self.plane.is_noop(task) {
            return;
        }
        let n = self.plane.graph.params().tile_size;
        let (inputs, output) = io_of(task);
        let tiles: Vec<Tile> = inputs
            .iter()
            .map(|k| self.plane.store.read(*k, node))
            .collect();
        let op = match task.class {
            TaskClass::Potrf => "potrf",
            TaskClass::Trsm => "trsm",
            TaskClass::Syrk => "syrk",
            TaskClass::Gemm => "gemm",
            _ => unreachable!(),
        };
        // TRSM artifact parameter order is (L, B); io_of already lists
        // the diagonal tile first. GEMM/SYRK list C first, matching the
        // artifacts. POTRF takes just A.
        let outs = self
            .svc
            .execute(op, n, tiles)
            .expect("PJRT kernel execution failed");
        self.plane.store.write(output, outs[0].clone());
    }

    fn name(&self) -> &'static str {
        "pjrt-cholesky"
    }
}

/// Pure-Rust executor: same data plane, oracle kernels.
pub struct CpuCholeskyExecutor {
    plane: CholeskyPlane,
}

impl CpuCholeskyExecutor {
    pub fn new(graph: Arc<CholeskyGraph>) -> Self {
        let store = build_tile_store(&graph);
        CpuCholeskyExecutor {
            plane: CholeskyPlane { graph, store },
        }
    }

    pub fn verify(&self, reference: &TileStore) -> f64 {
        self.plane.verify(reference)
    }

    pub fn store(&self) -> &TileStore {
        &self.plane.store
    }
}

impl TaskExecutor for CpuCholeskyExecutor {
    fn execute(&self, node: NodeId, task: TaskDesc) {
        if self.plane.is_noop(task) {
            return;
        }
        let (inputs, output) = io_of(task);
        let tiles: Vec<Tile> = inputs
            .iter()
            .map(|k| self.plane.store.read(*k, node))
            .collect();
        let result = match task.class {
            TaskClass::Potrf => cpu::potrf(&tiles[0]),
            TaskClass::Trsm => cpu::trsm(&tiles[0], &tiles[1]),
            TaskClass::Syrk => {
                let mut c = tiles[0].clone();
                cpu::syrk(&mut c, &tiles[1]);
                c
            }
            TaskClass::Gemm => {
                let mut c = tiles[0].clone();
                cpu::gemm(&mut c, &tiles[1], &tiles[2]);
                c
            }
            _ => unreachable!(),
        };
        self.plane.store.write(output, result);
    }

    fn name(&self) -> &'static str {
        "cpu-cholesky"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::ttg::TaskGraph;
    use crate::migrate::MigrateConfig;
    use crate::node::{Cluster, ClusterConfig};
    use crate::workloads::CholeskyParams;

    fn dense_graph(tiles: u32, tile_size: u32, nodes: u32) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size,
            nodes,
            dense_fraction: 1.0,
            seed: 77,
            all_dense: true,
        }))
    }

    /// End-to-end on the CPU executor: distributed factorization across
    /// threads + steal protocol must produce a numerically correct L.
    #[test]
    fn distributed_cpu_cholesky_is_correct() {
        for steal in [false, true] {
            let g = dense_graph(4, 8, 2);
            let ex = Arc::new(CpuCholeskyExecutor::new(g.clone()));
            let reference = build_tile_store(&g);
            let cfg = ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(if steal {
                    MigrateConfig::default().with_poll_interval_us(30.0)
                } else {
                    MigrateConfig::disabled()
                })
                .with_seed(11)
                .with_record_polls(false);
            let r = Cluster::run(g.clone(), cfg, ex.clone());
            assert_eq!(r.tasks_total_executed(), g.total_tasks().unwrap());
            let err = ex.verify(&reference);
            assert!(err < 1e-8, "steal={steal}: ‖LLᵀ−A‖∞ = {err}");
        }
    }

    #[test]
    fn sparse_tasks_leave_zero_tiles() {
        let g = Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles: 6,
            tile_size: 4,
            nodes: 2,
            dense_fraction: 0.5,
            seed: 5,
            all_dense: false,
        }));
        let ex = Arc::new(CpuCholeskyExecutor::new(g.clone()));
        let r = Cluster::run(
            g.clone(),
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::disabled()),
            ex.clone(),
        );
        assert_eq!(r.tasks_total_executed(), g.total_tasks().unwrap());
        // sparse tiles were never touched
        for i in 0..6u32 {
            for j in 0..=i {
                if g.tile_kind(i, j) == TileKind::Sparse {
                    let t = ex.store().read(TileKey { row: i, col: j }, NodeId(0));
                    assert!(t.data.iter().all(|&v| v == 0.0));
                }
            }
        }
    }
}
