//! Cluster topology model (`--topology`) and hierarchical steal domains
//! (`--steal-domains`).
//!
//! A [`Topology`] groups nodes into nested tiers — node → socket → rack
//! → cluster — and resolves, for any *pair* of nodes, the [`LinkModel`]
//! of the tightest tier that contains both. It is the single source of
//! per-pair link parameters for every consumer that used to read one
//! node-wide latency/bandwidth pair: the threaded wire model
//! (`comm::Network`), the DES wire scheduling (`sim::Simulator`), the
//! steal/suspicion timeout formulas (`migrate::protocol`) and the
//! victim selector's round-trip price (`migrate::VictimSelector`) —
//! closing the per-victim-link follow-up PR 6 deferred.
//!
//! The `flat` default has no tier structure and no link overrides:
//! [`Topology::link_between`] returns the base link *verbatim* (the
//! same `LinkModel` value, not a recomputation), so a flat run is
//! byte-identical to a build without this module.
//!
//! [`StealDomains::Hierarchical`] makes thieves exhaust their nearest
//! tier before escalating outward: a per-thief [`EscalationState`]
//! starts at the lowest tier that contains a peer and widens one tier
//! after [`TIER_ATTEMPT_BUDGET`] consecutive failed steal attempts
//! (denials or timeouts); any granted steal resets it to the nearest
//! tier. Both runtimes drive the same state machine, so the DES and
//! the threaded runtime cannot diverge on escalation behaviour.

use std::fmt;
use std::str::FromStr;

use crate::comm::LinkModel;

/// Tier indices: 0 = socket, 1 = rack, 2 = cluster. The cluster tier
/// always exists (it is "everyone else"), so escalation terminates.
pub const TIER_COUNT: usize = 3;

/// Human names for the tiers, indexed by [`Topology::tier_of`]'s
/// result — used by report JSON keys and the figure output.
pub const TIER_NAMES: [&str; TIER_COUNT] = ["socket", "rack", "cluster"];

/// Consecutive failed steal attempts (denial or timeout) a thief
/// tolerates at its current tier before widening the steal domain by
/// one tier (`--steal-domains hierarchical`). Two misses ≈ one full
/// retry round under the protocol's per-victim retry budget without
/// letting a single unlucky denial leak traffic across a tier.
pub const TIER_ATTEMPT_BUDGET: u32 = 2;

/// Sentinel for "inherit this parameter from the base link".
const INHERIT: f64 = -1.0;

/// Nested tier model with per-tier link parameters.
///
/// Spec grammar (comma-separated `key=value`, `--topology`):
///
/// ```text
/// flat                        no tiers, base link everywhere (default)
/// socket=N                    nodes per socket (0 = tier absent)
/// rack=N                      nodes per rack (0 = tier absent; when a
///                             socket tier is present, N must be a
///                             multiple of the socket size so tiers nest)
/// socket-lat-us=L, socket-bw=B    intra-socket link (µs, bytes/µs)
/// rack-lat-us=L,   rack-bw=B      intra-rack (cross-socket) link
/// cluster-lat-us=L, cluster-bw=B  cross-rack link
/// ```
///
/// Unset link parameters inherit the run's base `--latency-us`/`--bw`
/// link, so `socket=4,socket-lat-us=1,socket-bw=40000` models a fast
/// intra-socket path with everything else at cluster defaults.
/// `topo.label().parse()` round-trips (property-tested alongside the
/// policy labels in `tests/invariants.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Topology {
    /// Nodes per socket; 0 = socket tier absent.
    pub socket_size: u32,
    /// Nodes per rack; 0 = rack tier absent.
    pub rack_size: u32,
    /// Per-tier latency overrides (µs); negative = inherit base link.
    pub socket_lat_us: f64,
    pub rack_lat_us: f64,
    pub cluster_lat_us: f64,
    /// Per-tier bandwidth overrides (bytes/µs); negative = inherit.
    pub socket_bw: f64,
    pub rack_bw: f64,
    pub cluster_bw: f64,
}

impl Default for Topology {
    /// `flat`: no tier structure, no overrides — every pair resolves to
    /// the base link verbatim.
    fn default() -> Self {
        Topology {
            socket_size: 0,
            rack_size: 0,
            socket_lat_us: INHERIT,
            rack_lat_us: INHERIT,
            cluster_lat_us: INHERIT,
            socket_bw: INHERIT,
            rack_bw: INHERIT,
            cluster_bw: INHERIT,
        }
    }
}

impl Topology {
    /// The flat (default) topology.
    pub fn flat() -> Topology {
        Topology::default()
    }

    /// A 2-tier convenience used by tests, the smoke runs and the
    /// topology figure: sockets of `socket_size` nodes with a fast
    /// intra-socket link, everything else on the (slower) cluster link.
    pub fn two_tier(
        socket_size: u32,
        socket: LinkModel,
        cluster: LinkModel,
    ) -> Topology {
        Topology {
            socket_size,
            socket_lat_us: socket.latency_us,
            socket_bw: socket.bw_bytes_per_us,
            cluster_lat_us: cluster.latency_us,
            cluster_bw: cluster.bw_bytes_per_us,
            ..Topology::default()
        }
    }

    /// No tiers and no overrides: [`Topology::link_between`] is the
    /// identity on the base link and hierarchical stealing degenerates
    /// to one cluster-wide domain.
    pub fn is_flat(&self) -> bool {
        *self == Topology::default()
    }

    /// The tightest tier containing both nodes: 0 = same socket,
    /// 1 = same rack, 2 = cluster. A node shares its own socket with
    /// itself. Absent tiers (size 0) never match, so with no tier
    /// structure every remote pair is cluster-distance.
    pub fn tier_of(&self, a: usize, b: usize) -> usize {
        let same = |size: u32| size > 0 && a / size as usize == b / size as usize;
        if a == b || same(self.socket_size) {
            0
        } else if same(self.rack_size) {
            1
        } else {
            2
        }
    }

    /// The link model of one tier, inheriting unset parameters from
    /// `base`.
    pub fn tier_link(&self, tier: usize, base: LinkModel) -> LinkModel {
        let (lat, bw) = match tier {
            0 => (self.socket_lat_us, self.socket_bw),
            1 => (self.rack_lat_us, self.rack_bw),
            _ => (self.cluster_lat_us, self.cluster_bw),
        };
        LinkModel {
            latency_us: if lat >= 0.0 { lat } else { base.latency_us },
            bw_bytes_per_us: if bw > 0.0 { bw } else { base.bw_bytes_per_us },
        }
    }

    /// Per-pair link resolution — the module's reason to exist. Flat
    /// returns `base` verbatim (bit-for-bit), which is what keeps the
    /// default byte-identical to the pre-topology runtime.
    pub fn link_between(&self, a: usize, b: usize, base: LinkModel) -> LinkModel {
        if self.is_flat() {
            return base;
        }
        self.tier_link(self.tier_of(a, b), base)
    }

    /// The slowest link any pair in an `n`-node run can see — what the
    /// crash detector's suspicion timeout must cover, since suspicion
    /// must outlast a steal round trip to *any* victim.
    pub fn worst_link(&self, n: usize, base: LinkModel) -> LinkModel {
        if self.is_flat() || n < 2 {
            return base;
        }
        let mut worst = self.tier_link(self.tier_of(0, 1), base);
        for peer in 1..n {
            let l = self.tier_link(self.tier_of(0, peer), base);
            if l.latency_us > worst.latency_us
                || (l.latency_us == worst.latency_us && l.bw_bytes_per_us < worst.bw_bytes_per_us)
            {
                worst = l;
            }
        }
        worst
    }

    /// Is `peer` inside `me`'s steal domain at escalation tier `tier`?
    pub fn in_domain(&self, me: usize, peer: usize, tier: usize) -> bool {
        me != peer && self.tier_of(me, peer) <= tier
    }

    /// The peers of `me` (out of `n` nodes) within escalation tier
    /// `tier`, in node-id order.
    pub fn peers_within(&self, me: usize, n: usize, tier: usize) -> Vec<usize> {
        (0..n).filter(|&p| self.in_domain(me, p, tier)).collect()
    }

    /// The lowest tier at which `me` has at least one peer — where a
    /// hierarchical thief starts. The cluster tier always qualifies
    /// when any peer exists at all.
    pub fn start_tier(&self, me: usize, n: usize) -> usize {
        for tier in 0..TIER_COUNT {
            if (0..n).any(|p| self.in_domain(me, p, tier)) {
                return tier;
            }
        }
        TIER_COUNT - 1
    }

    /// Canonical spec string; `topo.label().parse()` round-trips.
    pub fn label(&self) -> String {
        if self.is_flat() {
            return "flat".to_string();
        }
        let mut parts: Vec<String> = Vec::new();
        if self.socket_size > 0 {
            parts.push(format!("socket={}", self.socket_size));
        }
        if self.rack_size > 0 {
            parts.push(format!("rack={}", self.rack_size));
        }
        for (key, v) in [
            ("socket-lat-us", self.socket_lat_us),
            ("socket-bw", self.socket_bw),
            ("rack-lat-us", self.rack_lat_us),
            ("rack-bw", self.rack_bw),
            ("cluster-lat-us", self.cluster_lat_us),
            ("cluster-bw", self.cluster_bw),
        ] {
            if v >= 0.0 {
                parts.push(format!("{key}={v}"));
            }
        }
        parts.join(",")
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

fn parse_size(key: &str, v: &str) -> Result<u32, String> {
    v.parse::<u32>()
        .map_err(|_| format!("--topology: '{key}={v}' is not a node count"))
}

fn parse_lat(key: &str, v: &str) -> Result<f64, String> {
    let t: f64 = v
        .parse()
        .map_err(|_| format!("--topology: '{key}={v}' is not a latency (µs)"))?;
    if t < 0.0 {
        return Err(format!("--topology: '{key}={v}' must be >= 0"));
    }
    Ok(t)
}

fn parse_bw(key: &str, v: &str) -> Result<f64, String> {
    let b: f64 = v
        .parse()
        .map_err(|_| format!("--topology: '{key}={v}' is not a bandwidth (bytes/µs)"))?;
    if b <= 0.0 {
        return Err(format!("--topology: '{key}={v}' must be > 0"));
    }
    Ok(b)
}

impl FromStr for Topology {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let spec = s.trim();
        let mut topo = Topology::default();
        if spec.is_empty() || spec.eq_ignore_ascii_case("flat") {
            return Ok(topo);
        }
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (key, value) = match entry.split_once('=') {
                Some((k, v)) => (k.trim(), v.trim()),
                None => (entry, ""),
            };
            match key.to_ascii_lowercase().as_str() {
                "socket" => topo.socket_size = parse_size(key, value)?,
                "rack" => topo.rack_size = parse_size(key, value)?,
                "socket-lat-us" => topo.socket_lat_us = parse_lat(key, value)?,
                "rack-lat-us" => topo.rack_lat_us = parse_lat(key, value)?,
                "cluster-lat-us" => topo.cluster_lat_us = parse_lat(key, value)?,
                "socket-bw" => topo.socket_bw = parse_bw(key, value)?,
                "rack-bw" => topo.rack_bw = parse_bw(key, value)?,
                "cluster-bw" => topo.cluster_bw = parse_bw(key, value)?,
                other => return Err(format!("--topology: unknown key '{other}'")),
            }
        }
        if topo.socket_size > 0 && topo.rack_size > 0 && topo.rack_size % topo.socket_size != 0 {
            return Err(format!(
                "--topology: rack={} is not a multiple of socket={} (tiers must nest)",
                topo.rack_size, topo.socket_size
            ));
        }
        if topo.rack_size > 0 && topo.socket_size > 0 && topo.rack_size < topo.socket_size {
            return Err(format!(
                "--topology: rack={} is smaller than socket={}",
                topo.rack_size, topo.socket_size
            ));
        }
        Ok(topo)
    }
}

/// How thieves traverse the topology (`--steal-domains`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StealDomains {
    /// One cluster-wide domain — the paper's behaviour, and byte-
    /// identical to the pre-topology runtime. The default.
    #[default]
    Flat,
    /// Exhaust the nearest tier before escalating outward
    /// ([`EscalationState`]); DuctTeip-style hierarchical distribution
    /// applied to stealing.
    Hierarchical,
}

impl StealDomains {
    /// Canonical CLI spelling; accepted back by the [`FromStr`] parser
    /// (round-trip property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> &'static str {
        match self {
            StealDomains::Flat => "flat",
            StealDomains::Hierarchical => "hierarchical",
        }
    }
}

impl fmt::Display for StealDomains {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for StealDomains {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "flat" => Ok(StealDomains::Flat),
            "hierarchical" | "hier" => Ok(StealDomains::Hierarchical),
            _ => Err(format!(
                "unknown steal-domains mode '{s}' (flat | hierarchical)"
            )),
        }
    }
}

/// Per-thief escalation state (`--steal-domains hierarchical`), the one
/// state machine both runtimes drive: start at the lowest tier with a
/// peer, widen one tier after [`TIER_ATTEMPT_BUDGET`] consecutive
/// misses, snap back on any granted steal.
#[derive(Clone, Copy, Debug)]
pub struct EscalationState {
    /// The thief's nearest populated tier (reset target).
    base_tier: usize,
    /// Current escalation tier; candidates are peers within it.
    tier: usize,
    /// Consecutive denials/timeouts at the current tier.
    misses: u32,
}

impl EscalationState {
    /// State for thief `me` in an `n`-node run.
    pub fn new(topo: &Topology, me: usize, n: usize) -> EscalationState {
        let base = topo.start_tier(me, n);
        EscalationState {
            base_tier: base,
            tier: base,
            misses: 0,
        }
    }

    /// The tier whose peers the thief may currently target.
    pub fn tier(&self) -> usize {
        self.tier
    }

    /// A steal was granted: trust the near tier again.
    pub fn on_grant(&mut self) {
        self.tier = self.base_tier;
        self.misses = 0;
    }

    /// A steal attempt failed (denial or timeout): after
    /// [`TIER_ATTEMPT_BUDGET`] consecutive misses, widen the domain by
    /// one tier (saturating at the cluster tier).
    pub fn on_miss(&mut self) {
        self.misses += 1;
        if self.misses >= TIER_ATTEMPT_BUDGET && self.tier + 1 < TIER_COUNT {
            self.tier += 1;
            self.misses = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_is_the_default_and_is_identity() {
        let t = Topology::default();
        assert!(t.is_flat());
        assert_eq!(t.label(), "flat");
        assert_eq!("flat".parse::<Topology>().unwrap(), t);
        assert_eq!("".parse::<Topology>().unwrap(), t);
        // link_between must return the base verbatim (bit-for-bit).
        let base = LinkModel {
            latency_us: 7.25,
            bw_bytes_per_us: 12_345.0,
        };
        let l = t.link_between(0, 9, base);
        assert_eq!(l.latency_us.to_bits(), base.latency_us.to_bits());
        assert_eq!(l.bw_bytes_per_us.to_bits(), base.bw_bytes_per_us.to_bits());
        let ideal = LinkModel::ideal();
        assert!(t.link_between(3, 4, ideal).is_ideal(), "infinity survives");
    }

    #[test]
    fn tier_of_nests_socket_rack_cluster() {
        let t: Topology = "socket=2,rack=4".parse().unwrap();
        assert_eq!(t.tier_of(0, 0), 0, "self is socket-local");
        assert_eq!(t.tier_of(0, 1), 0, "same socket");
        assert_eq!(t.tier_of(0, 2), 1, "same rack, different socket");
        assert_eq!(t.tier_of(1, 3), 1);
        assert_eq!(t.tier_of(0, 4), 2, "different rack");
        assert_eq!(t.tier_of(5, 2), 2);
        // Rack-only topology: no socket tier for remote peers.
        let r: Topology = "rack=4".parse().unwrap();
        assert_eq!(r.tier_of(0, 1), 1);
        assert_eq!(r.tier_of(0, 5), 2);
        assert_eq!(r.tier_of(2, 2), 0, "self is always tier 0");
    }

    #[test]
    fn link_between_resolves_the_tightest_tier() {
        let base = LinkModel::cluster(); // 5 µs, 10_000 B/µs
        let t: Topology =
            "socket=2,rack=4,socket-lat-us=1,socket-bw=40000,cluster-lat-us=20,cluster-bw=2500"
                .parse()
                .unwrap();
        let s = t.link_between(0, 1, base);
        assert_eq!((s.latency_us, s.bw_bytes_per_us), (1.0, 40_000.0));
        // Rack tier has no overrides: inherits the base link.
        let r = t.link_between(0, 2, base);
        assert_eq!((r.latency_us, r.bw_bytes_per_us), (5.0, 10_000.0));
        let c = t.link_between(0, 4, base);
        assert_eq!((c.latency_us, c.bw_bytes_per_us), (20.0, 2_500.0));
        // worst_link covers the slowest reachable pair.
        let w = t.worst_link(8, base);
        assert_eq!((w.latency_us, w.bw_bytes_per_us), (20.0, 2_500.0));
        // …but a 2-node run never leaves the socket.
        let w2 = t.worst_link(2, base);
        assert_eq!((w2.latency_us, w2.bw_bytes_per_us), (1.0, 40_000.0));
    }

    #[test]
    fn label_round_trips() {
        for spec in [
            "flat",
            "socket=4",
            "socket=4,socket-lat-us=1,socket-bw=40000",
            "socket=2,rack=8,socket-lat-us=0.5,socket-bw=50000,rack-lat-us=5,rack-bw=10000,cluster-lat-us=20,cluster-bw=2500",
            "rack=16,rack-lat-us=2,cluster-lat-us=25",
        ] {
            let t: Topology = spec.parse().unwrap();
            let back: Topology = t.label().parse().unwrap();
            assert_eq!(back, t, "label round-trip for '{spec}' via '{}'", t.label());
        }
    }

    #[test]
    fn parser_rejects_bad_specs() {
        assert!("socket=x".parse::<Topology>().is_err());
        assert!("socket-lat-us=-3".parse::<Topology>().is_err());
        assert!("socket-bw=0".parse::<Topology>().is_err());
        assert!("bogus=1".parse::<Topology>().is_err());
        assert!(
            "socket=3,rack=8".parse::<Topology>().is_err(),
            "tiers must nest"
        );
    }

    #[test]
    fn steal_domains_labels_round_trip() {
        assert_eq!(StealDomains::default(), StealDomains::Flat);
        for d in [StealDomains::Flat, StealDomains::Hierarchical] {
            assert_eq!(d.label().parse::<StealDomains>().unwrap(), d);
        }
        assert_eq!("hier".parse::<StealDomains>().unwrap(), StealDomains::Hierarchical);
        assert!("ring".parse::<StealDomains>().is_err());
    }

    #[test]
    fn domain_membership_and_start_tier() {
        let t: Topology = "socket=2,rack=4".parse().unwrap();
        assert_eq!(t.peers_within(0, 8, 0), vec![1]);
        assert_eq!(t.peers_within(0, 8, 1), vec![1, 2, 3]);
        assert_eq!(t.peers_within(0, 8, 2), vec![1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(t.start_tier(0, 8), 0);
        // A node whose socket-mate is out of range starts at the rack.
        let odd: Topology = "socket=2,rack=4".parse().unwrap();
        assert_eq!(odd.start_tier(2, 3), 1, "node 3 absent: rack is nearest");
        // Flat: every peer is cluster-distance.
        let flat = Topology::flat();
        assert_eq!(flat.start_tier(0, 8), 2);
        assert_eq!(flat.peers_within(1, 4, 2), vec![0, 2, 3]);
    }

    #[test]
    fn escalation_widens_on_budget_and_snaps_back_on_grant() {
        let t: Topology = "socket=2,rack=4".parse().unwrap();
        let mut e = EscalationState::new(&t, 0, 8);
        assert_eq!(e.tier(), 0);
        e.on_miss();
        assert_eq!(e.tier(), 0, "one miss is within budget");
        e.on_miss();
        assert_eq!(e.tier(), 1, "budget exhausted: widen to the rack");
        e.on_miss();
        e.on_miss();
        assert_eq!(e.tier(), 2, "…then the cluster");
        e.on_miss();
        e.on_miss();
        assert_eq!(e.tier(), 2, "cluster is terminal");
        e.on_grant();
        assert_eq!(e.tier(), 0, "a grant resets to the nearest tier");
        // A thief with no socket mate starts (and resets) at its base.
        let mut lone = EscalationState::new(&Topology::flat(), 1, 4);
        assert_eq!(lone.tier(), 2);
        lone.on_grant();
        assert_eq!(lone.tier(), 2);
    }
}
