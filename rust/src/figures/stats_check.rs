//! §4 methodology checks: normality of execution times
//! (D'Agostino–Pearson + Shapiro–Wilk) and one-way ANOVA between the
//! steal and no-steal populations.

use anyhow::Result;

use crate::migrate::MigrateConfig;
use crate::stats::{anova_one_way, dagostino_pearson, shapiro_wilk};
use crate::util::json::Json;

use super::common::Ctx;

pub fn run(ctx: &Ctx) -> Result<String> {
    let nodes = 8; // where the paper's effect peaks
    let reps = ctx.seeds.max(12); // normality tests want n >= 8
    let mut no_steal = Vec::new();
    let mut steal = Vec::new();
    for s in 0..reps {
        no_steal.push(
            ctx.run_cholesky(nodes, MigrateConfig::disabled(), 5000 + s, false)
                .makespan_us
                / 1e6,
        );
        let half = MigrateConfig::default().with_victim(crate::migrate::VictimPolicy::Half);
        steal.push(ctx.run_cholesky(nodes, half, 6000 + s, false).makespan_us / 1e6);
    }
    let mut out = String::new();
    out.push_str(&format!("§4 statistics ({} runs per group, {nodes} nodes)\n", reps));
    for (label, xs) in [("No-Steal", &no_steal), ("Steal", &steal)] {
        let dp = dagostino_pearson(xs);
        let sw = shapiro_wilk(xs);
        out.push_str(&format!(
            "{label:<10} D'Agostino-Pearson K²={:.2} p={:.3}   Shapiro-Wilk W={:.4} p={:.3}\n",
            dp.statistic, dp.p_value, sw.statistic, sw.p_value
        ));
    }
    let an = anova_one_way(&[&no_steal, &steal]);
    out.push_str(&format!(
        "ANOVA steal vs no-steal: F({:.0},{:.0}) = {:.2}, p = {:.4} -> {}\n",
        an.df_between,
        an.df_within,
        an.f_statistic,
        an.p_value,
        if an.significant(0.05) {
            "different distributions (matches the paper)"
        } else {
            "NOT significant at this scale"
        }
    ));
    ctx.write_json(
        "stats",
        &Json::obj(vec![
            ("anova_f", Json::Num(an.f_statistic)),
            ("anova_p", Json::Num(an.p_value)),
            (
                "no_steal_s",
                Json::Arr(no_steal.iter().map(|t| Json::Num(*t)).collect()),
            ),
            ("steal_s", Json::Arr(steal.iter().map(|t| Json::Num(*t)).collect())),
        ]),
    )?;
    Ok(out)
}
