//! Table 1 — speedup per victim policy across tile sizes (granularity).
//! Shape: work stealing gets more effective as granularity grows; at the
//! smallest tiles Half drops below 1.0 (stealing *hurts*) and Chunk
//! outperforms Half.

use anyhow::Result;

use crate::stats::Summary;
use crate::util::json::Json;

use super::common::{victim_cells, Ctx};

pub const TILE_SIZES: [u32; 5] = [10, 20, 30, 40, 50];

pub fn run(ctx: &Ctx) -> Result<String> {
    let nodes = 4;
    let mut out = String::new();
    out.push_str("Table 1 — execution time (s) and speedup vs tile size (4 nodes)\n");
    out.push_str(&format!(
        "{:<10} {:>9} {:>9} {:>9} {:>9} | {:>7} {:>7} {:>7}\n",
        "tile", "No-Steal", "Chunk", "Half", "Single", "S.Chunk", "S.Half", "S.Single"
    ));
    let mut json_rows = Vec::new();
    for tile in TILE_SIZES {
        let mut means = std::collections::BTreeMap::new();
        for cell in victim_cells(ctx.scale, true) {
            let mut times = Vec::new();
            for s in 0..ctx.seeds {
                let graph = ctx.cholesky_custom(nodes, ctx.scale.tiles(), tile, 0);
                let r = ctx.run_cholesky_graph(graph, cell.migrate, 4000 + s, false);
                times.push(r.makespan_us / 1e6);
            }
            means.insert(cell.label.clone(), Summary::of(&times).mean);
        }
        let base = means["No-Steal"];
        out.push_str(&format!(
            "{:<10} {:>9.3} {:>9.3} {:>9.3} {:>9.3} | {:>7.3} {:>7.3} {:>7.3}\n",
            format!("{tile}x{tile}"),
            base,
            means["Chunk"],
            means["Half"],
            means["Single"],
            base / means["Chunk"],
            base / means["Half"],
            base / means["Single"],
        ));
        json_rows.push(Json::obj(vec![
            ("tile", Json::from(tile as u64)),
            ("no_steal_s", Json::Num(base)),
            ("chunk_s", Json::Num(means["Chunk"])),
            ("half_s", Json::Num(means["Half"])),
            ("single_s", Json::Num(means["Single"])),
            ("speedup_chunk", Json::Num(base / means["Chunk"])),
            ("speedup_half", Json::Num(base / means["Half"])),
            ("speedup_single", Json::Num(base / means["Single"])),
        ]));
    }
    ctx.write_json("table1", &Json::obj(vec![("rows", Json::Arr(json_rows))]))?;
    Ok(out)
}
