//! Fig. 1 — potential for work stealing `E^b` per interval, No-Steal
//! runs on 2–16 nodes. Shape to reproduce: highest at the start of the
//! run, decaying as execution progresses, with the 8-node curve staying
//! highest late in execution.

use anyhow::Result;

use crate::migrate::MigrateConfig;
use crate::util::json::Json;

use super::common::Ctx;

pub const INTERVALS: usize = 20;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig.1 — potential for work stealing E^b (No-Steal)\n");
    out.push_str(&format!(
        "matrix: {0}x{0} tiles of 50x50, 50% dense, cyclic; {1} intervals\n",
        ctx.scale.tiles(),
        INTERVALS
    ));
    let mut json_series = Vec::new();
    for nodes in [2u32, 4, 8, 16] {
        let report = ctx.run_cholesky(nodes, MigrateConfig::disabled(), 42, true);
        let interval = report.makespan_us / INTERVALS as f64;
        let series = report.potential_series(interval);
        out.push_str(&format!("\nnodes={nodes} (makespan {:.2}s)\n  E^b:", report.makespan_us / 1e6));
        for e in &series {
            out.push_str(&format!(" {e:.2}"));
        }
        out.push('\n');
        json_series.push(Json::obj(vec![
            ("nodes", Json::from(nodes as u64)),
            ("makespan_us", Json::Num(report.makespan_us)),
            ("interval_us", Json::Num(interval)),
            ("e_b", Json::Arr(series.iter().map(|e| Json::Num(*e)).collect())),
        ]));
    }
    ctx.write_json("fig1", &Json::obj(vec![("series", Json::Arr(json_series))]))?;
    Ok(out)
}
