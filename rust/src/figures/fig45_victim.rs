//! Fig. 4 — execution time per victim policy across node counts (multi-
//! run distributions; stealing reduces run-to-run variance), and
//! Fig. 5 — speedup vs No-Steal (peaks near 8 nodes, ~1.35×, declining
//! at larger node counts as the potential for stealing shrinks).

use anyhow::Result;

use crate::stats::Summary;
use crate::util::json::Json;

use super::common::{fmt_summary, victim_cells, Ctx};

pub const NODE_COUNTS: [u32; 4] = [2, 4, 8, 16];

/// Shared sweep for fig4/fig5/fig8: every victim policy × node count ×
/// seed, returning (policy label, nodes, times, success %). Honors the
/// harness's `--victim-select` mode (uniform keeps every cell — and
/// therefore every figure artifact — identical to the pre-selector
/// output; targeted re-renders the same sweep as the ablation arm).
pub fn sweep(ctx: &Ctx) -> Vec<(String, u32, Vec<f64>, f64)> {
    let mut rows = Vec::new();
    for nodes in NODE_COUNTS {
        for cell in victim_cells(ctx.scale, true) {
            let migrate = ctx.ov.apply_migrate(cell.migrate);
            let mut times = Vec::new();
            let mut success = 0.0;
            for s in 0..ctx.seeds {
                let r = ctx.run_cholesky(nodes, migrate, 2000 + s, false);
                times.push(r.makespan_us / 1e6);
                success += r.total_steals().success_pct();
            }
            rows.push((
                cell.label.clone(),
                nodes,
                times,
                success / ctx.seeds as f64,
            ));
        }
    }
    rows
}

pub fn run_fig4(ctx: &Ctx, rows: &[(String, u32, Vec<f64>, f64)]) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig.4 — execution time per victim policy × nodes (multi-run)\n");
    let mut json_rows = Vec::new();
    for nodes in NODE_COUNTS {
        out.push_str(&format!("\nnodes={nodes}\n"));
        for (label, n, times, _) in rows.iter().filter(|(_, n, _, _)| *n == nodes) {
            out.push_str(&format!("  {}\n", fmt_summary(label, times)));
            json_rows.push(Json::obj(vec![
                ("policy", Json::from(label.as_str())),
                ("nodes", Json::from(*n as u64)),
                ("times_s", Json::Arr(times.iter().map(|t| Json::Num(*t)).collect())),
            ]));
        }
        // variance-reduction check (the paper's §4.4 observation)
        let cv_of = |lbl: &str| {
            rows.iter()
                .find(|(l, n, _, _)| l == lbl && *n == nodes)
                .map(|(_, _, t, _)| Summary::of(t).cv())
                .unwrap_or(0.0)
        };
        out.push_str(&format!(
            "  cv: No-Steal {:.3} vs best-steal {:.3}\n",
            cv_of("No-Steal"),
            ["Chunk", "Half", "Single"]
                .iter()
                .map(|l| cv_of(l))
                .fold(f64::INFINITY, f64::min)
        ));
    }
    ctx.write_json("fig4", &Json::obj(vec![("rows", Json::Arr(json_rows))]))?;
    Ok(out)
}

pub fn run_fig5(ctx: &Ctx, rows: &[(String, u32, Vec<f64>, f64)]) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig.5 — speedup vs No-Steal per victim policy × nodes\n");
    out.push_str(&format!(
        "{:<8} {:>10} {:>10} {:>10}\n",
        "nodes", "Chunk", "Half", "Single"
    ));
    let mut json_rows = Vec::new();
    for nodes in NODE_COUNTS {
        let base = rows
            .iter()
            .find(|(l, n, _, _)| l == "No-Steal" && *n == nodes)
            .map(|(_, _, t, _)| Summary::of(t).mean)
            .unwrap();
        let mut line = format!("{nodes:<8}");
        for policy in ["Chunk", "Half", "Single"] {
            let mean = rows
                .iter()
                .find(|(l, n, _, _)| l == policy && *n == nodes)
                .map(|(_, _, t, _)| Summary::of(t).mean)
                .unwrap();
            let speedup = base / mean;
            line.push_str(&format!(" {speedup:>10.3}"));
            json_rows.push(Json::obj(vec![
                ("policy", Json::from(policy)),
                ("nodes", Json::from(nodes as u64)),
                ("speedup", Json::Num(speedup)),
            ]));
        }
        out.push_str(&line);
        out.push('\n');
    }
    ctx.write_json("fig5", &Json::obj(vec![("rows", Json::Arr(json_rows))]))?;
    Ok(out)
}
