//! Shared plumbing for the figure harness: scaled experiment configs,
//! batched simulator runs, and output formatting.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::metrics::RunReport;
use crate::migrate::{MigrateConfig, VictimPolicy, VictimSelect};
use crate::sched::SchedBackend;
use crate::sim::{CostModel, SimConfig, Simulator};
use crate::stats::Summary;
use crate::topology::{StealDomains, Topology};
use crate::util::json::Json;
use crate::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

/// Experiment scale: `Small` finishes `figure all` in minutes on this
/// container; `Paper` uses the paper's exact matrix geometry (much
/// slower — millions of tasks per run).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Scale {
        if s.eq_ignore_ascii_case("paper") {
            Scale::Paper
        } else {
            Scale::Small
        }
    }

    /// Tiles per side for the headline matrix (paper: 200² tiles of 50²).
    pub fn tiles(self) -> u32 {
        match self {
            Scale::Small => 48,
            Scale::Paper => 200,
        }
    }

    /// Workers per node (paper: 40).
    pub fn workers(self) -> usize {
        match self {
            Scale::Small => 8,
            Scale::Paper => 40,
        }
    }

    /// Chunk size = half the worker threads (paper: 20).
    pub fn chunk(self) -> usize {
        self.workers() / 2
    }
}

/// One experiment cell: a workload + policy + seed.
#[derive(Clone, Debug)]
pub struct Cell {
    pub label: String,
    pub migrate: MigrateConfig,
}

/// Cross-cutting knobs one `repro figure` invocation stamps onto every
/// simulation a figure runs (`--sched`, `--victim-select`,
/// `--topology`, `--steal-domains`). [`RunOverrides::default`] is the
/// identity: figures rendered with it are byte-identical to a harness
/// with no override support at all, so re-rendering a sweep under a
/// different scheduler or topology is one flag, not a figure rewrite.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RunOverrides {
    /// Scheduler backend every figure's simulations run on.
    pub sched: SchedBackend,
    /// Victim selection every steal-enabled cell runs with: uniform is
    /// the paper's protocol; targeted re-renders the same figures under
    /// the scored selector for the uniform-vs-targeted ablation.
    pub victim_select: VictimSelect,
    /// Link topology every simulation prices communication on.
    pub topology: Topology,
    /// Steal-domain policy (flat victim choice vs tier escalation).
    pub steal_domains: StealDomains,
}

impl Default for RunOverrides {
    fn default() -> Self {
        RunOverrides {
            sched: SchedBackend::Central,
            victim_select: VictimSelect::Uniform,
            topology: Topology::flat(),
            steal_domains: StealDomains::Flat,
        }
    }
}

impl RunOverrides {
    /// Select the scheduler backend the figures sweep on.
    pub fn with_sched(mut self, sched: SchedBackend) -> Self {
        self.sched = sched;
        self
    }

    /// Select the victim-selection mode the figures sweep on.
    pub fn with_victim_select(mut self, select: VictimSelect) -> Self {
        self.victim_select = select;
        self
    }

    /// Select the link topology the figures sweep on.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Select the steal-domain policy the figures sweep on.
    pub fn with_steal_domains(mut self, domains: StealDomains) -> Self {
        self.steal_domains = domains;
        self
    }

    /// Apply the victim-selection override to a cell's steal policy;
    /// disabled cells (No-Steal) pass through untouched.
    pub fn apply_migrate(&self, mut migrate: MigrateConfig) -> MigrateConfig {
        if migrate.enabled {
            migrate.victim_select = self.victim_select;
        }
        migrate
    }

    /// Stamp the scheduler/topology overrides onto a simulator config.
    pub fn apply_sim(&self, cfg: SimConfig) -> SimConfig {
        cfg.with_sched(self.sched)
            .with_topology(self.topology)
            .with_steal_domains(self.steal_domains)
    }
}

/// Harness context threaded through every figure.
pub struct Ctx {
    pub scale: Scale,
    pub seeds: u64,
    pub cost: CostModel,
    pub out_dir: std::path::PathBuf,
    /// Overrides stamped onto every run this context performs.
    pub ov: RunOverrides,
}

impl Ctx {
    pub fn new(scale: Scale, seeds: u64, artifacts_dir: &Path, out_dir: &Path) -> Ctx {
        std::fs::create_dir_all(out_dir).ok();
        Ctx {
            scale,
            seeds,
            cost: CostModel::load_or_default(&artifacts_dir.join("costmodel.json")),
            out_dir: out_dir.to_path_buf(),
            ov: RunOverrides::default(),
        }
    }

    /// Install the run overrides for every figure this context renders
    /// — the single entry point that replaced the per-knob
    /// `with_sched`/`with_victim_select` setters.
    pub fn overrides(mut self, ov: RunOverrides) -> Ctx {
        self.ov = ov;
        self
    }

    pub fn cholesky(&self, nodes: u32, seed: u64) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles: self.scale.tiles(),
            tile_size: 50,
            nodes,
            dense_fraction: 0.5,
            seed: 0xC404 ^ seed,
            all_dense: false,
        }))
    }

    pub fn cholesky_custom(
        &self,
        nodes: u32,
        tiles: u32,
        tile_size: u32,
        seed: u64,
    ) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size,
            nodes,
            dense_fraction: 0.5,
            seed: 0xC404 ^ seed,
            all_dense: false,
        }))
    }

    pub fn uts(&self, nodes: u32, seed: u64) -> Arc<UtsGraph> {
        // Paper's Fig.7 parameters, depth-capped to keep tree size sane;
        // granularity g converts through the cost model.
        let (b0, g) = match self.scale {
            Scale::Small => (64, 200_000.0),
            Scale::Paper => (120, 12e6),
        };
        Arc::new(UtsGraph::new(UtsParams {
            b0,
            m: 5,
            q: 0.200014,
            g,
            seed: 0x075 ^ seed,
            nodes,
            max_depth: 24,
        }))
    }

    pub fn run_cholesky(
        &self,
        nodes: u32,
        migrate: MigrateConfig,
        seed: u64,
        record_polls: bool,
    ) -> RunReport {
        let graph = self.cholesky(nodes, 0); // same matrix across seeds
        let cfg = self.ov.apply_sim(
            SimConfig::default()
                .with_workers_per_node(self.scale.workers())
                .with_seed(seed)
                .with_record_polls(record_polls),
        );
        Simulator::new(graph, cfg, self.cost.clone(), self.ov.apply_migrate(migrate), 50).run()
    }

    pub fn run_cholesky_graph(
        &self,
        graph: Arc<CholeskyGraph>,
        migrate: MigrateConfig,
        seed: u64,
        record_polls: bool,
    ) -> RunReport {
        let tile = graph.params().tile_size;
        let cfg = self.ov.apply_sim(
            SimConfig::default()
                .with_workers_per_node(self.scale.workers())
                .with_seed(seed)
                .with_record_polls(record_polls),
        );
        Simulator::new(graph, cfg, self.cost.clone(), self.ov.apply_migrate(migrate), tile).run()
    }

    pub fn run_uts(&self, nodes: u32, migrate: MigrateConfig, seed: u64) -> RunReport {
        let graph = self.uts(nodes, 0);
        let cfg = self.ov.apply_sim(
            SimConfig::default()
                .with_workers_per_node(self.scale.workers())
                .with_seed(seed)
                .with_record_polls(false),
        );
        Simulator::new(graph, cfg, self.cost.clone(), self.ov.apply_migrate(migrate), 0).run()
    }

    /// Execution times (seconds of virtual time) across seeds.
    pub fn exec_times_cholesky(&self, nodes: u32, migrate: MigrateConfig) -> Vec<f64> {
        (0..self.seeds)
            .map(|s| self.run_cholesky(nodes, migrate, 1000 + s, false).makespan_us / 1e6)
            .collect()
    }

    pub fn write_json(&self, name: &str, j: &Json) -> Result<()> {
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, j.pretty())?;
        Ok(())
    }
}

/// Standard policy set for the victim-policy figures.
pub fn victim_cells(scale: Scale, waiting_time: bool) -> Vec<Cell> {
    let mk = |victim| {
        MigrateConfig::default()
            .with_victim(victim)
            .with_use_waiting_time(waiting_time)
    };
    vec![
        Cell {
            label: "No-Steal".into(),
            migrate: MigrateConfig::disabled(),
        },
        Cell {
            label: "Chunk".into(),
            migrate: mk(VictimPolicy::Chunk(scale.chunk())),
        },
        Cell {
            label: "Half".into(),
            migrate: mk(VictimPolicy::Half),
        },
        Cell {
            label: "Single".into(),
            migrate: mk(VictimPolicy::Single),
        },
    ]
}

/// Render a mean±sd table row.
pub fn fmt_summary(label: &str, xs: &[f64]) -> String {
    let s = Summary::of(xs);
    format!(
        "{label:<22} mean {:>9.4}s  sd {:>8.4}s  min {:>9.4}s  max {:>9.4}s  cv {:>6.3}",
        s.mean, s.std, s.min, s.max, s.cv()
    )
}
