//! Fig. 8 — steal success percentage per victim policy × nodes. Shape:
//! Chunk has the highest success rate under high imbalance, yet Fig. 5
//! shows Single gets the best speedup — stealing *more* does not mean
//! stealing *better*.

use anyhow::Result;

use crate::util::json::Json;

use super::common::Ctx;
use super::fig45_victim::NODE_COUNTS;

pub fn run(ctx: &Ctx, rows: &[(String, u32, Vec<f64>, f64)]) -> Result<String> {
    let mut out = String::new();
    out.push_str("Fig.8 — steal success percentage per victim policy × nodes\n");
    out.push_str(&format!(
        "{:<8} {:>10} {:>10} {:>10}\n",
        "nodes", "Chunk", "Half", "Single"
    ));
    let mut json_rows = Vec::new();
    for nodes in NODE_COUNTS {
        let mut line = format!("{nodes:<8}");
        for policy in ["Chunk", "Half", "Single"] {
            let pct = rows
                .iter()
                .find(|(l, n, _, _)| l == policy && *n == nodes)
                .map(|(_, _, _, pct)| *pct)
                .unwrap_or(0.0);
            line.push_str(&format!(" {pct:>9.1}%"));
            json_rows.push(Json::obj(vec![
                ("policy", Json::from(policy)),
                ("nodes", Json::from(nodes as u64)),
                ("success_pct", Json::Num(pct)),
            ]));
        }
        out.push_str(&line);
        out.push('\n');
    }
    ctx.write_json("fig8", &Json::obj(vec![("rows", Json::Arr(json_rows))]))?;
    Ok(out)
}
