//! Figure/table regeneration harness — one entry per table and figure in
//! the paper's evaluation (§4). See DESIGN.md's experiment index for the
//! workload, parameters and "shape that must hold" per experiment.
//!
//! Entry point: [`run`] with a figure id (`fig1`..`fig9`, `table1`,
//! `stats`, or `all`). Output goes to stdout and `<out>/<id>.json`.

pub mod common;
pub mod fig1_potential;
pub mod fig2_thief;
pub mod fig3_arrival;
pub mod fig45_victim;
pub mod fig6_waiting;
pub mod fig7_uts;
pub mod fig8_success;
pub mod fig9_domains;
pub mod stats_check;
pub mod table1_granularity;

use anyhow::{bail, Result};

pub use common::{Ctx, RunOverrides, Scale};

pub const ALL_IDS: [&str; 11] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table1", "stats",
];

/// Run one figure (or `all`); returns the rendered report text.
pub fn run(ctx: &Ctx, id: &str) -> Result<String> {
    match id {
        "fig1" => fig1_potential::run(ctx),
        "fig2" => fig2_thief::run(ctx),
        "fig3" => fig3_arrival::run(ctx),
        "fig4" | "fig5" | "fig8" => {
            // Shared sweep: compute once, render the requested view.
            let rows = fig45_victim::sweep(ctx);
            match id {
                "fig4" => fig45_victim::run_fig4(ctx, &rows),
                "fig5" => fig45_victim::run_fig5(ctx, &rows),
                _ => fig8_success::run(ctx, &rows),
            }
        }
        "fig6" => fig6_waiting::run(ctx),
        "fig7" => fig7_uts::run(ctx),
        "fig9" => fig9_domains::run(ctx),
        "table1" => table1_granularity::run(ctx),
        "stats" => stats_check::run(ctx),
        "all" => {
            let mut out = String::new();
            out.push_str(&fig1_potential::run(ctx)?);
            out.push('\n');
            out.push_str(&fig2_thief::run(ctx)?);
            out.push('\n');
            out.push_str(&fig3_arrival::run(ctx)?);
            out.push('\n');
            let rows = fig45_victim::sweep(ctx);
            out.push_str(&fig45_victim::run_fig4(ctx, &rows)?);
            out.push('\n');
            out.push_str(&fig45_victim::run_fig5(ctx, &rows)?);
            out.push('\n');
            out.push_str(&fig8_success::run(ctx, &rows)?);
            out.push('\n');
            out.push_str(&fig6_waiting::run(ctx)?);
            out.push('\n');
            out.push_str(&fig7_uts::run(ctx)?);
            out.push('\n');
            out.push_str(&fig9_domains::run(ctx)?);
            out.push('\n');
            out.push_str(&table1_granularity::run(ctx)?);
            out.push('\n');
            out.push_str(&stats_check::run(ctx)?);
            Ok(out)
        }
        other => bail!("unknown figure id '{other}' (try: {} or all)", ALL_IDS.join(", ")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn unknown_id_is_error() {
        let dir = std::env::temp_dir().join("parsteal-figtest-err");
        let ctx = Ctx::new(Scale::Small, 1, Path::new("artifacts"), &dir);
        assert!(run(&ctx, "fig99").is_err());
    }
}
