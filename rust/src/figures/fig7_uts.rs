//! Fig. 7 — victim policies on the UTS benchmark
//! (b0=120, m=5, q=0.200014, g=12e6; child-follows-parent placement).
//! Shape (matching Perarnau & Sato and the paper): Half ≈ Single, both
//! far better than small fixed chunks; everything beats No-Steal by an
//! enormous factor because without stealing the whole tree runs on one
//! node.

use anyhow::Result;

use crate::util::json::Json;

use super::common::{fmt_summary, victim_cells, Ctx};

pub fn run(ctx: &Ctx) -> Result<String> {
    let nodes = 4;
    let mut out = String::new();
    out.push_str("Fig.7 — UTS victim policies (4 nodes)\n");
    let tree = ctx.uts(nodes, 0);
    out.push_str(&format!("tree size: {} nodes\n", tree.tree_size(100_000_000)));
    let mut rows = Vec::new();
    for cell in victim_cells(ctx.scale, true) {
        let mut times = Vec::new();
        for s in 0..ctx.seeds {
            let r = ctx.run_uts(nodes, cell.migrate, 3000 + s);
            times.push(r.makespan_us / 1e6);
        }
        out.push_str(&format!("  {}\n", fmt_summary(&cell.label, &times)));
        rows.push(Json::obj(vec![
            ("policy", Json::from(cell.label.as_str())),
            ("times_s", Json::Arr(times.iter().map(|t| Json::Num(*t)).collect())),
        ]));
    }
    ctx.write_json("fig7", &Json::obj(vec![("rows", Json::Arr(rows))]))?;
    Ok(out)
}
