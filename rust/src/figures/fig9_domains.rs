//! Fig. 9 — flat vs hierarchical steal domains on a two-tier topology
//! at cluster scale (128 simulated nodes, 16 sockets of 8). UTS puts
//! all roots on node 0, so every other node must steal its work; flat
//! victim choice sends almost every request over the slow cluster
//! links, while hierarchical domains exhaust the thief's socket before
//! escalating. Shape: hierarchical moves markedly fewer steal requests
//! and payload bytes across sockets at equal seeds, without losing the
//! makespan benefit of stealing.

use anyhow::Result;

use crate::comm::LinkModel;
use crate::migrate::MigrateConfig;
use crate::sim::{SimConfig, Simulator};
use crate::stats::Summary;
use crate::topology::{StealDomains, Topology, TIER_COUNT, TIER_NAMES};
use crate::util::json::Json;

use super::common::{fmt_summary, Ctx};

/// Simulated node count — past the hundred-node mark so cross-tier
/// traffic dominates under flat victim choice.
pub const NODES: u32 = 128;
/// Nodes per socket domain in the two-tier topology.
pub const SOCKET_SIZE: u32 = 8;

/// The two-tier topology every Fig. 9 cell runs on: fast intra-socket
/// links, slow everything-else.
pub fn two_tier() -> Topology {
    Topology::two_tier(
        SOCKET_SIZE,
        LinkModel {
            latency_us: 1.0,
            bw_bytes_per_us: 40_000.0,
        },
        LinkModel {
            latency_us: 20.0,
            bw_bytes_per_us: 2_500.0,
        },
    )
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let topo = two_tier();
    let mut out = String::new();
    out.push_str(&format!(
        "Fig.9 — flat vs hierarchical steal domains (UTS, {NODES} nodes, topology {})\n",
        topo.label()
    ));
    let mut json_rows = Vec::new();
    for domains in [StealDomains::Flat, StealDomains::Hierarchical] {
        let mut times = Vec::new();
        let mut cross_req = Vec::new();
        let mut cross_bytes = Vec::new();
        let mut tier_req = [0u64; TIER_COUNT];
        for s in 0..ctx.seeds {
            let graph = ctx.uts(NODES, 0); // same tree across seeds
            let cfg = ctx
                .ov
                .apply_sim(
                    SimConfig::default()
                        .with_workers_per_node(ctx.scale.workers())
                        .with_seed(9000 + s)
                        .with_record_polls(false),
                )
                .with_topology(topo)
                .with_steal_domains(domains);
            let migrate = ctx.ov.apply_migrate(MigrateConfig::default());
            let r = Simulator::new(graph, cfg, ctx.cost.clone(), migrate, 0).run();
            times.push(r.makespan_us / 1e6);
            cross_req.push(r.cross_tier_steal_requests() as f64);
            cross_bytes.push(r.cross_tier_steal_bytes() as f64);
            let tiers = r.tier_steal_totals();
            for (acc, (req, _, _)) in tier_req.iter_mut().zip(tiers) {
                *acc += req;
            }
        }
        let label = domains.label();
        out.push_str(&format!("\n{label}\n"));
        out.push_str(&format!("  {}\n", fmt_summary("makespan", &times)));
        let req = Summary::of(&cross_req);
        let bytes = Summary::of(&cross_bytes);
        out.push_str(&format!(
            "  cross-tier: {:.0} requests, {:.0} payload bytes (mean/seed)\n",
            req.mean, bytes.mean
        ));
        let per_tier = TIER_NAMES
            .iter()
            .zip(tier_req)
            .map(|(name, r)| format!("{name} {r}"))
            .collect::<Vec<_>>()
            .join(", ");
        out.push_str(&format!("  requests by tier (all seeds): {per_tier}\n"));
        json_rows.push(Json::obj(vec![
            ("domains", Json::from(label)),
            ("nodes", Json::from(NODES as u64)),
            ("topology", Json::from(topo.label().as_str())),
            (
                "makespan_s",
                Json::Arr(times.iter().map(|t| Json::Num(*t)).collect()),
            ),
            (
                "cross_tier_requests",
                Json::Arr(cross_req.iter().map(|v| Json::Num(*v)).collect()),
            ),
            (
                "cross_tier_bytes",
                Json::Arr(cross_bytes.iter().map(|v| Json::Num(*v)).collect()),
            ),
            (
                "tier_requests",
                Json::Arr(tier_req.iter().map(|v| Json::from(*v)).collect()),
            ),
        ]));
    }
    ctx.write_json("fig9", &Json::obj(vec![("rows", Json::Arr(json_rows))]))?;
    Ok(out)
}
