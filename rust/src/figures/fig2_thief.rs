//! Fig. 2 — thief policies: counting only ready tasks vs ready +
//! successor tasks, 4 nodes, Single victim policy. Shape: the
//! successor-aware policy beats both ReadyOnly and No-Steal; ReadyOnly
//! over-steals and can be worse than not stealing at all.

use anyhow::Result;

use crate::migrate::{MigrateConfig, ThiefPolicy};
use crate::util::json::Json;

use super::common::{fmt_summary, Ctx};

pub fn run(ctx: &Ctx) -> Result<String> {
    let nodes = 4;
    let mk = |thief| MigrateConfig::default().with_thief(thief);
    let cells = [
        ("No-Steal", MigrateConfig::disabled()),
        ("Ready-only", mk(ThiefPolicy::ReadyOnly)),
        ("Ready+Successors", mk(ThiefPolicy::ReadySuccessors)),
    ];
    let mut out = String::new();
    out.push_str("Fig.2 — thief policies (4 nodes, Single victim policy)\n");
    let mut rows = Vec::new();
    for (label, mc) in cells {
        let times = ctx.exec_times_cholesky(nodes, mc);
        out.push_str(&fmt_summary(label, &times));
        out.push('\n');
        rows.push(Json::obj(vec![
            ("policy", Json::from(label)),
            ("times_s", Json::Arr(times.iter().map(|t| Json::Num(*t)).collect())),
        ]));
    }
    ctx.write_json("fig2", &Json::obj(vec![("rows", Json::Arr(rows))]))?;
    Ok(out)
}
