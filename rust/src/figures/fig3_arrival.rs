//! Fig. 3 — ready tasks in a thief node when a stolen task arrives,
//! under the ReadyOnly thief policy (2 nodes, coarser tiles). Shape: the
//! counts are substantially above zero — successors of tasks that were
//! executing have refilled the queue before the stolen task lands, which
//! is exactly why ReadyOnly over-steals.

use anyhow::Result;

use crate::migrate::{MigrateConfig, ThiefPolicy};
use crate::stats::Summary;
use crate::util::json::Json;

use super::common::Ctx;

pub fn run(ctx: &Ctx) -> Result<String> {
    // Paper: 100² tiles of 100² elements, two nodes, ready-only. The
    // link uses MPI-rendezvous-scale costs (the paper's Gadi runs move
    // ~240 KB of tile inputs per stolen task), so the steal round trip
    // is long enough for executing tasks to finish and enqueue their
    // successors — the effect Fig. 3 demonstrates.
    use crate::comm::LinkModel;
    use crate::sim::{SimConfig, Simulator};
    let tiles = ctx.scale.tiles() / 2;
    let graph = ctx.cholesky_custom(2, tiles, 100, 0);
    let mc = MigrateConfig::default().with_thief(ThiefPolicy::ReadyOnly);
    let cfg = ctx.ov.apply_sim(
        SimConfig::default()
            .with_workers_per_node(ctx.scale.workers())
            .with_link(LinkModel {
                latency_us: 50.0,
                bw_bytes_per_us: 1_000.0,
            })
            .with_seed(7),
    );
    let report = Simulator::new(graph, cfg, ctx.cost.clone(), ctx.ov.apply_migrate(mc), 100).run();
    let samples = report.arrival_ready_all();
    let mut out = String::new();
    out.push_str("Fig.3 — ready tasks at thief when stolen task arrives (ReadyOnly, 2 nodes)\n");
    if samples.is_empty() {
        out.push_str("no stolen tasks arrived (no starvation at this scale)\n");
        ctx.write_json("fig3", &Json::obj(vec![("samples", Json::Arr(vec![]))]))?;
        return Ok(out);
    }
    let xs: Vec<f64> = samples.iter().map(|s| *s as f64).collect();
    let s = Summary::of(&xs);
    out.push_str(&format!(
        "{} arrivals; ready-at-arrival mean {:.1}, median {:.0}, max {:.0}\n",
        samples.len(),
        s.mean,
        s.median,
        s.max
    ));
    let nonzero = samples.iter().filter(|&&v| v > 0).count();
    out.push_str(&format!(
        "{:.0}% of stolen tasks arrived at a non-empty queue\n",
        100.0 * nonzero as f64 / samples.len() as f64
    ));
    // histogram, 8 buckets
    let max = *samples.last().unwrap() as usize;
    let bucket = (max / 8).max(1);
    out.push_str("histogram:\n");
    for b in 0..=(max / bucket) {
        let lo = b * bucket;
        let hi = lo + bucket;
        let count = samples
            .iter()
            .filter(|&&v| (v as usize) >= lo && (v as usize) < hi)
            .count();
        out.push_str(&format!("  [{lo:>4},{hi:>4}) {}\n", "#".repeat(count.min(70))));
    }
    ctx.write_json(
        "fig3",
        &Json::obj(vec![(
            "samples",
            Json::Arr(samples.iter().map(|v| Json::from(*v as u64)).collect()),
        )]),
    )?;
    Ok(out)
}
