//! Fig. 6 — victim policies with and without the waiting-time gate
//! (4 nodes). Shape: the gate barely moves Chunk, significantly improves
//! Half and Single; without the gate Half is worse than Chunk, with it
//! Half edges ahead (by a small margin).

use anyhow::Result;

use crate::util::json::Json;

use super::common::{fmt_summary, victim_cells, Ctx};

pub fn run(ctx: &Ctx) -> Result<String> {
    let nodes = 4;
    let mut out = String::new();
    out.push_str("Fig.6 — waiting-time gate ablation (4 nodes)\n");
    let mut rows = Vec::new();
    for gate in [false, true] {
        out.push_str(&format!(
            "\nwaiting-time {}\n",
            if gate { "CONSIDERED" } else { "ignored" }
        ));
        for cell in victim_cells(ctx.scale, gate) {
            if cell.label == "No-Steal" {
                continue;
            }
            let times = ctx.exec_times_cholesky(nodes, cell.migrate);
            out.push_str(&format!("  {}\n", fmt_summary(&cell.label, &times)));
            rows.push(Json::obj(vec![
                ("policy", Json::from(cell.label.as_str())),
                ("waiting_time", Json::Bool(gate)),
                ("times_s", Json::Arr(times.iter().map(|t| Json::Num(*t)).collect())),
            ]));
        }
    }
    ctx.write_json("fig6", &Json::obj(vec![("rows", Json::Arr(rows))]))?;
    Ok(out)
}
