//! Normality tests used in §4's methodology: D'Agostino–Pearson K² and
//! Shapiro–Wilk (Royston's AS R94 approximation).

use super::descriptive::Summary;
use super::special::{chi2_sf, norm_cdf, norm_ppf};

/// Result of a normality test.
#[derive(Clone, Copy, Debug)]
pub struct NormalityTest {
    pub statistic: f64,
    pub p_value: f64,
}

impl NormalityTest {
    /// Fail to reject normality at `alpha`.
    pub fn consistent_with_normal(&self, alpha: f64) -> bool {
        self.p_value > alpha
    }
}

/// D'Agostino–Pearson omnibus K² test (skewness + kurtosis).
///
/// Needs n ≥ 8 for the kurtosis transform to be defined.
pub fn dagostino_pearson(xs: &[f64]) -> NormalityTest {
    let n = xs.len();
    assert!(n >= 8, "dagostino_pearson needs n >= 8, got {n}");
    let s = Summary::of(xs);
    let nf = n as f64;

    // -- skewness transform (D'Agostino 1970)
    let g1 = s.skewness;
    let y = g1 * ((nf + 1.0) * (nf + 3.0) / (6.0 * (nf - 2.0))).sqrt();
    let beta2 = 3.0 * (nf * nf + 27.0 * nf - 70.0) * (nf + 1.0) * (nf + 3.0)
        / ((nf - 2.0) * (nf + 5.0) * (nf + 7.0) * (nf + 9.0));
    let w2 = -1.0 + (2.0 * (beta2 - 1.0)).sqrt();
    let w = w2.sqrt();
    let delta = 1.0 / (w.ln()).sqrt();
    let alpha = (2.0 / (w2 - 1.0)).sqrt();
    let z1 = if y == 0.0 {
        0.0
    } else {
        delta * ((y / alpha) + ((y / alpha).powi(2) + 1.0).sqrt()).ln()
    };

    // -- kurtosis transform (Anscombe & Glynn 1983)
    let g2 = s.kurtosis; // excess
    let eb2 = -6.0 / (nf + 1.0); // E[g2]
    let vb2 = 24.0 * nf * (nf - 2.0) * (nf - 3.0) / ((nf + 1.0).powi(2) * (nf + 3.0) * (nf + 5.0));
    let x = (g2 - eb2) / vb2.sqrt();
    let sqrt_beta1 = 6.0 * (nf * nf - 5.0 * nf + 2.0) / ((nf + 7.0) * (nf + 9.0))
        * (6.0 * (nf + 3.0) * (nf + 5.0) / (nf * (nf - 2.0) * (nf - 3.0))).sqrt();
    let a = 6.0 + 8.0 / sqrt_beta1 * (2.0 / sqrt_beta1 + (1.0 + 4.0 / (sqrt_beta1 * sqrt_beta1)).sqrt());
    let t1 = 1.0 - 2.0 / (9.0 * a);
    let denom = 1.0 + x * (2.0 / (a - 4.0)).sqrt();
    let t2 = if denom <= 0.0 {
        // extreme tail; sign carries through
        f64::NAN
    } else {
        ((1.0 - 2.0 / a) / denom).cbrt()
    };
    let z2 = if t2.is_nan() {
        4.0 * x.signum()
    } else {
        (t1 - t2) / (2.0 / (9.0 * a)).sqrt()
    };

    let k2 = z1 * z1 + z2 * z2;
    NormalityTest {
        statistic: k2,
        p_value: chi2_sf(k2, 2.0),
    }
}

/// Shapiro–Wilk W test, Royston (1995) AS R94 approximation.
/// Valid for 3 ≤ n ≤ 5000.
pub fn shapiro_wilk(xs: &[f64]) -> NormalityTest {
    let n = xs.len();
    assert!((3..=5000).contains(&n), "shapiro_wilk needs 3 <= n <= 5000");
    let mut x = xs.to_vec();
    x.sort_by(|a, b| a.total_cmp(b));
    let nf = n as f64;

    // Weights m_i = Φ⁻¹((i − 3/8)/(n + 1/4))
    let mut m: Vec<f64> = (1..=n)
        .map(|i| norm_ppf((i as f64 - 0.375) / (nf + 0.25)))
        .collect();
    let m_sumsq: f64 = m.iter().map(|v| v * v).sum();
    let rsn = 1.0 / nf.sqrt();

    // Royston polynomial corrections for the last two weights
    // (coefficients listed highest degree first; Horner forward fold).
    let c = |coefs: &[f64], u: f64| -> f64 { coefs.iter().fold(0.0, |acc, &k| acc * u + k) };
    let u = rsn;
    let a_n = c(&[-2.706056, 4.434685, -2.071190, -0.147981, 0.221157, 0.0], u)
        + m[n - 1] / m_sumsq.sqrt();
    let mut a = vec![0.0; n];
    if n > 5 {
        let a_n1 = c(&[-3.582633, 5.682633, -1.752461, -0.293762, 0.042981, 0.0], u)
            + m[n - 2] / m_sumsq.sqrt();
        let phi = (m_sumsq - 2.0 * m[n - 1] * m[n - 1] - 2.0 * m[n - 2] * m[n - 2])
            / (1.0 - 2.0 * a_n * a_n - 2.0 * a_n1 * a_n1);
        a[n - 1] = a_n;
        a[n - 2] = a_n1;
        a[0] = -a_n;
        a[1] = -a_n1;
        for i in 2..n - 2 {
            a[i] = m[i] / phi.sqrt();
        }
    } else {
        let phi = (m_sumsq - 2.0 * m[n - 1] * m[n - 1]) / (1.0 - 2.0 * a_n * a_n);
        a[n - 1] = a_n;
        a[0] = -a_n;
        for i in 1..n - 1 {
            a[i] = m[i] / phi.sqrt();
        }
    }
    let _ = &mut m;

    let mean = x.iter().sum::<f64>() / nf;
    let ssq: f64 = x.iter().map(|v| (v - mean) * (v - mean)).sum();
    let wnum: f64 = a.iter().zip(&x).map(|(ai, xi)| ai * xi).sum::<f64>().powi(2);
    let w = if ssq > 0.0 { wnum / ssq } else { 1.0 };

    // p-value: Royston's normalizing transform of (1 - W).
    let lw = (1.0 - w).max(1e-15).ln();
    let (mu, sigma) = if n <= 11 {
        let g = -2.273 + 0.459 * nf;
        let mu = 0.5440 - 0.39978 * nf + 0.025054 * nf * nf - 0.0006714 * nf * nf * nf;
        let sigma =
            (1.3822 - 0.77857 * nf + 0.062767 * nf * nf - 0.0020322 * nf * nf * nf).exp();
        // transform statistic: z = (-ln(g - lw) - mu)/sigma
        let z = (-(g - lw).ln() - mu) / sigma;
        return NormalityTest {
            statistic: w,
            p_value: (1.0 - norm_cdf(z)).clamp(0.0, 1.0),
        };
    } else {
        let ln_n = nf.ln();
        let mu = -1.5861 - 0.31082 * ln_n - 0.083751 * ln_n * ln_n + 0.0038915 * ln_n.powi(3);
        let sigma = (-0.4803 - 0.082676 * ln_n + 0.0030302 * ln_n * ln_n).exp();
        (mu, sigma)
    };
    let z = (lw - mu) / sigma;
    NormalityTest {
        statistic: w,
        p_value: (1.0 - norm_cdf(z)).clamp(0.0, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn normal_sample(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| 10.0 + 2.0 * r.normal()).collect()
    }

    fn exponential_sample(n: usize, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| -r.uniform().max(1e-12).ln()).collect()
    }

    #[test]
    fn dagostino_accepts_normal() {
        let mut accepted = 0;
        for seed in 0..10 {
            let t = dagostino_pearson(&normal_sample(200, seed));
            if t.consistent_with_normal(0.01) {
                accepted += 1;
            }
        }
        assert!(accepted >= 8, "accepted {accepted}/10 normal samples");
    }

    #[test]
    fn dagostino_rejects_exponential() {
        let mut rejected = 0;
        for seed in 0..10 {
            let t = dagostino_pearson(&exponential_sample(200, seed));
            if !t.consistent_with_normal(0.05) {
                rejected += 1;
            }
        }
        assert!(rejected >= 9, "rejected {rejected}/10 exponential samples");
    }

    #[test]
    fn shapiro_accepts_normal() {
        let mut accepted = 0;
        for seed in 0..10 {
            let t = shapiro_wilk(&normal_sample(50, 100 + seed));
            assert!(t.statistic > 0.8 && t.statistic <= 1.0);
            if t.consistent_with_normal(0.01) {
                accepted += 1;
            }
        }
        assert!(accepted >= 8, "accepted {accepted}/10");
    }

    #[test]
    fn shapiro_rejects_exponential() {
        let mut rejected = 0;
        for seed in 0..10 {
            let t = shapiro_wilk(&exponential_sample(50, 200 + seed));
            if !t.consistent_with_normal(0.05) {
                rejected += 1;
            }
        }
        assert!(rejected >= 9, "rejected {rejected}/10");
    }

    #[test]
    fn shapiro_w_near_one_for_linear_data() {
        // perfectly uniform spacing is very "straight" on the normal QQ
        // plot's center; W should be high
        let xs: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let t = shapiro_wilk(&xs);
        assert!(t.statistic > 0.9);
    }

    #[test]
    fn small_n_paths() {
        // exercise the n <= 11 branch
        let t = shapiro_wilk(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert!(t.p_value > 0.0 && t.p_value <= 1.0);
    }
}
