//! Statistics used by the paper's methodology (§4): descriptive
//! summaries, normality tests (D'Agostino–Pearson and Shapiro–Wilk) and
//! one-way ANOVA, plus the special functions their p-values need.

pub mod anova;
pub mod descriptive;
pub mod normality;
pub mod special;

pub use anova::{anova_one_way, AnovaResult};
pub use descriptive::Summary;
pub use normality::{dagostino_pearson, shapiro_wilk, NormalityTest};
