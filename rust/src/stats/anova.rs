//! One-way ANOVA — the paper's §4 check that steal vs no-steal execution
//! times come from different distributions.

use super::special::f_sf;

/// One-way ANOVA outcome.
#[derive(Clone, Copy, Debug)]
pub struct AnovaResult {
    pub f_statistic: f64,
    pub p_value: f64,
    pub df_between: f64,
    pub df_within: f64,
}

impl AnovaResult {
    pub fn significant(&self, alpha: f64) -> bool {
        self.p_value < alpha
    }
}

/// `groups`: two or more samples (e.g. execution times with and without
/// work stealing).
pub fn anova_one_way(groups: &[&[f64]]) -> AnovaResult {
    let k = groups.len();
    assert!(k >= 2, "anova needs >= 2 groups");
    let n_total: usize = groups.iter().map(|g| g.len()).sum();
    assert!(
        groups.iter().all(|g| !g.is_empty()) && n_total > k,
        "anova needs non-empty groups and residual dof"
    );

    let grand_mean =
        groups.iter().flat_map(|g| g.iter()).sum::<f64>() / n_total as f64;

    let mut ss_between = 0.0;
    let mut ss_within = 0.0;
    for g in groups {
        let mean = g.iter().sum::<f64>() / g.len() as f64;
        ss_between += g.len() as f64 * (mean - grand_mean).powi(2);
        ss_within += g.iter().map(|x| (x - mean).powi(2)).sum::<f64>();
    }
    let df_between = (k - 1) as f64;
    let df_within = (n_total - k) as f64;
    let ms_between = ss_between / df_between;
    let ms_within = ss_within / df_within;
    let f = if ms_within > 0.0 {
        ms_between / ms_within
    } else if ms_between > 0.0 {
        f64::INFINITY
    } else {
        0.0
    };
    let p = if f.is_finite() {
        f_sf(f, df_between, df_within)
    } else {
        0.0
    };
    AnovaResult {
        f_statistic: f,
        p_value: p,
        df_between,
        df_within,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(n: usize, mean: f64, sd: f64, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| mean + sd * r.normal()).collect()
    }

    #[test]
    fn distinct_means_are_significant() {
        let a = sample(30, 100.0, 3.0, 1);
        let b = sample(30, 80.0, 3.0, 2);
        let r = anova_one_way(&[&a, &b]);
        assert!(r.significant(0.001), "p = {}", r.p_value);
        assert!(r.f_statistic > 50.0);
    }

    #[test]
    fn same_distribution_not_significant() {
        let mut hits = 0;
        for seed in 0..20 {
            let a = sample(25, 50.0, 5.0, 100 + seed);
            let b = sample(25, 50.0, 5.0, 200 + seed);
            if anova_one_way(&[&a, &b]).significant(0.05) {
                hits += 1;
            }
        }
        // alpha = 0.05: expect about 1 false positive in 20
        assert!(hits <= 4, "false positives: {hits}/20");
    }

    #[test]
    fn three_groups() {
        let a = sample(20, 10.0, 1.0, 5);
        let b = sample(20, 10.1, 1.0, 6);
        let c = sample(20, 18.0, 1.0, 7);
        let r = anova_one_way(&[&a, &b, &c]);
        assert_eq!(r.df_between, 2.0);
        assert_eq!(r.df_within, 57.0);
        assert!(r.significant(0.001));
    }

    #[test]
    fn identical_groups_f_zero() {
        let a = [5.0, 5.0, 5.0];
        let b = [5.0, 5.0, 5.0];
        let r = anova_one_way(&[&a, &b]);
        assert_eq!(r.f_statistic, 0.0);
        assert_eq!(r.p_value, 1.0);
    }
}
