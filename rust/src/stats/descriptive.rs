//! Descriptive statistics.

/// Summary of a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample variance (n−1 denominator).
    pub var: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Sample skewness (g1).
    pub skewness: f64,
    /// Excess kurtosis (g2).
    pub kurtosis: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let nf = n as f64;
        let mean = xs.iter().sum::<f64>() / nf;
        let mut m2 = 0.0;
        let mut m3 = 0.0;
        let mut m4 = 0.0;
        for &x in xs {
            let d = x - mean;
            m2 += d * d;
            m3 += d * d * d;
            m4 += d * d * d * d;
        }
        m2 /= nf;
        m3 /= nf;
        m4 /= nf;
        let var = if n > 1 {
            m2 * nf / (nf - 1.0)
        } else {
            0.0
        };
        let skewness = if m2 > 0.0 { m3 / m2.powf(1.5) } else { 0.0 };
        let kurtosis = if m2 > 0.0 { m4 / (m2 * m2) - 3.0 } else { 0.0 };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            var,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            skewness,
            kurtosis,
        }
    }

    /// Coefficient of variation (σ/µ) — the paper's variability claim.
    pub fn cv(&self) -> f64 {
        if self.mean != 0.0 {
            self.std / self.mean
        } else {
            0.0
        }
    }
}

/// Empirical quantile (linear interpolation).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.var, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!(s.skewness.abs() < 1e-12, "symmetric sample");
    }

    #[test]
    fn even_median_interpolates() {
        assert_eq!(Summary::of(&[1.0, 2.0, 3.0, 4.0]).median, 2.5);
    }

    #[test]
    fn skewness_sign() {
        let right = Summary::of(&[1.0, 1.0, 1.0, 1.0, 10.0]);
        assert!(right.skewness > 1.0);
        let left = Summary::of(&[-10.0, 1.0, 1.0, 1.0, 1.0]);
        assert!(left.skewness < -1.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn cv_scale_free() {
        let a = Summary::of(&[9.0, 10.0, 11.0]);
        let b = Summary::of(&[90.0, 100.0, 110.0]);
        assert!((a.cv() - b.cv()).abs() < 1e-12);
    }
}
