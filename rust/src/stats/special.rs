//! Special functions backing the p-values: log-gamma (Lanczos),
//! regularized incomplete gamma (series + continued fraction) and
//! regularized incomplete beta (Lentz continued fraction), plus the
//! standard-normal CDF.

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + 7.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series expansion
        let mut term = 1.0 / a;
        let mut sum = term;
        let mut ap = a;
        for _ in 0..500 {
            ap += 1.0;
            term *= x / ap;
            sum += term;
            if term.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        (sum.ln() + a * x.ln() - x - ln_gamma(a)).exp()
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Regularized upper incomplete gamma Q(a, x) via continued fraction.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let mut b = x + 1.0 - a;
    let mut c = 1e308;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        c = b + an / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (a * x.ln() - x - ln_gamma(a)).exp() * h
}

/// Chi-squared upper-tail p-value with k degrees of freedom.
pub fn chi2_sf(x: f64, k: f64) -> f64 {
    (1.0 - gamma_p(k / 2.0, x / 2.0)).clamp(0.0, 1.0)
}

/// Regularized incomplete beta I_x(a, b) (Lentz's continued fraction).
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x));
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // symmetry for faster convergence
    if x > (a + 1.0) / (a + b + 2.0) {
        return 1.0 - beta_inc(b, a, 1.0 - x);
    }
    let mut c = 1.0;
    let mut d = 1.0 - (a + b) * x / (a + 1.0);
    if d.abs() < 1e-300 {
        d = 1e-300;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        // even step
        let num = m * (b - m) * x / ((a + 2.0 * m - 1.0) * (a + 2.0 * m));
        d = 1.0 + num * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        c = 1.0 + num / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        h *= d * c;
        // odd step
        let num = -(a + m) * (a + b + m) * x / ((a + 2.0 * m) * (a + 2.0 * m + 1.0));
        d = 1.0 + num * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        c = 1.0 + num / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    (front * h / a).clamp(0.0, 1.0)
}

/// Upper-tail p-value of the F distribution.
pub fn f_sf(f: f64, d1: f64, d2: f64) -> f64 {
    if f <= 0.0 {
        return 1.0;
    }
    beta_inc(d2 / 2.0, d1 / 2.0, d2 / (d2 + d1 * f)).clamp(0.0, 1.0)
}

/// Standard normal CDF.
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / std::f64::consts::SQRT_2)
}

/// Complementary error function (Numerical-Recipes rational Chebyshev).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Inverse standard normal CDF (Acklam's algorithm, |relerr| < 1.15e-9).
pub fn norm_ppf(p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn gamma_p_is_cdf_like() {
        assert!(gamma_p(2.0, 0.0) == 0.0);
        assert!(gamma_p(2.0, 100.0) > 0.999999);
        // P(1, x) = 1 - e^-x
        assert!((gamma_p(1.0, 1.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
    }

    #[test]
    fn chi2_known() {
        // chi2 sf at x=k has p around 0.3-0.5 for small k
        let p = chi2_sf(2.0, 2.0);
        assert!((p - (-1.0f64).exp()).abs() < 1e-10, "sf(2;2)=e^-1, got {p}");
    }

    #[test]
    fn beta_inc_symmetry_and_known() {
        // I_x(1,1) = x
        for x in [0.1, 0.37, 0.9] {
            assert!((beta_inc(1.0, 1.0, x) - x).abs() < 1e-12);
        }
        // symmetry: I_x(a,b) = 1 - I_{1-x}(b,a)
        let v = beta_inc(2.5, 3.5, 0.4) + beta_inc(3.5, 2.5, 0.6);
        assert!((v - 1.0).abs() < 1e-10);
    }

    #[test]
    fn f_sf_sanity() {
        // F(1, d1, d2) is not tiny; F of huge value -> 0
        assert!(f_sf(1.0, 3.0, 10.0) > 0.3);
        assert!(f_sf(100.0, 3.0, 10.0) < 1e-5);
        assert_eq!(f_sf(0.0, 3.0, 10.0), 1.0);
    }

    #[test]
    fn norm_cdf_ppf_roundtrip() {
        for p in [0.001, 0.025, 0.31, 0.5, 0.77, 0.975, 0.999] {
            let z = norm_ppf(p);
            // erfc rational approximation is good to ~1.2e-7 absolute
            assert!((norm_cdf(z) - p).abs() < 3e-7, "p={p}");
        }
        assert!((norm_cdf(1.959964) - 0.975).abs() < 1e-6);
    }
}
