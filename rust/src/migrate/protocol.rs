//! Victim-side steal decision and shared steal accounting.
//!
//! The decision is O(1) + O(tasks extracted): the stealable census comes
//! from the scheduler's incrementally-maintained accounting
//! ([`Scheduler::stealable_count`]) and extraction walks the stealable
//! index ([`Scheduler::extract_stealable`]) — no queue scan per request
//! (asserted by `steal_poll_performs_no_queue_scan` below). The contract
//! is that every task was enqueued with [`TaskMeta::of`] so the stored
//! stealable bit agrees with `graph.is_stealable`.
//!
//! Every decision also *reports its verdict back* to the scheduler
//! ([`Scheduler::feedback`] with a [`StealOutcome`]): a waiting-time
//! denial tells the sharded backend to raise its spill watermark (the
//! gate just measured that tasks run locally sooner than they migrate),
//! a grant tells it to keep the steal pool stocked. The denial path
//! returns the extracted batch through one
//! [`Scheduler::insert_batch_at`] call booked to the gate-denial site —
//! one lock acquisition, meta preserved — instead of per-task
//! reinserts. Denials that are *certain* from the O(1) accounting alone
//! (the overhead + latency + minimum-stealable-payload floor already
//! loses to the waiting time) skip extraction entirely.

use crate::dataflow::task::TaskDesc;
use crate::dataflow::ttg::TaskGraph;
use crate::sched::{BatchSite, Scheduler, StealOutcome, TaskMeta};

use super::policy::{
    migrate_time_us, steal_allowance, waiting_time_per_class_us, waiting_time_us, ExecSnapshot,
    MigrateConfig,
};
use super::victim::PRICED_REPLY_BYTES;

/// How many times a thief re-issues a timed-out steal request before
/// abandoning the slot (`--faults` hardening). With per-class drop
/// probability capped at [`crate::faults::MAX_FAULT_P`] = 0.95, the
/// chance that a request *and* all four retries lose a message is below
/// `0.995^5` of the worst case — in practice a handful of retries
/// clears any plan the fabric accepts, and the inflight slot is
/// released (never leaked) either way.
pub const THIEF_RETRY_BUDGET: u32 = 4;

/// Floor on the steal timeout (µs): on an ideal link the modeled
/// round trip is ~0, but the victim's migrate thread still polls its
/// mailbox at `poll_interval_us` granularity and the threaded
/// runtime's comm loop adds scheduling jitter — a sub-millisecond
/// timeout would fire on healthy traffic and every "retry" would be a
/// spurious duplicate.
pub const STEAL_TIMEOUT_FLOOR_US: f64 = 5_000.0;

/// Exponential-backoff cap: the timeout doubles per attempt but never
/// exceeds `2^4 = 16×` the base, so a long fault window delays
/// recovery by a bounded factor instead of unboundedly.
pub const STEAL_BACKOFF_CAP_EXP: u32 = 4;

/// How many times the victim's ack watchdog retransmits an unacked
/// `StealReply` before *probing* the thief instead of retransmitting
/// again. PR 7 retransmitted unbounded, which was the documented
/// liveness caveat: a thief stalled forever (or crash-stopped) kept the
/// victim's ledger entry — and the run — alive indefinitely. After this
/// budget the victim settles the entry from the thief's transfer book:
/// an absorbed grant retires it, anything else reclaims the tasks.
pub const ACK_PROBE_BUDGET: u32 = 4;

/// The failure detector's suspicion threshold (µs): how long a node may
/// stay silent before the leader declares it dead. Derived from the
/// same wire model as [`steal_timeout_us`] — several fully backed-off
/// steal round trips — so on a healthy fabric a silent-but-live node is
/// impossible by construction: idle nodes ping at a quarter of this
/// period, and the modeled worst-case round trip (including the fault
/// plan's bounded delay factor budget) is a small fraction of it.
/// Shared by the threaded runtime (wall clock) and the DES (which uses
/// it directly as the deterministic detection latency), so both declare
/// at the same model time and never falsely in a fault-free run.
pub fn suspicion_timeout_us(
    latency_us: f64,
    bw_bytes_per_us: f64,
    migrate_overhead_us: f64,
    poll_interval_us: f64,
) -> f64 {
    4.0 * steal_timeout_us(
        latency_us,
        bw_bytes_per_us,
        migrate_overhead_us,
        poll_interval_us,
        0,
    )
}

/// Compose a steal request id: the thief's node id in the high bits,
/// its monotone per-thief counter in the low 40 — globally unique
/// without coordination, and wire-free (the id rides the existing
/// 16-byte request/reply headers). `+1` keeps every id nonzero, so 0
/// can never collide with a live request. Shared by the threaded
/// runtime and the DES so transcripts line up.
pub fn steal_req_id(thief: u32, counter: u64) -> u64 {
    ((u64::from(thief) + 1) << 40) | (counter & ((1 << 40) - 1))
}

/// The thief's steal timeout for retry `attempt` (0 = first try), in
/// µs. Shared by the threaded runtime and the DES so both time out —
/// and therefore retry, and therefore agree — identically.
///
/// The base is derived from the same Khatiri-style round-trip model
/// the victim selector prices steals with: request out + reply back
/// (`2·latency`) plus the minimal priced reply
/// ([`PRICED_REPLY_BYTES`]) at link bandwidth. Four round trips of
/// headroom absorb fault-plan delay multipliers, plus the victim's
/// processing overhead and two mailbox poll intervals, floored at
/// [`STEAL_TIMEOUT_FLOOR_US`]; then capped exponential backoff per
/// attempt.
pub fn steal_timeout_us(
    latency_us: f64,
    bw_bytes_per_us: f64,
    migrate_overhead_us: f64,
    poll_interval_us: f64,
    attempt: u32,
) -> f64 {
    let round_trip = 2.0 * latency_us + PRICED_REPLY_BYTES / bw_bytes_per_us.max(f64::MIN_POSITIVE);
    let base = (4.0 * round_trip + migrate_overhead_us + 2.0 * poll_interval_us)
        .max(STEAL_TIMEOUT_FLOOR_US);
    base * f64::from(1u32 << attempt.min(STEAL_BACKOFF_CAP_EXP))
}

/// Outcome of processing one steal request at the victim.
#[derive(Debug, Default)]
pub struct VictimDecision {
    /// Tasks extracted for migration (may be empty — steal failed).
    pub tasks: Vec<TaskDesc>,
    /// Total input payload that must travel with them.
    pub payload_bytes: u64,
    /// Denied by the waiting-time gate (vs merely nothing stealable).
    pub denied_by_waiting_time: bool,
}

/// Apply the victim policy + waiting-time gate to the node's queue.
///
/// `est` carries the victim's execution-time estimates — the node-wide
/// running mean ("execution time elapsed / tasks executed till now")
/// or, under [`MigrateConfig::exec_ewma`], the EWMA of recent
/// executions ([`crate::migrate::ewma_update`]); with
/// [`MigrateConfig::exec_per_class`] also the per-class table, so the
/// expected wait weighs the queue's actual class composition
/// ([`waiting_time_per_class_us`] over [`Scheduler::class_counts`]).
/// `workers` is the victim's worker-thread count, and the link
/// parameters describe the path to the thief. Works against any
/// [`Scheduler`] backend: with the central queue the extraction
/// *competes* with worker `select`s on one lock (the §4.4 contention);
/// the sharded backend serves it from the steal pool. Either way the
/// allowance is best-effort exactly as §3 describes. The stealable
/// census is the scheduler's O(1) accounting — no per-request queue
/// scan — and the verdict is fed back via [`Scheduler::feedback`].
pub fn decide_steal(
    cfg: &MigrateConfig,
    graph: &dyn TaskGraph,
    queue: &dyn Scheduler,
    workers: usize,
    est: &ExecSnapshot,
    link_latency_us: f64,
    link_bw_bytes_per_us: f64,
) -> VictimDecision {
    let stealable = queue.stealable_count();
    let allowed = steal_allowance(cfg.victim, stealable);
    if allowed == 0 {
        queue.feedback(StealOutcome::DeniedEmpty);
        return VictimDecision::default();
    }

    if cfg.use_waiting_time {
        // Gate: allow the steal only if the task would wait longer for a
        // local worker than the migration takes. The waiting time uses
        // the *total* ready count (all queued tasks delay each other) —
        // weighted per class when the per-class estimator is on.
        let waiting = match (cfg.exec_per_class, est.per_class) {
            (true, Some(table)) => {
                waiting_time_per_class_us(&queue.class_counts(), &table, workers, est.avg_us)
            }
            _ => waiting_time_us(queue.len(), workers, est.avg_us),
        };
        // Denial-certain fast path: overhead + latency + the minimum
        // stealable payload's transfer is a lower bound on the
        // migration time of *any* non-empty batch (every extractable
        // task carries at least the queue's minimum stealable payload).
        // When even that bound loses to the waiting time, the verdict
        // cannot depend on which tasks would be extracted — skip the
        // extraction entirely and the poll is O(1). This covers both
        // the overhead-bound regime (PR 3) and the payload-bound one:
        // sustained payload-driven denial no longer extracts at all, so
        // the sharded backend's all-shards fallback walk never runs.
        // The minimum is the queue's *exact* payload-multiset minimum
        // (not the old monotone-per-epoch bound), so for single-task
        // allowances the fast path denies precisely what the full
        // extract-and-weigh would have denied.
        let min_payload = queue.min_stealable_payload_bytes();
        let payload_floor_us = if min_payload == u64::MAX {
            0.0 // stealable set emptied under us; overhead-only bound
        } else {
            min_payload as f64 / link_bw_bytes_per_us
        };
        if cfg.migrate_overhead_us + link_latency_us + payload_floor_us >= waiting {
            queue.feedback(StealOutcome::DeniedWaitingTime);
            return VictimDecision {
                tasks: Vec::new(),
                payload_bytes: 0,
                denied_by_waiting_time: true,
            };
        }
        // Extract first, then re-insert if the gate fails: the gate needs
        // the concrete payload size of the tasks that would migrate.
        let tasks = queue.extract_stealable(allowed);
        if tasks.is_empty() {
            queue.feedback(StealOutcome::DeniedEmpty);
            return VictimDecision::default();
        }
        let payload: u64 = tasks.iter().map(|t| graph.payload_bytes(*t)).sum();
        // The gate compares the waiting time against the time to migrate
        // the whole batch: a Half-policy steal of dozens of tasks moves
        // dozens of input tile sets, and every one of them is delayed by
        // the full transfer (§3 "time required to migrate the task").
        let migrate = cfg.migrate_overhead_us
            + migrate_time_us(link_latency_us, payload, link_bw_bytes_per_us);
        if migrate < waiting {
            queue.feedback(StealOutcome::Granted);
            return VictimDecision {
                tasks,
                payload_bytes: payload,
                denied_by_waiting_time: false,
            };
        }
        // Denied: return the batch under one lock acquisition (with its
        // accounting meta, booked to the gate-denial site — the sharded
        // backend sends it back to the steal pool), then close the loop
        // — the denial is the signal that tasks should stay local.
        queue.insert_batch_at(BatchSite::GateDenial, &TaskMeta::batch_of(graph, &tasks));
        queue.feedback(StealOutcome::DeniedWaitingTime);
        VictimDecision {
            tasks: Vec::new(),
            payload_bytes: 0,
            denied_by_waiting_time: true,
        }
    } else {
        let tasks = queue.extract_stealable(allowed);
        if tasks.is_empty() {
            queue.feedback(StealOutcome::DeniedEmpty);
            return VictimDecision::default();
        }
        let payload = tasks.iter().map(|t| graph.payload_bytes(*t)).sum();
        queue.feedback(StealOutcome::Granted);
        VictimDecision {
            tasks,
            payload_bytes: payload,
            denied_by_waiting_time: false,
        }
    }
}

/// Per-node steal accounting (drives Fig. 8 and the §4 analyses).
#[derive(Clone, Copy, Debug, Default)]
pub struct StealStats {
    /// Thief side: requests sent.
    pub requests_sent: u64,
    /// Thief side: replies that contained at least one task.
    pub successful_steals: u64,
    /// Thief side: tasks received.
    pub tasks_received: u64,
    /// Victim side: requests processed.
    pub requests_served: u64,
    /// Victim side: tasks given away.
    pub tasks_migrated: u64,
    /// Victim side: denials due to the waiting-time gate.
    pub waiting_time_denials: u64,
    /// Victim side: denials because nothing was stealable.
    pub empty_denials: u64,
    /// Payload bytes migrated (victim side).
    pub payload_bytes: u64,
}

impl StealStats {
    pub fn success_pct(&self) -> f64 {
        if self.requests_sent == 0 {
            return 0.0;
        }
        100.0 * self.successful_steals as f64 / self.requests_sent as f64
    }

    pub fn merge(&mut self, o: &StealStats) {
        self.requests_sent += o.requests_sent;
        self.successful_steals += o.successful_steals;
        self.tasks_received += o.tasks_received;
        self.requests_served += o.requests_served;
        self.tasks_migrated += o.tasks_migrated;
        self.waiting_time_denials += o.waiting_time_denials;
        self.empty_denials += o.empty_denials;
        self.payload_bytes += o.payload_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
    use crate::dataflow::ttg::TtgBuilder;
    use crate::migrate::policy::VictimPolicy;
    use crate::sched::{SchedBackend, SchedQueue};

    fn graph(payload: u64) -> impl TaskGraph {
        TtgBuilder::new("g", 2)
            .wrap_g(
                "c",
                |t| t.i % 2 == 0, // even tasks stealable
                |_| vec![],
                |_| 1,
                |_| NodeId(0),
                |_| 1.0,
            )
            .with_payload(move |_| payload)
            .build()
    }

    /// Even tasks stealable (as [`graph`]), but task `i == 2` carries a
    /// tiny payload while the rest carry `heavy`: the min-payload bound
    /// stays at 64 bytes, so the gate cannot prove a denial from the
    /// accounting alone and must extract-and-weigh the concrete batch.
    fn mixed_graph(heavy: u64) -> impl TaskGraph {
        TtgBuilder::new("g", 2)
            .wrap_g(
                "c",
                |t| t.i % 2 == 0,
                |_| vec![],
                |_| 1,
                |_| NodeId(0),
                |_| 1.0,
            )
            .with_payload(move |t| if t.i == 2 { 64 } else { heavy })
            .build()
    }

    /// Enqueue n tasks carrying the graph's steal meta — the contract
    /// every runtime call site follows.
    fn queue_with(graph: &dyn TaskGraph, n: u32) -> SchedQueue {
        let q = SchedQueue::new();
        for i in 0..n {
            let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
            q.insert_meta(t, i as i64, TaskMeta::of(graph, t));
        }
        q
    }

    fn cfg(victim: VictimPolicy, gate: bool) -> MigrateConfig {
        MigrateConfig::default()
            .with_victim(victim)
            .with_use_waiting_time(gate)
    }

    #[test]
    fn half_policy_without_gate_takes_half_of_stealable() {
        let g = graph(0);
        let q = queue_with(&g, 8); // 4 stealable (even i)
        let est = ExecSnapshot::uniform(10.0);
        let d = decide_steal(&cfg(VictimPolicy::Half, false), &g, &q, 4, &est, 1.0, 1e9);
        assert_eq!(d.tasks.len(), 2);
        assert!(d.tasks.iter().all(|t| t.i % 2 == 0));
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn gate_denies_when_migration_slower_than_wait() {
        let g = mixed_graph(1_000_000_000); // 1 GB payloads, one 64 B outlier
        let q = queue_with(&g, 4);
        // wait = (4/4+1)*100 = 200µs beats the ≈155.06µs floor
        // (overhead + latency + 64 B min payload), so the batch is
        // actually extracted and weighed: the lowest-priority stealable
        // is the 1 GB task -> migrate = 155 + 1e9/1e3 = huge -> deny,
        // reinsert.
        let est = ExecSnapshot::uniform(100.0);
        let d = decide_steal(&cfg(VictimPolicy::Single, true), &g, &q, 4, &est, 5.0, 1e3);
        assert!(d.tasks.is_empty());
        assert!(d.denied_by_waiting_time);
        assert_eq!(q.len(), 4, "denied tasks returned to the queue");
        assert!(q.stats().steal_extracted > 0, "the batch was weighed");
    }

    #[test]
    fn gate_denies_without_extraction_when_overhead_alone_loses() {
        // Denial-certain fast path: waiting = (4/4+1)*10 = 20µs is below
        // the 155µs overhead+latency floor, so the verdict cannot depend
        // on the payload — no extraction, no reinsert, still a denial.
        let g = graph(100);
        let q = queue_with(&g, 4);
        let est = ExecSnapshot::uniform(10.0);
        let d = decide_steal(&cfg(VictimPolicy::Single, true), &g, &q, 4, &est, 5.0, 1e3);
        assert!(d.tasks.is_empty());
        assert!(d.denied_by_waiting_time);
        assert_eq!(q.len(), 4);
        let s = q.stats();
        assert_eq!(s.steal_extracted, 0, "fast path never touched the queue");
        assert_eq!(s.batch_inserts(), 0, "nothing to reinsert");
        assert_eq!(s.feedback_wt_denials, 1, "the denial still feeds back");
    }

    /// The payload-certain fast path: overhead + latency alone (155µs)
    /// loses to the 200µs waiting time, but every stealable payload is
    /// ≥ 1 GB, so the min-payload floor proves the denial without
    /// extracting — on both backends, with zero sharded fallback walks.
    #[test]
    fn gate_denies_without_extraction_when_payload_floor_loses() {
        let g = graph(1_000_000_000);
        for backend in SchedBackend::ALL {
            let q = backend.build(4);
            for i in 0..4 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            let est = ExecSnapshot::uniform(100.0);
            let mc = cfg(VictimPolicy::Single, true);
            let d = decide_steal(&mc, &g, q.as_ref(), 4, &est, 5.0, 1e3);
            assert!(d.denied_by_waiting_time, "{backend:?}");
            assert_eq!(q.len(), 4, "{backend:?}");
            let s = q.stats();
            assert_eq!(s.steal_extracted, 0, "{backend:?}: no extraction");
            assert_eq!(s.batch_inserts(), 0, "{backend:?}: no reinsert");
            assert_eq!(s.feedback_wt_denials, 1, "{backend:?}");
            assert_eq!(
                s.extract_fallback_walks, 0,
                "{backend:?}: payload-certain denial never walks the shards"
            );
        }
    }

    /// With `--exec-per-class` the same queue can flip the verdict: a
    /// queue of heavy GEMMs has a long expected wait even when the
    /// node-wide average is tiny (it was trained on cheap POTRFs), so
    /// the composition-aware gate grants what the node-wide gate would
    /// deny.
    #[test]
    fn per_class_gate_weighs_queue_composition() {
        let g = graph(100);
        let mut mc = cfg(VictimPolicy::Single, true);
        let mut table = [0.0f64; TaskClass::COUNT];
        table[TaskClass::Gemm.idx()] = 1000.0; // queued class: heavy
        let est = ExecSnapshot {
            avg_us: 10.0, // node-wide history: cheap
            per_class: Some(table),
        };
        let fill = |q: &dyn Scheduler| {
            for i in 0..8 {
                let t = TaskDesc::indexed(TaskClass::Gemm, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
        };
        // Node-wide: waiting = (8/4+1)*10 = 30µs < 155µs floor -> deny.
        let q = SchedQueue::new();
        fill(&q);
        let d = decide_steal(&mc, &g, &q, 4, &est, 5.0, 1e3);
        assert!(d.denied_by_waiting_time, "node-wide gate denies");
        // Per-class: waiting = 8·1000/4 + 10 = 2010µs -> grant.
        mc.exec_per_class = true;
        let q = SchedQueue::new();
        fill(&q);
        let d = decide_steal(&mc, &g, &q, 4, &est, 5.0, 1e3);
        assert_eq!(d.tasks.len(), 1, "composition-aware gate grants");
        assert!(!d.denied_by_waiting_time);
        assert_eq!(q.stats().scans, 0, "class counts are O(1), not a scan");
    }

    #[test]
    fn gate_allows_cheap_migration() {
        let g = graph(100);
        let q = queue_with(&g, 40);
        // wait = (40/4+1)*100 = 1100µs; migrate = 5 + 100/1e3 ≈ 5.1µs
        let est = ExecSnapshot::uniform(100.0);
        let d = decide_steal(&cfg(VictimPolicy::Single, true), &g, &q, 4, &est, 5.0, 1e3);
        assert_eq!(d.tasks.len(), 1);
        assert!(!d.denied_by_waiting_time);
    }

    #[test]
    fn nothing_stealable_is_empty_not_denied() {
        let g = TtgBuilder::new("g", 2)
            .wrap_g("c", |_| false, |_| vec![], |_| 1, |_| NodeId(0), |_| 1.0)
            .build();
        let q = queue_with(&g, 4);
        let est = ExecSnapshot::uniform(10.0);
        let d = decide_steal(&cfg(VictimPolicy::Half, true), &g, &q, 4, &est, 1.0, 1e3);
        assert!(d.tasks.is_empty());
        assert!(!d.denied_by_waiting_time);
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn half_needs_at_least_two_stealable() {
        let g = graph(0);
        let q = SchedQueue::new();
        let t = TaskDesc::indexed(TaskClass::Synthetic, 0, 0, 0);
        q.insert_meta(t, 0, TaskMeta::of(&g, t));
        let est = ExecSnapshot::uniform(10.0);
        let d = decide_steal(&cfg(VictimPolicy::Half, false), &g, &q, 4, &est, 1.0, 1e3);
        assert!(d.tasks.is_empty(), "half of 1 stealable = 0");
    }

    #[test]
    fn decide_steal_agrees_across_backends() {
        let g = graph(100);
        for backend in SchedBackend::ALL {
            let q = backend.build(4);
            for i in 0..40 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            // wait = (40/4+1)*100 = 1100µs; migrate ≈ 155µs -> allowed
            let d = decide_steal(
                &cfg(VictimPolicy::Chunk(6), true),
                &g,
                q.as_ref(),
                4,
                &ExecSnapshot::uniform(100.0),
                5.0,
                1e3,
            );
            assert_eq!(d.tasks.len(), 6, "{backend:?}");
            assert!(d.tasks.iter().all(|t| t.i % 2 == 0), "{backend:?}");
            assert_eq!(q.len(), 34, "{backend:?}: conservation");
        }
    }

    /// The §Perf acceptance gate: a full victim-side steal poll —
    /// census, waiting-time gate, extraction, even a gate denial with
    /// re-insert — performs zero O(n) queue scans on either backend.
    #[test]
    fn steal_poll_performs_no_queue_scan() {
        for backend in SchedBackend::ALL {
            // Granted steal.
            let g = graph(100);
            let q = backend.build(4);
            for i in 0..40 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            let d = decide_steal(
                &cfg(VictimPolicy::Chunk(6), true),
                &g,
                q.as_ref(),
                4,
                &ExecSnapshot::uniform(100.0),
                5.0,
                1e3,
            );
            assert_eq!(d.tasks.len(), 6, "{backend:?}");
            assert_eq!(q.stats().scans, 0, "{backend:?}: granted poll scanned");

            // Denied steal (heavy payloads with one light outlier, so
            // the denial is not payload-certain and the waiting time
            // beats the overhead floor): extraction + batched re-insert.
            let g = mixed_graph(1_000_000_000);
            let q = backend.build(4);
            for i in 0..4 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            let est = ExecSnapshot::uniform(100.0);
            let mc = cfg(VictimPolicy::Single, true);
            let d = decide_steal(&mc, &g, q.as_ref(), 4, &est, 5.0, 1e3);
            assert!(d.denied_by_waiting_time, "{backend:?}");
            assert_eq!(q.len(), 4, "{backend:?}: denied tasks returned");
            assert_eq!(q.stats().scans, 0, "{backend:?}: denied poll scanned");
            assert_eq!(
                q.stats().site(BatchSite::GateDenial).batches,
                1,
                "{backend:?}: reinsert batched at the gate-denial site"
            );
        }
    }

    /// The closed loop, unit level: a denial-heavy request stream must
    /// raise the sharded spill watermark (asserted against the
    /// `watermark()` accessor), and a grant-heavy one must lower it —
    /// the gate's verdict, not just pool pressure, drives the AIMD.
    #[test]
    fn gate_denials_raise_sharded_watermark() {
        use crate::sched::{SPILL_THRESHOLD, ShardedQueue};
        // Denial-heavy: 1 GB payloads make migration always lose (the
        // payload-certain fast path proves it without extracting).
        let g = graph(1_000_000_000);
        let q = ShardedQueue::new(4);
        for i in 0..8 {
            let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
            q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
        }
        assert_eq!(q.watermark(), SPILL_THRESHOLD);
        let est = ExecSnapshot::uniform(10.0);
        for _ in 0..30 {
            let d = decide_steal(&cfg(VictimPolicy::Single, true), &g, &q, 4, &est, 5.0, 1e3);
            assert!(d.denied_by_waiting_time);
        }
        assert_eq!(q.len(), 8, "denied tasks all returned");
        assert!(
            q.watermark() > SPILL_THRESHOLD,
            "30 denials must raise the watermark, got {}",
            q.watermark()
        );
        assert_eq!(q.stats().feedback_wt_denials, 30);
        assert_eq!(q.fallback_walks(), 0, "certain denials never walk the shards");

        // Grant-heavy: tiny payloads, long local waits.
        let g = graph(100);
        let q = ShardedQueue::new(4);
        let est = ExecSnapshot::uniform(100.0);
        let mut granted = 0;
        while granted < 30 {
            for i in 0..40 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            let d = decide_steal(&cfg(VictimPolicy::Single, true), &g, &q, 4, &est, 5.0, 1e3);
            assert_eq!(d.tasks.len(), 1);
            granted += 1;
            let _ = q.drain();
        }
        assert!(
            q.watermark() < SPILL_THRESHOLD,
            "grants must lower the watermark, got {}",
            q.watermark()
        );
    }

    /// The gate-denial reinsert is one batched insert per request — one
    /// lock acquisition for the whole batch, counted under the
    /// gate-denial site — on both backends.
    #[test]
    fn denial_reinsert_is_one_batched_insert() {
        let g = mixed_graph(1_000_000_000);
        for backend in SchedBackend::ALL {
            let q = backend.build(4);
            for i in 0..8 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            // Chunk(3): the denial returns 3 tasks in one batch. avg =
            // 100µs keeps the waiting time above the overhead floor and
            // the 64 B min payload keeps the denial from being certain,
            // so the payload-weighing (extract + reinsert) path runs.
            let mc = cfg(VictimPolicy::Chunk(3), true);
            let est = ExecSnapshot::uniform(100.0);
            let d = decide_steal(&mc, &g, q.as_ref(), 4, &est, 5.0, 1e3);
            assert!(d.denied_by_waiting_time, "{backend:?}");
            let s = q.stats();
            let denial = s.site(BatchSite::GateDenial);
            assert_eq!(denial.batches, 1, "{backend:?}: one batch per denial");
            assert_eq!(denial.saved_locks(), 2, "{backend:?}: 3 tasks, 2 locks saved");
            assert_eq!(s.feedback_wt_denials, 1, "{backend:?}");
            assert_eq!(q.len(), 8, "{backend:?}: conservation");
            assert_eq!(q.stealable_count(), 4, "{backend:?}: meta preserved");
        }
    }

    /// Granted and empty outcomes reach the scheduler too.
    #[test]
    fn grants_and_empties_feed_back() {
        let g = graph(100);
        for backend in SchedBackend::ALL {
            let q = backend.build(4);
            for i in 0..40 {
                let t = TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0);
                q.insert_meta(t, i as i64, TaskMeta::of(&g, t));
            }
            let mc = cfg(VictimPolicy::Single, true);
            let est = ExecSnapshot::uniform(100.0);
            let d = decide_steal(&mc, &g, q.as_ref(), 4, &est, 5.0, 1e3);
            assert_eq!(d.tasks.len(), 1, "{backend:?}");
            assert_eq!(q.stats().feedback_grants, 1, "{backend:?}");
            let _ = q.drain();
            let d = decide_steal(&mc, &g, q.as_ref(), 4, &est, 5.0, 1e3);
            assert!(d.tasks.is_empty(), "{backend:?}");
            assert_eq!(q.stats().feedback_grants, 1, "{backend:?}: empty is not a grant");
            assert_eq!(q.stats().feedback_wt_denials, 0, "{backend:?}");
        }
    }

    #[test]
    fn steal_req_ids_are_unique_across_thieves_and_nonzero() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for thief in 0..16u32 {
            for counter in 0..64u64 {
                let id = steal_req_id(thief, counter);
                assert_ne!(id, 0);
                assert!(seen.insert(id), "collision at thief {thief} counter {counter}");
            }
        }
    }

    #[test]
    fn steal_timeout_scales_with_link_and_backs_off_capped() {
        // Ideal link: the floor dominates.
        assert_eq!(steal_timeout_us(0.0, 1e9, 50.0, 100.0, 0), STEAL_TIMEOUT_FLOOR_US);
        // Slow link: the round-trip term dominates the floor.
        // rt = 2·10_000 + 64/1 = 20_064; base = 4·rt + 150 + 200.
        let slow = steal_timeout_us(10_000.0, 1.0, 150.0, 100.0, 0);
        assert_eq!(slow, 4.0 * 20_064.0 + 150.0 + 200.0);
        // Exponential backoff, capped at 2^STEAL_BACKOFF_CAP_EXP.
        for attempt in 0..=STEAL_BACKOFF_CAP_EXP {
            assert_eq!(
                steal_timeout_us(10_000.0, 1.0, 150.0, 100.0, attempt),
                slow * f64::from(1u32 << attempt),
                "attempt {attempt}"
            );
        }
        assert_eq!(
            steal_timeout_us(10_000.0, 1.0, 150.0, 100.0, 40),
            steal_timeout_us(10_000.0, 1.0, 150.0, 100.0, STEAL_BACKOFF_CAP_EXP),
            "backoff is capped, not unbounded"
        );
        // Monotone in attempt up to the cap.
        assert!(
            steal_timeout_us(0.0, 1e9, 50.0, 100.0, 1) > STEAL_TIMEOUT_FLOOR_US,
            "retries wait longer than first tries"
        );
    }

    #[test]
    fn suspicion_threshold_dominates_steal_timeouts() {
        // The detector must never fire on a node that is merely slow to
        // answer a steal: the threshold sits above a full first-try
        // timeout with headroom, on ideal and slow links alike.
        for (lat, bw) in [(0.0, 1e9), (10_000.0, 1.0), (500.0, 1e3)] {
            let t0 = steal_timeout_us(lat, bw, 150.0, 100.0, 0);
            let susp = suspicion_timeout_us(lat, bw, 150.0, 100.0);
            assert_eq!(susp, 4.0 * t0);
            assert!(susp >= 4.0 * STEAL_TIMEOUT_FLOOR_US);
        }
    }

    #[test]
    fn stats_merge_and_success_pct() {
        let mut a = StealStats {
            requests_sent: 10,
            successful_steals: 4,
            ..Default::default()
        };
        let b = StealStats {
            requests_sent: 10,
            successful_steals: 8,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.success_pct(), 60.0);
    }
}
