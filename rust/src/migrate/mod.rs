//! The `migrate` module: distributed work stealing (§3 of the paper).
//!
//! A dedicated migrate thread per node runs all stealing-related
//! activity. The *thief* side watches for starvation and issues steal
//! requests to randomly-selected victims; the *victim* side bounds how
//! many tasks a request may take (victim policy) and — the paper's
//! addition — permits a steal only when the migrated task would
//! otherwise wait longer in the victim's queue than the migration takes
//! (the waiting-time gate).
//!
//! Both the real runtime ([`crate::node`]) and the discrete-event
//! simulator ([`crate::sim`]) drive the exact same policy code here, so
//! figure regeneration exercises the same decision logic the live system
//! runs.
//!
//! Since PR 3 the decision is a *closed loop*: every gate verdict flows
//! back into the scheduler through
//! [`crate::sched::Scheduler::feedback`], where the sharded backend
//! turns it into spill-watermark pressure, and the execution-time
//! estimate the gate runs on can track observed runtimes
//! ([`MigrateConfig::exec_ewma`]). See `docs/ARCHITECTURE.md` for the
//! loop diagram.
//!
//! Since PR 6 the *thief* side of that loop is closed too: victim
//! choice, uniform-random in the paper (and by default), can instead be
//! driven by the [`victim::VictimSelector`] (`--victim-select
//! targeted`), which scores candidates from decayed per-victim steal
//! outcomes, shipped [`EstimateDigest`] richness, and the modeled
//! round-trip price of the steal.

pub mod policy;
pub mod protocol;
pub mod victim;

pub use policy::{
    class_estimate_update, ewma_update, exec_estimate_seeded_us, exec_estimate_us, is_starving,
    merge_estimate, migrate_time_us, steal_allowance, waiting_time_per_class_us, waiting_time_us,
    DIGEST_SAMPLE_CAP, EXEC_EWMA_ALPHA, EstimateDigest, ExecSnapshot, MigrateConfig,
    StarvationView, ThiefPolicy, VictimPolicy,
};
pub use protocol::{
    steal_req_id, steal_timeout_us, suspicion_timeout_us, StealStats, VictimDecision,
    ACK_PROBE_BUDGET, STEAL_BACKOFF_CAP_EXP, STEAL_TIMEOUT_FLOOR_US, THIEF_RETRY_BUDGET,
};
pub use victim::{classify_reply, VictimOutcome, VictimSelect, VictimSelector};
