//! Victim *selection* — which node a starving thief asks — as opposed
//! to the victim *policy* ([`super::VictimPolicy`]), which is how much a
//! victim gives away once asked.
//!
//! The paper's thieves pick victims uniformly at random, and that
//! remains the default ([`VictimSelect::Uniform`], paper-faithful).
//! `--victim-select targeted` enables the [`VictimSelector`], which
//! scores every candidate from three signals, each maintained in O(1)
//! per observation and consulted in O(candidates) per pick — the
//! selector never scans a queue, its own or anyone else's:
//!
//! 1. **Steal-outcome history** — per-victim counts of granted,
//!    waiting-time-denied and empty replies, exponentially decayed
//!    ([`OUTCOME_DECAY`]) so stale verdicts fade as the run's load
//!    balance shifts. Laplace-smoothed into a grant likelihood
//!    ([`VictimSelector::grant_likelihood`]); an unprobed victim sits
//!    at 0.5. This is the AAWS idea (Fernandes et al.): prefer victims
//!    with demonstrated surplus.
//! 2. **Queue richness** — the victim's last-shipped
//!    [`super::EstimateDigest`] node-wide estimate, i.e. how much work
//!    one stolen task from that victim is worth. Digest observations
//!    age by [`DIGEST_DECAY`] per selector clock tick (lazily, via one
//!    `powi` — no per-tick sweep), so a long-running thief is not
//!    forever anchored to one early victim's numbers.
//! 3. **Round-trip price** — the modeled cost of the steal itself,
//!    `2·latency + reply_bytes/bw`, *subtracted* from the expected win.
//!    This is the Khatiri et al. analysis (*Work Stealing with
//!    latency*): a distant rich victim can lose to a near poor one, and
//!    the unit test `latency_dominated_rich_victim_loses` pins the
//!    inversion.
//!
//! The score is
//!
//! ```text
//! score(v) = grant_likelihood(v) · expected_win_us(v) − round_trip_cost_us(v)
//! ```
//!
//! and the pick is epsilon-greedy ([`DEFAULT_EPSILON`]): explore a
//! uniform-random victim with probability ε so cold or recovered
//! victims stay discoverable, otherwise take the argmax with uniform
//! tie-breaking. With no history at all (or after full decay) every
//! score ties and the selector degenerates to the paper's uniform
//! choice — property-tested in `tests/invariants.rs`.

use std::str::FromStr;

use super::policy::EstimateDigest;
use crate::comm::LinkModel;
use crate::dataflow::task::TaskClass;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// Per-observation decay applied to a victim's outcome counters before
/// each new reply from it is counted: an effective memory of
/// 1/(1−0.9) = 10 recent probes. Denials from the start of the run
/// should not poison a victim that has since filled up (and vice
/// versa) — UTS-style irregular graphs move their surplus around.
pub const OUTCOME_DECAY: f64 = 0.9;

/// Per-clock-tick decay of a digest observation's weight (one tick =
/// one recorded reply at this thief). Applied lazily as
/// `DIGEST_DECAY^age` when the weight is read, so maintenance stays
/// O(1) per observation instead of O(victims) per tick.
pub const DIGEST_DECAY: f64 = 0.95;

/// Laplace prior mass on the grant/miss counters: one phantom grant
/// and one phantom miss, so an unprobed victim scores a likelihood of
/// exactly 0.5 instead of 0/0.
pub const OUTCOME_PRIOR: f64 = 1.0;

/// Weight of the thief's own fallback estimate when blending it with
/// aged digest observations in [`VictimSelector::expected_win_us`]:
/// one fresh digest counts as much as the local prior, and a fully
/// aged-out digest leaves the fallback alone.
pub const DIGEST_PRIOR: f64 = 1.0;

/// Exploration rate of the epsilon-greedy pick: 1 in 10 steals probes
/// a uniform-random victim so the outcome history never freezes.
pub const DEFAULT_EPSILON: f64 = 0.1;

/// Reply bytes priced into the round-trip cost: the 16-byte reply
/// header, one 32-byte task descriptor and the 16-byte digest header —
/// the marginal wire bill of a minimal *successful* steal. A constant,
/// not a measurement: pricing must not require scanning any queue.
pub const PRICED_REPLY_BYTES: f64 = 64.0;

/// How a starving thief chooses which node to rob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VictimSelect {
    /// Uniform random victim — the paper's protocol and the default.
    #[default]
    Uniform,
    /// Score-and-argmax over the decayed outcome history, digest
    /// richness and link price ([`VictimSelector`]).
    Targeted,
}

impl VictimSelect {
    /// Canonical CLI spelling; accepted back by the [`FromStr`] parser
    /// (round-trip property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> &'static str {
        match self {
            VictimSelect::Uniform => "uniform",
            VictimSelect::Targeted => "targeted",
        }
    }
}

impl FromStr for VictimSelect {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "random" | "rand" => Ok(VictimSelect::Uniform),
            "targeted" | "target" | "scored" => Ok(VictimSelect::Targeted),
            _ => Err(format!(
                "unknown victim selection '{s}' (uniform | targeted)"
            )),
        }
    }
}

/// What one steal reply told the thief about its victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimOutcome {
    /// The reply carried tasks.
    Granted,
    /// The victim had stealable tasks but its waiting-time gate
    /// refused to part with them — a *busy* victim, worth retrying
    /// sooner than an empty one.
    DeniedWaitingTime,
    /// The victim had nothing stealable at all.
    DeniedEmpty,
    /// No reply arrived before the thief's steal timeout (`--faults`):
    /// the request or its reply was lost, or the victim is stalled.
    /// Scored like a miss — a victim that does not answer is worth
    /// exactly as little as one that answers empty — but counted
    /// separately so the telemetry can tell loss from poverty.
    TimedOut,
    /// The victim is gone for good: crash-stopped (membership declared
    /// it dead), or it exhausted the thief's whole retry budget without
    /// ever answering (a stalled-forever straggler). Unlike every other
    /// outcome this one is *permanent* — decay never forgives it and
    /// [`VictimSelector::pick`] skips the victim outright, closing the
    /// PR 7 liveness caveat (a dead victim used to be retried forever).
    Quarantined,
}

/// Classify a steal reply from its observable fields — shared by the
/// threaded runtime and the DES so the two label outcomes identically.
/// A reply that never arrives is classified at the timeout site
/// ([`VictimOutcome::TimedOut`]), not here: timeouts have no reply to
/// observe.
pub fn classify_reply(got_tasks: bool, denied_by_waiting_time: bool) -> VictimOutcome {
    if got_tasks {
        VictimOutcome::Granted
    } else if denied_by_waiting_time {
        VictimOutcome::DeniedWaitingTime
    } else {
        VictimOutcome::DeniedEmpty
    }
}

/// The targeted victim selector: one per thief node, fed a record per
/// steal reply, consulted once per steal request. All state is a few
/// `f64` per candidate; [`VictimSelector::pick`] touches each
/// candidate exactly once and never inspects a queue.
#[derive(Clone, Debug)]
pub struct VictimSelector {
    /// This thief's own index — never picked.
    node: usize,
    /// Total node count (candidates = `n − 1`).
    n: usize,
    /// Private stream for exploration and tie-breaking; per-node
    /// ([`crate::util::rng::thief_rng`]) so the DES's shared
    /// cost-noise stream is never perturbed.
    rng: Rng,
    epsilon: f64,
    /// One-way wire latency to each candidate (µs). Per-victim so a
    /// [`Topology`] (and the Khatiri inversion test) price correctly;
    /// uniform on a flat fabric.
    latency_us: Vec<f64>,
    /// Link bandwidth to each candidate (bytes/µs) — per-victim since
    /// the topology model made links pairwise (the follow-up PR 6
    /// deferred).
    bw_bytes_per_us: Vec<f64>,
    /// Decayed outcome masses, per victim.
    grants: Vec<f64>,
    wt_denials: Vec<f64>,
    empties: Vec<f64>,
    timeouts: Vec<f64>,
    /// Weighted mean of digest `avg_us` observations, per victim…
    richness_us: Vec<f64>,
    /// …its decayed observation weight…
    richness_w: Vec<f64>,
    /// …and the clock value `richness_w` was last materialized at
    /// (ages as `DIGEST_DECAY^(clock − stamp)` when read).
    richness_stamp: Vec<u64>,
    /// Per-victim per-[`TaskClass`] digest richness (µs) and weights —
    /// the digest's class table, decayed on the same stamp as the
    /// node-wide richness, consulted when the thief supplies its queued
    /// class mix ([`VictimSelector::expected_win_mix_us`]).
    class_richness_us: Vec<[f64; TaskClass::COUNT]>,
    class_richness_w: Vec<[f64; TaskClass::COUNT]>,
    /// Advances once per recorded reply; the time base digest ages
    /// are measured in.
    clock: u64,
    /// Permanently excluded victims (crash-stopped or stalled past the
    /// full retry budget). Never decays, never faded.
    quarantined: Vec<bool>,
}

impl VictimSelector {
    /// A selector with no history: every victim scores identically, so
    /// the first picks are uniform (minus the link price, also still
    /// uniform). `rng` should come from
    /// [`crate::util::rng::thief_rng`] so both runtimes derive the
    /// same per-node stream.
    pub fn new(node: usize, n: usize, rng: Rng) -> VictimSelector {
        VictimSelector {
            node,
            n,
            rng,
            epsilon: DEFAULT_EPSILON,
            latency_us: vec![0.0; n],
            bw_bytes_per_us: vec![1_000.0; n],
            grants: vec![0.0; n],
            wt_denials: vec![0.0; n],
            empties: vec![0.0; n],
            timeouts: vec![0.0; n],
            richness_us: vec![0.0; n],
            richness_w: vec![0.0; n],
            richness_stamp: vec![0; n],
            class_richness_us: vec![[0.0; TaskClass::COUNT]; n],
            class_richness_w: vec![[0.0; TaskClass::COUNT]; n],
            clock: 0,
            quarantined: vec![false; n],
        }
    }

    /// Price every candidate with the same link — a flat fabric
    /// ([`crate::comm::LinkModel`]).
    pub fn with_link(mut self, latency_us: f64, bw_bytes_per_us: f64) -> VictimSelector {
        self.latency_us.fill(latency_us);
        self.bw_bytes_per_us
            .fill(bw_bytes_per_us.max(f64::MIN_POSITIVE));
        self
    }

    /// Price each candidate with its pairwise link under `topo`
    /// ([`Topology::link_between`]). With a flat topology every pair
    /// resolves to `base` and this is exactly
    /// [`VictimSelector::with_link`] on the base parameters.
    pub fn with_topology(mut self, topo: &Topology, base: LinkModel) -> VictimSelector {
        for v in 0..self.n {
            let l = topo.link_between(self.node, v, base);
            self.latency_us[v] = l.latency_us;
            self.bw_bytes_per_us[v] = l.bw_bytes_per_us.max(f64::MIN_POSITIVE);
        }
        self
    }

    pub fn with_epsilon(mut self, epsilon: f64) -> VictimSelector {
        self.epsilon = epsilon;
        self
    }

    /// Override one candidate's latency (heterogeneous-link tests).
    pub fn set_latency_us(&mut self, victim: usize, latency_us: f64) {
        self.latency_us[victim] = latency_us;
    }

    /// Feed one steal reply into the history. `digest` is the reply's
    /// [`EstimateDigest`], when one travelled — its node-wide estimate
    /// refreshes the victim's richness signal and its per-class table
    /// refreshes the class-aware richness consulted by
    /// [`VictimSelector::expected_win_mix_us`]. O(1): decays only the
    /// observed victim's counters and advances the clock (other
    /// victims' digests age lazily via the clock).
    pub fn record(
        &mut self,
        victim: usize,
        outcome: VictimOutcome,
        digest: Option<&EstimateDigest>,
    ) {
        self.clock += 1;
        self.grants[victim] *= OUTCOME_DECAY;
        self.wt_denials[victim] *= OUTCOME_DECAY;
        self.empties[victim] *= OUTCOME_DECAY;
        self.timeouts[victim] *= OUTCOME_DECAY;
        match outcome {
            VictimOutcome::Granted => self.grants[victim] += 1.0,
            VictimOutcome::DeniedWaitingTime => self.wt_denials[victim] += 1.0,
            VictimOutcome::DeniedEmpty => self.empties[victim] += 1.0,
            VictimOutcome::TimedOut => self.timeouts[victim] += 1.0,
            VictimOutcome::Quarantined => self.quarantined[victim] = true,
        }
        if let Some(d) = digest {
            if d.avg_us > 0.0 {
                // Age the victim's whole digest record (node-wide and
                // per-class share one stamp, so one powi covers both),
                // then fold in the fresh observation.
                let decay = self.digest_age_factor(victim);
                let aged = self.richness_w[victim] * decay;
                let w = aged + 1.0;
                self.richness_us[victim] =
                    (self.richness_us[victim] * aged + d.avg_us) / w;
                self.richness_w[victim] = w;
                for c in 0..TaskClass::COUNT {
                    let cw = self.class_richness_w[victim][c] * decay;
                    if d.class_samples[c] > 0 && d.class_est_us[c] > 0.0 {
                        let nw = cw + 1.0;
                        self.class_richness_us[victim][c] =
                            (self.class_richness_us[victim][c] * cw + d.class_est_us[c]) / nw;
                        self.class_richness_w[victim][c] = nw;
                    } else {
                        self.class_richness_w[victim][c] = cw;
                    }
                }
                self.richness_stamp[victim] = self.clock;
            }
        }
    }

    /// Lazy-aging factor for the victim's digest record at the current
    /// clock: `DIGEST_DECAY^(clock − stamp)`.
    fn digest_age_factor(&self, victim: usize) -> f64 {
        let age = (self.clock - self.richness_stamp[victim]).min(4_096) as i32;
        DIGEST_DECAY.powi(age)
    }

    /// The victim's digest-observation weight after lazy aging.
    fn aged_digest_weight(&self, victim: usize) -> f64 {
        self.richness_w[victim] * self.digest_age_factor(victim)
    }

    /// Laplace-smoothed probability that a request to `victim` comes
    /// back with tasks: `(g + 1) / (g + d + e + 2)` over the decayed
    /// masses. No history → 0.5.
    pub fn grant_likelihood(&self, victim: usize) -> f64 {
        let g = self.grants[victim];
        let miss = self.wt_denials[victim] + self.empties[victim] + self.timeouts[victim];
        (g + OUTCOME_PRIOR) / (g + miss + 2.0 * OUTCOME_PRIOR)
    }

    /// Expected worth (µs) of one task stolen from `victim`: the aged
    /// digest observations shrunk toward `fallback_us` — the thief's
    /// own node-wide estimate, its best guess absent remote evidence.
    /// Fully aged-out history returns exactly the fallback.
    pub fn expected_win_us(&self, victim: usize, fallback_us: f64) -> f64 {
        let w = self.aged_digest_weight(victim);
        (w * self.richness_us[victim] + DIGEST_PRIOR * fallback_us) / (w + DIGEST_PRIOR)
    }

    /// Class-aware expected win: the digest's per-class table weighted
    /// by the thief's queued class mix, instead of the node-wide mean.
    /// Each queued class contributes its aged per-class richness shrunk
    /// toward the node-wide expectation (which itself shrinks toward
    /// `fallback_us`), weighted by its share of the mix. An empty mix —
    /// the common case for a fully starved thief — degenerates to
    /// [`VictimSelector::expected_win_us`] exactly, as does a victim
    /// whose digests never carried class entries.
    pub fn expected_win_mix_us(
        &self,
        victim: usize,
        mix: &[usize; TaskClass::COUNT],
        fallback_us: f64,
    ) -> f64 {
        let total: usize = mix.iter().sum();
        if total == 0 {
            return self.expected_win_us(victim, fallback_us);
        }
        let base = self.expected_win_us(victim, fallback_us);
        let decay = self.digest_age_factor(victim);
        let mut acc = 0.0;
        for c in 0..TaskClass::COUNT {
            if mix[c] == 0 {
                continue;
            }
            let cw = self.class_richness_w[victim][c] * decay;
            let est = (cw * self.class_richness_us[victim][c] + DIGEST_PRIOR * base)
                / (cw + DIGEST_PRIOR);
            acc += mix[c] as f64 * est;
        }
        acc / total as f64
    }

    /// The steal's modeled price: request out, reply back
    /// (`2·latency`), plus the minimal granted reply's bytes at the
    /// pairwise link bandwidth. A constant per victim — no queue is
    /// consulted.
    pub fn round_trip_cost_us(&self, victim: usize) -> f64 {
        2.0 * self.latency_us[victim] + PRICED_REPLY_BYTES / self.bw_bytes_per_us[victim]
    }

    /// The candidate's full score (µs of expected net win).
    pub fn score(&self, victim: usize, fallback_win_us: f64) -> f64 {
        self.grant_likelihood(victim) * self.expected_win_us(victim, fallback_win_us)
            - self.round_trip_cost_us(victim)
    }

    /// [`VictimSelector::score`] with the thief's queued class mix
    /// driving the expected win (`None` = node-wide, identical to
    /// `score`).
    pub fn score_mix(
        &self,
        victim: usize,
        fallback_win_us: f64,
        mix: Option<&[usize; TaskClass::COUNT]>,
    ) -> f64 {
        let win = match mix {
            Some(m) => self.expected_win_mix_us(victim, m, fallback_win_us),
            None => self.expected_win_us(victim, fallback_win_us),
        };
        self.grant_likelihood(victim) * win - self.round_trip_cost_us(victim)
    }

    /// Choose a victim: with probability ε a uniform-random candidate
    /// (exploration), otherwise the score argmax with uniform
    /// tie-breaking (reservoir-sampled, so an all-tie state — no
    /// history, or full decay on a uniform fabric — is a uniform draw
    /// and the selector degenerates to the paper's protocol). Never
    /// returns `self.node`. O(candidates).
    pub fn pick(&mut self, fallback_win_us: f64) -> usize {
        self.pick_scoped(fallback_win_us, None, None)
    }

    /// [`VictimSelector::pick`] restricted to a steal domain and/or
    /// class-mix-aware:
    ///
    /// * `domain` — per-node membership mask (`--steal-domains
    ///   hierarchical` passes the current escalation tier's peers);
    ///   `None` = every remote node, exactly `pick`'s candidate set.
    /// * `mix` — the thief's queued class mix for the expected-win term
    ///   ([`VictimSelector::score_mix`]); `None` or all-zero = the
    ///   node-wide mean.
    ///
    /// With both `None` this *is* `pick`: same candidate walk, same RNG
    /// draws, same result — the byte-identity anchor for flat runs.
    pub fn pick_scoped(
        &mut self,
        fallback_win_us: f64,
        domain: Option<&[bool]>,
        mix: Option<&[usize; TaskClass::COUNT]>,
    ) -> usize {
        debug_assert!(self.n > 1);
        let allowed = |sel: &Self, v: usize| {
            v != sel.node
                && !sel.quarantined[v]
                && domain.map_or(true, |d| d.get(v).copied().unwrap_or(false))
        };
        let live = (0..self.n).filter(|&v| allowed(self, v)).count();
        if live == 0 {
            // Every candidate is quarantined (or the whole domain is):
            // there is no good answer, so fall back to a uniform draw —
            // the ensuing request times out or is denied like any other
            // and stealing starves out.
            return self.rng.pick_other(self.n, self.node);
        }
        if self.epsilon > 0.0 && self.rng.uniform() < self.epsilon {
            // k-th live candidate. With nothing quarantined and no
            // domain this is the same draw and the same index map as
            // `Rng::pick_other`, so quarantine-free flat runs are
            // byte-identical to PR 8.
            let mut k = self.rng.below(live as u64) as usize;
            for v in 0..self.n {
                if !allowed(self, v) {
                    continue;
                }
                if k == 0 {
                    return v;
                }
                k -= 1;
            }
            unreachable!("k < live by construction");
        }
        let mut best = usize::MAX;
        let mut best_score = f64::NEG_INFINITY;
        let mut ties = 0u64;
        for v in 0..self.n {
            if !allowed(self, v) {
                continue;
            }
            let s = self.score_mix(v, fallback_win_us, mix);
            if s > best_score || best == usize::MAX {
                best = v;
                best_score = s;
                ties = 1;
            } else if s == best_score {
                ties += 1;
                if self.rng.below(ties) == 0 {
                    best = v;
                }
            }
        }
        best
    }

    /// Whether `victim` has been permanently excluded.
    pub fn is_quarantined(&self, victim: usize) -> bool {
        self.quarantined[victim]
    }

    /// Multiply every piece of decayed history by `factor`
    /// (`fade(0.0)` forgets everything). Exists for the
    /// decay-returns-to-uniform property test; the runtimes never call
    /// it — their decay is the per-observation [`OUTCOME_DECAY`] /
    /// [`DIGEST_DECAY`] machinery.
    pub fn fade(&mut self, factor: f64) {
        for v in 0..self.n {
            self.grants[v] *= factor;
            self.wt_denials[v] *= factor;
            self.empties[v] *= factor;
            self.timeouts[v] *= factor;
            self.richness_w[v] *= factor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::thief_rng;

    fn selector(node: usize, n: usize) -> VictimSelector {
        VictimSelector::new(node, n, thief_rng(42, node)).with_link(1.0, 1_000.0)
    }

    /// A digest carrying only the node-wide estimate — what most tests
    /// feed [`VictimSelector::record`].
    fn digest(avg_us: f64) -> EstimateDigest {
        EstimateDigest {
            avg_us,
            avg_samples: 1,
            class_est_us: [0.0; TaskClass::COUNT],
            class_samples: [0; TaskClass::COUNT],
        }
    }

    /// A digest with one seeded class entry on top of the node-wide
    /// estimate.
    fn class_digest(avg_us: f64, class: TaskClass, est_us: f64) -> EstimateDigest {
        let mut d = digest(avg_us);
        d.class_est_us[class.idx()] = est_us;
        d.class_samples[class.idx()] = 1;
        d
    }

    #[test]
    fn select_labels_round_trip() {
        for s in [VictimSelect::Uniform, VictimSelect::Targeted] {
            assert_eq!(s.label().parse::<VictimSelect>().unwrap(), s);
        }
        assert_eq!("RANDOM".parse::<VictimSelect>().unwrap(), VictimSelect::Uniform);
        assert_eq!("scored".parse::<VictimSelect>().unwrap(), VictimSelect::Targeted);
        assert!("nearest".parse::<VictimSelect>().is_err());
        assert_eq!(VictimSelect::default(), VictimSelect::Uniform);
    }

    #[test]
    fn classify_reply_covers_all_outcomes() {
        assert_eq!(classify_reply(true, false), VictimOutcome::Granted);
        // A granted reply wins even if the flag were set (it never is).
        assert_eq!(classify_reply(true, true), VictimOutcome::Granted);
        assert_eq!(classify_reply(false, true), VictimOutcome::DeniedWaitingTime);
        assert_eq!(classify_reply(false, false), VictimOutcome::DeniedEmpty);
    }

    #[test]
    fn cold_selector_scores_tie_and_grant_likelihood_is_half() {
        let s = selector(0, 4);
        for v in 1..4 {
            assert_eq!(s.grant_likelihood(v), 0.5);
            assert_eq!(s.score(v, 100.0), s.score(1, 100.0));
        }
        // Expected win with no digest history is exactly the fallback.
        assert_eq!(s.expected_win_us(2, 123.0), 123.0);
    }

    #[test]
    fn granting_victim_outscores_denying_victim() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        for _ in 0..5 {
            s.record(1, VictimOutcome::Granted, Some(&digest(50.0)));
            s.record(2, VictimOutcome::DeniedEmpty, None);
        }
        assert!(s.grant_likelihood(1) > 0.8, "{}", s.grant_likelihood(1));
        assert!(s.grant_likelihood(2) < 0.2, "{}", s.grant_likelihood(2));
        assert!(s.score(1, 50.0) > s.score(2, 50.0));
        for _ in 0..20 {
            assert_eq!(s.pick(50.0), 1);
        }
    }

    #[test]
    fn digest_richness_prefers_fat_task_victims() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        // Both victims grant equally; victim 1's tasks are 100× fatter.
        for _ in 0..4 {
            s.record(1, VictimOutcome::Granted, Some(&digest(1_000.0)));
            s.record(2, VictimOutcome::Granted, Some(&digest(10.0)));
        }
        assert!(s.expected_win_us(1, 10.0) > s.expected_win_us(2, 10.0));
        assert_eq!(s.pick(10.0), 1);
    }

    #[test]
    fn latency_dominated_rich_victim_loses() {
        // The Khatiri et al. inversion: a rich victim behind a long
        // link prices below a poor one next door.
        let mut s = selector(0, 3).with_epsilon(0.0);
        for _ in 0..4 {
            s.record(1, VictimOutcome::Granted, Some(&digest(10_000.0))); // rich…
            s.record(2, VictimOutcome::Granted, Some(100.0)); // …poor
        }
        assert_eq!(s.pick(100.0), 1, "equal links: richness wins");
        // Push the rich victim 20 ms away (round trip 40 ms ≫ win).
        s.set_latency_us(1, 20_000.0);
        assert!(s.score(1, 100.0) < s.score(2, 100.0));
        assert_eq!(s.pick(100.0), 2, "latency prices the rich victim out");
    }

    #[test]
    fn timeouts_score_like_misses_but_decay_and_fade() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        for _ in 0..5 {
            s.record(1, VictimOutcome::Granted, Some(&digest(50.0)));
            s.record(2, VictimOutcome::TimedOut, None);
        }
        // A victim that never answers prices like one that answers empty.
        assert!(s.grant_likelihood(2) < 0.2, "{}", s.grant_likelihood(2));
        assert!(s.score(1, 50.0) > s.score(2, 50.0));
        for _ in 0..20 {
            assert_eq!(s.pick(50.0), 1, "the lossy victim is avoided");
        }
        // Decay forgives a recovered victim (the fault window closed).
        for _ in 0..5 {
            s.record(2, VictimOutcome::Granted, Some(&digest(50.0)));
        }
        assert!(
            s.grant_likelihood(2) > 0.6,
            "timeouts decay: {}",
            s.grant_likelihood(2)
        );
        // And fade(0) wipes the timeout mass like every other signal.
        s.fade(0.0);
        assert_eq!(s.grant_likelihood(2), 0.5);
    }

    #[test]
    fn outcome_history_decays() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        for _ in 0..10 {
            s.record(1, VictimOutcome::DeniedEmpty, None);
        }
        let poisoned = s.grant_likelihood(1);
        assert!(poisoned < 0.2);
        // The victim fills up: a few grants outweigh the decayed
        // denial history well before 10 more probes.
        for _ in 0..5 {
            s.record(1, VictimOutcome::Granted, Some(&digest(50.0)));
        }
        assert!(
            s.grant_likelihood(1) > 0.6,
            "decay forgives: {}",
            s.grant_likelihood(1)
        );
    }

    #[test]
    fn digest_observations_age_toward_fallback() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        s.record(1, VictimOutcome::Granted, Some(&digest(10_000.0)));
        let fresh = s.expected_win_us(1, 10.0);
        assert!(fresh > 4_000.0, "fresh digest dominates: {fresh}");
        // 200 clock ticks of unrelated traffic age the observation out.
        for _ in 0..200 {
            s.record(2, VictimOutcome::DeniedEmpty, None);
        }
        let stale = s.expected_win_us(1, 10.0);
        assert!(stale < 20.0, "aged digest ≈ fallback: {stale}");
        assert!(stale >= 10.0);
    }

    #[test]
    fn fade_returns_selector_to_uniform() {
        let mut s = selector(0, 4).with_epsilon(0.0);
        for _ in 0..6 {
            s.record(1, VictimOutcome::Granted, Some(&digest(500.0)));
            s.record(2, VictimOutcome::DeniedEmpty, None);
            s.record(3, VictimOutcome::DeniedWaitingTime, None);
        }
        assert_eq!(s.pick(50.0), 1);
        s.fade(0.0);
        for v in 1..4 {
            assert_eq!(s.grant_likelihood(v), 0.5);
            assert_eq!(s.expected_win_us(v, 50.0), 50.0);
            assert_eq!(s.score(v, 50.0), s.score(1, 50.0));
        }
        // All-tie picks are a uniform draw: every victim shows up.
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[s.pick(50.0)] = true;
        }
        assert!(!seen[0], "never self");
        assert!(seen[1] && seen[2] && seen[3], "uniform coverage: {seen:?}");
    }

    #[test]
    fn pick_never_self_and_explores_everywhere_at_full_epsilon() {
        let mut s = selector(2, 6).with_epsilon(1.0);
        let mut seen = [false; 6];
        for _ in 0..500 {
            let v = s.pick(10.0);
            assert_ne!(v, 2);
            seen[v] = true;
        }
        for (v, hit) in seen.iter().enumerate() {
            assert_eq!(*hit, v != 2, "victim {v}");
        }
    }

    #[test]
    fn quarantine_is_permanent_and_skipped_by_pick() {
        let mut s = selector(0, 4).with_epsilon(0.5);
        // Victim 1 is the richest by far — then it crash-stops.
        for _ in 0..6 {
            s.record(1, VictimOutcome::Granted, Some(&digest(10_000.0)));
        }
        s.record(1, VictimOutcome::Quarantined, None);
        assert!(s.is_quarantined(1));
        for _ in 0..300 {
            let v = s.pick(50.0);
            assert_ne!(v, 1, "quarantined victims are never picked");
            assert_ne!(v, 0, "never self");
        }
        // Neither decay, fresh grants elsewhere, nor fade() forgive it.
        for _ in 0..50 {
            s.record(2, VictimOutcome::Granted, Some(&digest(50.0)));
        }
        s.fade(0.0);
        assert!(s.is_quarantined(1));
        for _ in 0..100 {
            assert_ne!(s.pick(50.0), 1);
        }
    }

    #[test]
    fn all_quarantined_degenerates_to_uniform_fallback() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        s.record(1, VictimOutcome::Quarantined, None);
        s.record(2, VictimOutcome::Quarantined, None);
        // No live candidate remains; the pick still terminates and
        // never returns self.
        for _ in 0..50 {
            assert_ne!(s.pick(50.0), 0);
        }
    }

    #[test]
    fn identical_history_gives_identical_picks() {
        let mut a = selector(0, 5).with_epsilon(0.0);
        let mut b = selector(0, 5).with_epsilon(0.0);
        let feed = |s: &mut VictimSelector| {
            s.record(1, VictimOutcome::Granted, Some(&digest(300.0)));
            s.record(2, VictimOutcome::DeniedWaitingTime, None);
            s.record(3, VictimOutcome::DeniedEmpty, None);
            s.record(4, VictimOutcome::Granted, None);
        };
        feed(&mut a);
        feed(&mut b);
        for v in 1..5 {
            assert_eq!(a.score(v, 80.0), b.score(v, 80.0));
        }
        for _ in 0..50 {
            assert_eq!(a.pick(80.0), b.pick(80.0));
        }
    }

    #[test]
    fn topology_prices_links_pairwise() {
        let base = LinkModel::cluster();
        let topo: Topology = "socket=2,socket-lat-us=1,socket-bw=40000,cluster-lat-us=20,cluster-bw=2500"
            .parse()
            .unwrap();
        let s = VictimSelector::new(0, 4, thief_rng(7, 0)).with_topology(&topo, base);
        // Socket mate: 2·1 + 64/40000; cross-socket: 2·20 + 64/2500.
        assert_eq!(s.round_trip_cost_us(1), 2.0 + 64.0 / 40_000.0);
        assert_eq!(s.round_trip_cost_us(2), 40.0 + 64.0 / 2_500.0);
        assert_eq!(s.round_trip_cost_us(2), s.round_trip_cost_us(3));
        // Flat topology ≡ with_link on the base parameters, bit-for-bit.
        let flat = VictimSelector::new(0, 4, thief_rng(7, 0))
            .with_topology(&Topology::flat(), base);
        let uniform = VictimSelector::new(0, 4, thief_rng(7, 0))
            .with_link(base.latency_us, base.bw_bytes_per_us);
        for v in 1..4 {
            assert_eq!(
                flat.round_trip_cost_us(v).to_bits(),
                uniform.round_trip_cost_us(v).to_bits()
            );
        }
    }

    #[test]
    fn pick_scoped_respects_the_domain_mask() {
        let mut s = selector(0, 8).with_epsilon(0.5);
        // Victim 5 is far richer — but outside the domain.
        for _ in 0..6 {
            s.record(5, VictimOutcome::Granted, Some(&digest(10_000.0)));
        }
        let domain = [false, true, true, true, false, false, false, false];
        for _ in 0..300 {
            let v = s.pick_scoped(50.0, Some(&domain), None);
            assert!((1..=3).contains(&v), "out-of-domain pick: {v}");
        }
        // An empty domain falls back to a uniform draw over everyone.
        let none = [false; 8];
        for _ in 0..50 {
            assert_ne!(s.pick_scoped(50.0, Some(&none), None), 0);
        }
        // No domain, no mix ≡ pick (same draws on identical clones).
        let mut a = selector(1, 6).with_epsilon(0.3);
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(a.pick(20.0), b.pick_scoped(20.0, None, None));
        }
    }

    #[test]
    fn class_mix_weighs_digest_class_table() {
        let mut s = selector(0, 3).with_epsilon(0.0);
        // Victim 1 is rich in GEMMs, victim 2 in POTRFs; identical
        // node-wide averages, so the mean-based score cannot tell them
        // apart.
        for _ in 0..4 {
            s.record(
                1,
                VictimOutcome::Granted,
                Some(&class_digest(500.0, TaskClass::Gemm, 2_000.0)),
            );
            s.record(
                2,
                VictimOutcome::Granted,
                Some(&class_digest(500.0, TaskClass::Potrf, 2_000.0)),
            );
        }
        assert_eq!(s.expected_win_us(1, 100.0), s.expected_win_us(2, 100.0));
        let mut gemm_mix = [0usize; TaskClass::COUNT];
        gemm_mix[TaskClass::Gemm.idx()] = 5;
        assert!(
            s.expected_win_mix_us(1, &gemm_mix, 100.0)
                > s.expected_win_mix_us(2, &gemm_mix, 100.0),
            "a GEMM-heavy thief values the GEMM-rich victim more"
        );
        assert_eq!(s.pick_scoped(100.0, None, Some(&gemm_mix)), 1);
        let mut potrf_mix = [0usize; TaskClass::COUNT];
        potrf_mix[TaskClass::Potrf.idx()] = 5;
        assert_eq!(s.pick_scoped(100.0, None, Some(&potrf_mix)), 2);
        // An empty mix degenerates to the node-wide mean exactly.
        let empty = [0usize; TaskClass::COUNT];
        assert_eq!(
            s.expected_win_mix_us(1, &empty, 100.0),
            s.expected_win_us(1, 100.0)
        );
        assert_eq!(s.score_mix(1, 100.0, None), s.score(1, 100.0));
    }
}
