//! Thief and victim policies (§3, "Thief policy" / "Victim policy"),
//! the waiting-time formula (§3, "Waiting Time") and the execution-time
//! estimators that feed it.
//!
//! Everything here is pure policy arithmetic shared verbatim by the
//! threaded runtime ([`crate::node`]) and the DES ([`crate::sim`]); the
//! state it consumes (ready counts, successor counts, execution-time
//! averages) is maintained incrementally by the runtimes so every
//! evaluation is O(1).

use std::str::FromStr;

use crate::dataflow::task::TaskClass;

/// When does a node decide it is starving and becomes a thief?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThiefPolicy {
    /// Naive: steal when there are no ready tasks. The paper shows this
    /// over-steals — by the time a stolen task arrives, successors of
    /// tasks that were executing have refilled the queue (Fig. 3).
    ReadyOnly,
    /// The paper's contribution: steal only when there are no ready tasks
    /// *and* no local successors of tasks currently in execution (the
    /// "future tasks" that will be scheduled in the near term).
    ReadySuccessors,
}

impl ThiefPolicy {
    /// Canonical CLI spelling; accepted back by the [`FromStr`] parser
    /// (round-trip property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> &'static str {
        match self {
            ThiefPolicy::ReadyOnly => "ready-only",
            ThiefPolicy::ReadySuccessors => "ready-successors",
        }
    }
}

/// How many tasks may one steal request take?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Up to half of the currently stealable tasks.
    Half,
    /// Up to a fixed chunk (the paper uses 20 = half the worker threads).
    Chunk(usize),
    /// Exactly one task per request (Chunk(1)).
    Single,
}

impl VictimPolicy {
    /// Display label; the [`FromStr`] parser accepts it back
    /// (case-insensitively, including the `Chunk(8)` spelling — the
    /// round trip is property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> String {
        match self {
            VictimPolicy::Half => "Half".into(),
            VictimPolicy::Chunk(k) => format!("Chunk({k})"),
            VictimPolicy::Single => "Single".into(),
        }
    }
}

impl FromStr for VictimPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let l = s.to_ascii_lowercase();
        if l == "half" {
            Ok(VictimPolicy::Half)
        } else if l == "single" {
            Ok(VictimPolicy::Single)
        } else if l == "chunk" {
            Ok(VictimPolicy::Chunk(20))
        } else if let Some(k) = l.strip_prefix("chunk") {
            let k = k.trim_matches(|c| c == '(' || c == ')' || c == '-' || c == '=');
            k.parse::<usize>()
                .map(VictimPolicy::Chunk)
                .map_err(|_| format!("bad chunk size in '{s}'"))
        } else {
            Err(format!(
                "unknown victim policy '{s}' (half | chunk[N] | single)"
            ))
        }
    }
}

impl FromStr for ThiefPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ready" | "ready-only" | "readyonly" => Ok(ThiefPolicy::ReadyOnly),
            "successors" | "ready-successors" | "readysuccessors" | "future" => {
                Ok(ThiefPolicy::ReadySuccessors)
            }
            _ => Err(format!(
                "unknown thief policy '{s}' (ready-only | ready-successors)"
            )),
        }
    }
}

/// Full work-stealing configuration for a run.
#[derive(Clone, Copy, Debug)]
pub struct MigrateConfig {
    /// Stealing enabled at all? (`No-Steal` baseline when false.)
    pub enabled: bool,
    pub thief: ThiefPolicy,
    pub victim: VictimPolicy,
    /// The waiting-time gate on the victim side (§3, "Waiting Time").
    pub use_waiting_time: bool,
    /// Migrate-thread starvation check interval (µs).
    pub poll_interval_us: f64,
    /// Outstanding steal requests allowed per thief (PaRSEC uses 1:
    /// a thief waits for the reply before asking elsewhere).
    pub max_inflight: usize,
    /// Fixed per-steal protocol overhead (µs) counted by the waiting-
    /// time gate on top of the wire transfer: victim-side input-data
    /// copy-out, thief-side task recreation, and the MPI rendezvous
    /// handshake. PaRSEC-scale default; the Fig. 6 ablation is sensitive
    /// to this being non-trivial, exactly as the paper argues.
    pub migrate_overhead_us: f64,
    /// Feed [`waiting_time_us`] an EWMA of observed execution times
    /// ([`ewma_update`]) instead of the whole-run running mean
    /// (`--exec-ewma`). Off by default: the paper's §3 formula uses
    /// "execution time elapsed / tasks executed till now", so `false`
    /// is the paper-faithful estimator. On, the gate tracks the
    /// *current* task granularity — Table 1 shows it varies by orders
    /// of magnitude across kernels, so a run whose task mix shifts
    /// (e.g. Cholesky's POTRF→GEMM front) gates on stale averages
    /// without it.
    pub exec_ewma: bool,
    /// Gate on a per-[`TaskClass`] estimator table instead of one
    /// node-wide average (`--exec-per-class`). Table 1 shows per-class
    /// execution times spanning orders of magnitude, so a queue of
    /// GEMMs and a queue of POTRFs with the same length have wildly
    /// different expected waits: with this on, the expected wait is
    /// computed from the *actual queue composition*
    /// ([`waiting_time_per_class_us`]: Σ class_count × class_estimate
    /// / workers) with the node-wide estimate as the fallback oracle
    /// for classes that have not completed a task yet. Off by default —
    /// the node-wide estimator is the paper-faithful configuration.
    pub exec_per_class: bool,
}

impl MigrateConfig {
    pub fn disabled() -> Self {
        MigrateConfig {
            enabled: false,
            ..Self::default()
        }
    }
}

impl Default for MigrateConfig {
    fn default() -> Self {
        MigrateConfig {
            enabled: true,
            thief: ThiefPolicy::ReadySuccessors,
            victim: VictimPolicy::Single,
            use_waiting_time: true,
            poll_interval_us: 100.0,
            max_inflight: 1,
            migrate_overhead_us: 150.0,
            exec_ewma: false,
            exec_per_class: false,
        }
    }
}

/// A thief-side snapshot of the node state, fed to the starvation check.
///
/// Both fields are O(1) reads: `ready` is the scheduler's task counter
/// and `executing_local_successors` is maintained incrementally by the
/// runtimes (added when a task starts executing, subtracted when it
/// finishes) — the starvation poll never walks the queue or the
/// executing set.
#[derive(Clone, Copy, Debug, Default)]
pub struct StarvationView {
    /// Ready tasks waiting in the scheduler queue.
    pub ready: usize,
    /// Local successors of tasks currently in execution — the "future
    /// tasks" of the paper's improved thief policy.
    pub executing_local_successors: usize,
}

/// Is this node starving under `policy`?
pub fn is_starving(policy: ThiefPolicy, view: StarvationView) -> bool {
    match policy {
        ThiefPolicy::ReadyOnly => view.ready == 0,
        ThiefPolicy::ReadySuccessors => view.ready == 0 && view.executing_local_successors == 0,
    }
}

/// Victim-side upper bound on tasks allowed out per request, given the
/// current count of stealable ready tasks (§3, "Victim policy"). The
/// count is the scheduler's O(1) incremental census
/// ([`crate::sched::Scheduler::stealable_count`]), not a queue scan.
///
/// ```
/// use parsteal::migrate::{steal_allowance, VictimPolicy};
///
/// // Half gives away at most half of what is stealable…
/// assert_eq!(steal_allowance(VictimPolicy::Half, 40), 20);
/// // …so a single stealable task is never taken (half of 1 = 0).
/// assert_eq!(steal_allowance(VictimPolicy::Half, 1), 0);
/// // Chunk caps at the chunk size; Single at one.
/// assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 100), 20);
/// assert_eq!(steal_allowance(VictimPolicy::Single, 9), 1);
/// ```
pub fn steal_allowance(policy: VictimPolicy, stealable: usize) -> usize {
    match policy {
        VictimPolicy::Half => stealable / 2,
        VictimPolicy::Chunk(k) => stealable.min(k),
        VictimPolicy::Single => stealable.min(1),
    }
}

/// Expected waiting time before a queued task reaches a worker (§3,
/// "Waiting Time"):
///
/// ```text
/// waiting = (#ready / #workers + 1) * average task execution time
/// ```
///
/// The `+ 1` is the task's own execution slot: even an empty queue
/// waits one average task. `avg_exec_us` is either the running mean
/// ("execution time elapsed / tasks executed till now", the paper's
/// estimator) or, with [`MigrateConfig::exec_ewma`], the
/// [`ewma_update`] average of recent executions.
///
/// ```
/// use parsteal::migrate::waiting_time_us;
///
/// // 40 queued tasks over 40 workers, 10 µs average granularity:
/// // one queue "round" ahead of us plus our own slot = 20 µs.
/// assert_eq!(waiting_time_us(40, 40, 10.0), 20.0);
/// // An empty queue still waits one average task.
/// assert_eq!(waiting_time_us(0, 8, 5.0), 5.0);
/// ```
pub fn waiting_time_us(ready: usize, workers: usize, avg_exec_us: f64) -> f64 {
    (ready as f64 / workers.max(1) as f64 + 1.0) * avg_exec_us
}

/// Expected waiting time computed from the *actual queue composition*
/// (`--exec-per-class`): instead of `queue_len × one node-wide mean`,
/// each queued class contributes `count × its own estimate`, divided
/// over the workers, plus one `fallback_us` slot for the task's own
/// execution (the `+ 1` of [`waiting_time_us`]). Classes with no
/// completed sample yet (estimate ≤ 0) fall back to `fallback_us` —
/// the node-wide estimator stays the oracle until per-class history
/// exists, so the gated formula degenerates to the paper's exactly
/// when every class estimate equals the node-wide average.
///
/// ```
/// use parsteal::dataflow::task::TaskClass;
/// use parsteal::migrate::{waiting_time_per_class_us, waiting_time_us};
///
/// let mut counts = [0usize; TaskClass::COUNT];
/// let mut est = [0.0f64; TaskClass::COUNT];
/// counts[TaskClass::Potrf.idx()] = 4; // 4 queued POTRFs at 100 µs
/// est[TaskClass::Potrf.idx()] = 100.0;
/// counts[TaskClass::Gemm.idx()] = 4; // 4 queued GEMMs at 900 µs
/// est[TaskClass::Gemm.idx()] = 900.0;
/// // (4·100 + 4·900) / 4 workers + 500 own slot = 1500 µs …
/// assert_eq!(waiting_time_per_class_us(&counts, &est, 4, 500.0), 1500.0);
/// // …whereas the node-wide mean sees 8 × 500: (8/4 + 1) · 500.
/// assert_eq!(waiting_time_us(8, 4, 500.0), 1500.0);
/// // With uniform estimates the two formulas agree exactly.
/// est[TaskClass::Gemm.idx()] = 500.0;
/// est[TaskClass::Potrf.idx()] = 500.0;
/// assert_eq!(waiting_time_per_class_us(&counts, &est, 4, 500.0), 1500.0);
/// ```
pub fn waiting_time_per_class_us(
    class_counts: &[usize; TaskClass::COUNT],
    class_est_us: &[f64; TaskClass::COUNT],
    workers: usize,
    fallback_us: f64,
) -> f64 {
    let mut queued = 0.0;
    for class in TaskClass::ALL {
        let count = class_counts[class.idx()];
        if count == 0 {
            continue;
        }
        let est = class_est_us[class.idx()];
        let est = if est > 0.0 { est } else { fallback_us };
        queued += count as f64 * est;
    }
    queued / workers.max(1) as f64 + fallback_us
}

/// Time to migrate a task's inputs to the thief over the modeled link
/// (§3, "time required to migrate the task"): one latency plus the
/// payload serialized at link bandwidth. [`MigrateConfig`] adds the
/// fixed protocol overhead on top.
pub fn migrate_time_us(latency_us: f64, payload_bytes: u64, bw_bytes_per_us: f64) -> f64 {
    latency_us + payload_bytes as f64 / bw_bytes_per_us
}

/// Gain of the execution-time EWMA (`--exec-ewma`): 1/8, the classic
/// TCP-SRTT smoothing factor — heavy enough that one outlier kernel
/// cannot swing the waiting-time gate, light enough to track Table 1's
/// per-kernel granularity shifts within a few dozen completions.
pub const EXEC_EWMA_ALPHA: f64 = 0.125;

/// One EWMA step over observed execution times. A non-positive `prev`
/// means "no history yet", so the first sample seeds the average
/// (mirroring how the running mean starts).
///
/// ```
/// use parsteal::migrate::{ewma_update, EXEC_EWMA_ALPHA};
///
/// let first = ewma_update(0.0, 100.0); // first sample seeds
/// assert_eq!(first, 100.0);
/// let next = ewma_update(first, 200.0); // moves α of the way there
/// assert_eq!(next, 100.0 + EXEC_EWMA_ALPHA * 100.0);
/// ```
pub fn ewma_update(prev_us: f64, sample_us: f64) -> f64 {
    if prev_us <= 0.0 {
        sample_us
    } else {
        prev_us + EXEC_EWMA_ALPHA * (sample_us - prev_us)
    }
}

/// The execution-time estimate the waiting-time gate runs on — shared
/// by the threaded runtime and the DES so the two cannot diverge: the
/// EWMA when [`MigrateConfig::exec_ewma`] is on and at least one sample
/// landed, else the running mean, else an optimistic 1 µs (PaRSEC
/// starts the same way; converges after the first few tasks).
///
/// ```
/// use parsteal::migrate::exec_estimate_us;
///
/// assert_eq!(exec_estimate_us(false, 0.0, 800.0, 4), 200.0); // mean
/// assert_eq!(exec_estimate_us(true, 50.0, 800.0, 4), 50.0); // EWMA
/// assert_eq!(exec_estimate_us(true, 0.0, 0.0, 0), 1.0); // no history
/// ```
pub fn exec_estimate_us(use_ewma: bool, ewma_us: f64, exec_sum_us: f64, tasks_done: u64) -> f64 {
    if use_ewma && ewma_us > 0.0 {
        ewma_us
    } else if tasks_done > 0 {
        exec_sum_us / tasks_done as f64
    } else {
        1.0
    }
}

/// One per-class estimator step (`--exec-per-class`), applied at every
/// task finish to the finished task's class entry. This is the *shared*
/// update rule — the threaded runtime applies it in a CAS loop over
/// f64-bits atomics, the DES over plain fields, both through this one
/// function so the two estimator tables cannot diverge. The rule itself
/// is the [`ewma_update`] EWMA (first sample seeds), which tracks a
/// class whose granularity drifts over the run (e.g. GEMM fronts
/// widening as Cholesky proceeds) instead of averaging over history
/// that Table 1 shows can span orders of magnitude.
pub fn class_estimate_update(prev_us: f64, sample_us: f64) -> f64 {
    ewma_update(prev_us, sample_us)
}

/// The victim's execution-time estimates at one steal decision — the
/// node-wide estimate (running mean or EWMA, per
/// [`MigrateConfig::exec_ewma`]) plus, under
/// [`MigrateConfig::exec_per_class`], the per-class table. Both
/// runtimes build this from incrementally-maintained state, so a
/// decision is still O(1).
#[derive(Clone, Copy, Debug)]
pub struct ExecSnapshot {
    /// Node-wide execution-time estimate (µs); the per-class formula's
    /// fallback for classes with no history.
    pub avg_us: f64,
    /// Per-class estimates (µs; ≤ 0 = no sample yet), indexed by class
    /// discriminant. `None` when `--exec-per-class` is off.
    pub per_class: Option<[f64; TaskClass::COUNT]>,
}

impl ExecSnapshot {
    /// A snapshot with only the node-wide estimate — the paper-faithful
    /// configuration, and the natural spelling in tests and benches.
    pub fn uniform(avg_us: f64) -> ExecSnapshot {
        ExecSnapshot {
            avg_us,
            per_class: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starvation_ready_only_ignores_future() {
        let view = StarvationView {
            ready: 0,
            executing_local_successors: 12,
        };
        assert!(is_starving(ThiefPolicy::ReadyOnly, view));
        assert!(!is_starving(ThiefPolicy::ReadySuccessors, view));
    }

    #[test]
    fn starvation_requires_empty_queue() {
        let view = StarvationView {
            ready: 1,
            executing_local_successors: 0,
        };
        assert!(!is_starving(ThiefPolicy::ReadyOnly, view));
        assert!(!is_starving(ThiefPolicy::ReadySuccessors, view));
    }

    #[test]
    fn allowances() {
        assert_eq!(steal_allowance(VictimPolicy::Half, 40), 20);
        assert_eq!(steal_allowance(VictimPolicy::Half, 1), 0);
        assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 7), 7);
        assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 100), 20);
        assert_eq!(steal_allowance(VictimPolicy::Single, 9), 1);
        assert_eq!(steal_allowance(VictimPolicy::Single, 0), 0);
    }

    #[test]
    fn ewma_seeds_then_smooths() {
        assert_eq!(ewma_update(0.0, 40.0), 40.0);
        assert_eq!(ewma_update(-1.0, 40.0), 40.0, "negative = no history");
        let mut avg = 40.0;
        for _ in 0..64 {
            avg = ewma_update(avg, 10.0);
        }
        assert!((avg - 10.0).abs() < 1.0, "converges to the new regime: {avg}");
    }

    #[test]
    fn waiting_time_formula() {
        // (#ready/#workers + 1) * avg: (40/40 + 1) * 10 = 20
        assert_eq!(waiting_time_us(40, 40, 10.0), 20.0);
        // empty queue still waits one average task
        assert_eq!(waiting_time_us(0, 8, 5.0), 5.0);
    }

    #[test]
    fn per_class_waiting_time_weighs_composition() {
        let mut counts = [0usize; TaskClass::COUNT];
        let mut est = [0.0f64; TaskClass::COUNT];
        counts[TaskClass::Potrf.idx()] = 2;
        est[TaskClass::Potrf.idx()] = 10.0;
        counts[TaskClass::Gemm.idx()] = 6;
        est[TaskClass::Gemm.idx()] = 1000.0;
        // (2·10 + 6·1000) / 2 + 50 = 3060
        assert_eq!(waiting_time_per_class_us(&counts, &est, 2, 50.0), 3060.0);
        // A class without history falls back to the node-wide estimate.
        est[TaskClass::Gemm.idx()] = 0.0;
        // (2·10 + 6·50) / 2 + 50 = 210
        assert_eq!(waiting_time_per_class_us(&counts, &est, 2, 50.0), 210.0);
        // An empty queue still waits one fallback slot.
        assert_eq!(
            waiting_time_per_class_us(&[0; TaskClass::COUNT], &est, 4, 7.0),
            7.0
        );
        // Uniform estimates degenerate to the paper's formula.
        let uniform = [5.0; TaskClass::COUNT];
        assert_eq!(
            waiting_time_per_class_us(&counts, &uniform, 2, 5.0),
            waiting_time_us(8, 2, 5.0)
        );
    }

    #[test]
    fn class_estimate_update_is_the_shared_ewma() {
        assert_eq!(class_estimate_update(0.0, 40.0), 40.0, "first sample seeds");
        assert_eq!(class_estimate_update(40.0, 40.0), 40.0);
        assert_eq!(class_estimate_update(100.0, 200.0), ewma_update(100.0, 200.0));
    }

    #[test]
    fn policy_parsing() {
        assert_eq!("half".parse::<VictimPolicy>().unwrap(), VictimPolicy::Half);
        assert_eq!(
            "chunk20".parse::<VictimPolicy>().unwrap(),
            VictimPolicy::Chunk(20)
        );
        assert_eq!("chunk".parse::<VictimPolicy>().unwrap(), VictimPolicy::Chunk(20));
        assert_eq!("single".parse::<VictimPolicy>().unwrap(), VictimPolicy::Single);
        assert!("quarter".parse::<VictimPolicy>().is_err());
        assert_eq!(
            "ready-successors".parse::<ThiefPolicy>().unwrap(),
            ThiefPolicy::ReadySuccessors
        );
        assert!("eager".parse::<ThiefPolicy>().is_err());
    }
}
