//! Thief and victim policies (§3, "Thief policy" / "Victim policy"),
//! the waiting-time formula (§3, "Waiting Time") and the execution-time
//! estimators that feed it.
//!
//! Everything here is pure policy arithmetic shared verbatim by the
//! threaded runtime ([`crate::node`]) and the DES ([`crate::sim`]); the
//! state it consumes (ready counts, successor counts, execution-time
//! averages) is maintained incrementally by the runtimes so every
//! evaluation is O(1).

use std::str::FromStr;

use super::victim::VictimSelect;
use crate::dataflow::task::TaskClass;

/// When does a node decide it is starving and becomes a thief?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThiefPolicy {
    /// Naive: steal when there are no ready tasks. The paper shows this
    /// over-steals — by the time a stolen task arrives, successors of
    /// tasks that were executing have refilled the queue (Fig. 3).
    ReadyOnly,
    /// The paper's contribution: steal only when there are no ready tasks
    /// *and* no local successors of tasks currently in execution (the
    /// "future tasks" that will be scheduled in the near term).
    ReadySuccessors,
}

impl ThiefPolicy {
    /// Canonical CLI spelling; accepted back by the [`FromStr`] parser
    /// (round-trip property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> &'static str {
        match self {
            ThiefPolicy::ReadyOnly => "ready-only",
            ThiefPolicy::ReadySuccessors => "ready-successors",
        }
    }
}

/// How many tasks may one steal request take?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Up to half of the currently stealable tasks.
    Half,
    /// Up to a fixed chunk (the paper uses 20 = half the worker threads).
    Chunk(usize),
    /// Exactly one task per request (Chunk(1)).
    Single,
}

impl VictimPolicy {
    /// Display label; the [`FromStr`] parser accepts it back
    /// (case-insensitively, including the `Chunk(8)` spelling — the
    /// round trip is property-tested in `tests/invariants.rs`).
    pub fn label(&self) -> String {
        match self {
            VictimPolicy::Half => "Half".into(),
            VictimPolicy::Chunk(k) => format!("Chunk({k})"),
            VictimPolicy::Single => "Single".into(),
        }
    }
}

impl FromStr for VictimPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let l = s.to_ascii_lowercase();
        if l == "half" {
            Ok(VictimPolicy::Half)
        } else if l == "single" {
            Ok(VictimPolicy::Single)
        } else if l == "chunk" {
            Ok(VictimPolicy::Chunk(20))
        } else if let Some(k) = l.strip_prefix("chunk") {
            let k = k.trim_matches(|c| c == '(' || c == ')' || c == '-' || c == '=');
            k.parse::<usize>()
                .map(VictimPolicy::Chunk)
                .map_err(|_| format!("bad chunk size in '{s}'"))
        } else {
            Err(format!(
                "unknown victim policy '{s}' (half | chunk[N] | single)"
            ))
        }
    }
}

impl FromStr for ThiefPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "ready" | "ready-only" | "readyonly" => Ok(ThiefPolicy::ReadyOnly),
            "successors" | "ready-successors" | "readysuccessors" | "future" => {
                Ok(ThiefPolicy::ReadySuccessors)
            }
            _ => Err(format!(
                "unknown thief policy '{s}' (ready-only | ready-successors)"
            )),
        }
    }
}

/// Full work-stealing configuration for a run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrateConfig {
    /// Stealing enabled at all? (`No-Steal` baseline when false.)
    pub enabled: bool,
    pub thief: ThiefPolicy,
    pub victim: VictimPolicy,
    /// The waiting-time gate on the victim side (§3, "Waiting Time").
    pub use_waiting_time: bool,
    /// Migrate-thread starvation check interval (µs).
    pub poll_interval_us: f64,
    /// Outstanding steal requests allowed per thief (PaRSEC uses 1:
    /// a thief waits for the reply before asking elsewhere).
    pub max_inflight: usize,
    /// Fixed per-steal protocol overhead (µs) counted by the waiting-
    /// time gate on top of the wire transfer: victim-side input-data
    /// copy-out, thief-side task recreation, and the MPI rendezvous
    /// handshake. PaRSEC-scale default; the Fig. 6 ablation is sensitive
    /// to this being non-trivial, exactly as the paper argues.
    pub migrate_overhead_us: f64,
    /// Feed [`waiting_time_us`] an EWMA of observed execution times
    /// ([`ewma_update`]) instead of the whole-run running mean
    /// (`--exec-ewma`). Off by default: the paper's §3 formula uses
    /// "execution time elapsed / tasks executed till now", so `false`
    /// is the paper-faithful estimator. On, the gate tracks the
    /// *current* task granularity — Table 1 shows it varies by orders
    /// of magnitude across kernels, so a run whose task mix shifts
    /// (e.g. Cholesky's POTRF→GEMM front) gates on stale averages
    /// without it.
    pub exec_ewma: bool,
    /// Gate on a per-[`TaskClass`] estimator table instead of one
    /// node-wide average (`--exec-per-class`). Table 1 shows per-class
    /// execution times spanning orders of magnitude, so a queue of
    /// GEMMs and a queue of POTRFs with the same length have wildly
    /// different expected waits: with this on, the expected wait is
    /// computed from the *actual queue composition*
    /// ([`waiting_time_per_class_us`]: Σ class_count × class_estimate
    /// / workers) with the node-wide estimate as the fallback oracle
    /// for classes that have not completed a task yet. Off by default —
    /// the node-wide estimator is the paper-faithful configuration.
    pub exec_per_class: bool,
    /// Ship the victim's execution-time estimates with every granted
    /// steal reply (`--share-estimates`): an [`EstimateDigest`] — the
    /// node-wide estimate plus the seeded per-[`TaskClass`] entries and
    /// their sample counts — travels with the stolen tasks, accounted in
    /// the wire model, and is merged into the thief's estimator tables
    /// on receipt via the shared sample-count-weighted
    /// [`merge_estimate`] rule. A thief that has never executed a class
    /// adopts the victim's estimate outright, so its waiting-time gate
    /// stops falling back to a node-wide mean it does not have for
    /// freshly stolen classes. Off by default — per-node estimators are
    /// the paper-faithful configuration.
    pub share_estimates: bool,
    /// How thieves choose their victims (`--victim-select`):
    /// [`VictimSelect::Uniform`] is the paper's uniform-random pick and
    /// the default; [`VictimSelect::Targeted`] scores candidates from
    /// decayed steal-outcome history, digest richness and link price
    /// ([`super::VictimSelector`]). Per-victim outcome telemetry is
    /// recorded either way.
    pub victim_select: VictimSelect,
}

impl MigrateConfig {
    pub fn disabled() -> Self {
        Self::default().with_enabled(false)
    }

    // Chainable builder setters: `MigrateConfig::default().with_victim(…)
    // .with_share_estimates(true)`. Every construction site outside this
    // block goes through these (or `..Default::default()` spreads), so
    // adding a field no longer forces edits to every literal in the repo.

    pub fn with_enabled(mut self, enabled: bool) -> Self {
        self.enabled = enabled;
        self
    }

    pub fn with_thief(mut self, thief: ThiefPolicy) -> Self {
        self.thief = thief;
        self
    }

    pub fn with_victim(mut self, victim: VictimPolicy) -> Self {
        self.victim = victim;
        self
    }

    pub fn with_use_waiting_time(mut self, on: bool) -> Self {
        self.use_waiting_time = on;
        self
    }

    pub fn with_poll_interval_us(mut self, us: f64) -> Self {
        self.poll_interval_us = us;
        self
    }

    pub fn with_max_inflight(mut self, n: usize) -> Self {
        self.max_inflight = n;
        self
    }

    pub fn with_migrate_overhead_us(mut self, us: f64) -> Self {
        self.migrate_overhead_us = us;
        self
    }

    pub fn with_exec_ewma(mut self, on: bool) -> Self {
        self.exec_ewma = on;
        self
    }

    pub fn with_exec_per_class(mut self, on: bool) -> Self {
        self.exec_per_class = on;
        self
    }

    pub fn with_share_estimates(mut self, on: bool) -> Self {
        self.share_estimates = on;
        self
    }

    pub fn with_victim_select(mut self, select: VictimSelect) -> Self {
        self.victim_select = select;
        self
    }

    /// Must the runtimes maintain the per-class estimator tables?
    /// True when the gate consumes them (`--exec-per-class`) *or* when
    /// steal replies ship them to thieves (`--share-estimates`) — a
    /// victim with an empty table has nothing worth sharing.
    pub fn track_per_class(&self) -> bool {
        self.exec_per_class || self.share_estimates
    }
}

impl Default for MigrateConfig {
    fn default() -> Self {
        MigrateConfig {
            enabled: true,
            thief: ThiefPolicy::ReadySuccessors,
            victim: VictimPolicy::Single,
            use_waiting_time: true,
            poll_interval_us: 100.0,
            max_inflight: 1,
            migrate_overhead_us: 150.0,
            exec_ewma: false,
            exec_per_class: false,
            share_estimates: false,
            victim_select: VictimSelect::Uniform,
        }
    }
}

/// A thief-side snapshot of the node state, fed to the starvation check.
///
/// Both fields are O(1) reads: `ready` is the scheduler's task counter
/// and `executing_local_successors` is maintained incrementally by the
/// runtimes (added when a task starts executing, subtracted when it
/// finishes) — the starvation poll never walks the queue or the
/// executing set.
#[derive(Clone, Copy, Debug, Default)]
pub struct StarvationView {
    /// Ready tasks waiting in the scheduler queue.
    pub ready: usize,
    /// Local successors of tasks currently in execution — the "future
    /// tasks" of the paper's improved thief policy.
    pub executing_local_successors: usize,
}

/// Is this node starving under `policy`?
pub fn is_starving(policy: ThiefPolicy, view: StarvationView) -> bool {
    match policy {
        ThiefPolicy::ReadyOnly => view.ready == 0,
        ThiefPolicy::ReadySuccessors => view.ready == 0 && view.executing_local_successors == 0,
    }
}

/// Victim-side upper bound on tasks allowed out per request, given the
/// current count of stealable ready tasks (§3, "Victim policy"). The
/// count is the scheduler's O(1) incremental census
/// ([`crate::sched::Scheduler::stealable_count`]), not a queue scan.
///
/// ```
/// use parsteal::migrate::{steal_allowance, VictimPolicy};
///
/// // Half gives away at most half of what is stealable…
/// assert_eq!(steal_allowance(VictimPolicy::Half, 40), 20);
/// // …so a single stealable task is never taken (half of 1 = 0).
/// assert_eq!(steal_allowance(VictimPolicy::Half, 1), 0);
/// // Chunk caps at the chunk size; Single at one.
/// assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 100), 20);
/// assert_eq!(steal_allowance(VictimPolicy::Single, 9), 1);
/// ```
pub fn steal_allowance(policy: VictimPolicy, stealable: usize) -> usize {
    match policy {
        VictimPolicy::Half => stealable / 2,
        VictimPolicy::Chunk(k) => stealable.min(k),
        VictimPolicy::Single => stealable.min(1),
    }
}

/// Expected waiting time before a queued task reaches a worker (§3,
/// "Waiting Time"):
///
/// ```text
/// waiting = (#ready / #workers + 1) * average task execution time
/// ```
///
/// The `+ 1` is the task's own execution slot: even an empty queue
/// waits one average task. `avg_exec_us` is either the running mean
/// ("execution time elapsed / tasks executed till now", the paper's
/// estimator) or, with [`MigrateConfig::exec_ewma`], the
/// [`ewma_update`] average of recent executions.
///
/// ```
/// use parsteal::migrate::waiting_time_us;
///
/// // 40 queued tasks over 40 workers, 10 µs average granularity:
/// // one queue "round" ahead of us plus our own slot = 20 µs.
/// assert_eq!(waiting_time_us(40, 40, 10.0), 20.0);
/// // An empty queue still waits one average task.
/// assert_eq!(waiting_time_us(0, 8, 5.0), 5.0);
/// ```
pub fn waiting_time_us(ready: usize, workers: usize, avg_exec_us: f64) -> f64 {
    (ready as f64 / workers.max(1) as f64 + 1.0) * avg_exec_us
}

/// Expected waiting time computed from the *actual queue composition*
/// (`--exec-per-class`): instead of `queue_len × one node-wide mean`,
/// each queued class contributes `count × its own estimate`, divided
/// over the workers, plus one `fallback_us` slot for the task's own
/// execution (the `+ 1` of [`waiting_time_us`]). Classes with no
/// completed sample yet (estimate ≤ 0) fall back to `fallback_us` —
/// the node-wide estimator stays the oracle until per-class history
/// exists, so the gated formula degenerates to the paper's exactly
/// when every class estimate equals the node-wide average.
///
/// ```
/// use parsteal::dataflow::task::TaskClass;
/// use parsteal::migrate::{waiting_time_per_class_us, waiting_time_us};
///
/// let mut counts = [0usize; TaskClass::COUNT];
/// let mut est = [0.0f64; TaskClass::COUNT];
/// counts[TaskClass::Potrf.idx()] = 4; // 4 queued POTRFs at 100 µs
/// est[TaskClass::Potrf.idx()] = 100.0;
/// counts[TaskClass::Gemm.idx()] = 4; // 4 queued GEMMs at 900 µs
/// est[TaskClass::Gemm.idx()] = 900.0;
/// // (4·100 + 4·900) / 4 workers + 500 own slot = 1500 µs …
/// assert_eq!(waiting_time_per_class_us(&counts, &est, 4, 500.0), 1500.0);
/// // …whereas the node-wide mean sees 8 × 500: (8/4 + 1) · 500.
/// assert_eq!(waiting_time_us(8, 4, 500.0), 1500.0);
/// // With uniform estimates the two formulas agree exactly.
/// est[TaskClass::Gemm.idx()] = 500.0;
/// est[TaskClass::Potrf.idx()] = 500.0;
/// assert_eq!(waiting_time_per_class_us(&counts, &est, 4, 500.0), 1500.0);
/// ```
pub fn waiting_time_per_class_us(
    class_counts: &[usize; TaskClass::COUNT],
    class_est_us: &[f64; TaskClass::COUNT],
    workers: usize,
    fallback_us: f64,
) -> f64 {
    let mut queued = 0.0;
    for class in TaskClass::ALL {
        let count = class_counts[class.idx()];
        if count == 0 {
            continue;
        }
        let est = class_est_us[class.idx()];
        let est = if est > 0.0 { est } else { fallback_us };
        queued += count as f64 * est;
    }
    queued / workers.max(1) as f64 + fallback_us
}

/// Time to migrate a task's inputs to the thief over the modeled link
/// (§3, "time required to migrate the task"): one latency plus the
/// payload serialized at link bandwidth. [`MigrateConfig`] adds the
/// fixed protocol overhead on top.
pub fn migrate_time_us(latency_us: f64, payload_bytes: u64, bw_bytes_per_us: f64) -> f64 {
    latency_us + payload_bytes as f64 / bw_bytes_per_us
}

/// Gain of the execution-time EWMA (`--exec-ewma`): 1/8, the classic
/// TCP-SRTT smoothing factor — heavy enough that one outlier kernel
/// cannot swing the waiting-time gate, light enough to track Table 1's
/// per-kernel granularity shifts within a few dozen completions.
pub const EXEC_EWMA_ALPHA: f64 = 0.125;

/// One EWMA step over observed execution times. A non-positive `prev`
/// means "no history yet", so the first sample seeds the average
/// (mirroring how the running mean starts).
///
/// ```
/// use parsteal::migrate::{ewma_update, EXEC_EWMA_ALPHA};
///
/// let first = ewma_update(0.0, 100.0); // first sample seeds
/// assert_eq!(first, 100.0);
/// let next = ewma_update(first, 200.0); // moves α of the way there
/// assert_eq!(next, 100.0 + EXEC_EWMA_ALPHA * 100.0);
/// ```
pub fn ewma_update(prev_us: f64, sample_us: f64) -> f64 {
    if prev_us <= 0.0 {
        sample_us
    } else {
        prev_us + EXEC_EWMA_ALPHA * (sample_us - prev_us)
    }
}

/// The execution-time estimate the waiting-time gate runs on — shared
/// by the threaded runtime and the DES so the two cannot diverge: the
/// EWMA when [`MigrateConfig::exec_ewma`] is on and at least one sample
/// landed, else the running mean, else an optimistic 1 µs (PaRSEC
/// starts the same way; converges after the first few tasks).
///
/// ```
/// use parsteal::migrate::exec_estimate_us;
///
/// assert_eq!(exec_estimate_us(false, 0.0, 800.0, 4), 200.0); // mean
/// assert_eq!(exec_estimate_us(true, 50.0, 800.0, 4), 50.0); // EWMA
/// assert_eq!(exec_estimate_us(true, 0.0, 0.0, 0), 1.0); // no history
/// ```
pub fn exec_estimate_us(use_ewma: bool, ewma_us: f64, exec_sum_us: f64, tasks_done: u64) -> f64 {
    if use_ewma && ewma_us > 0.0 {
        ewma_us
    } else if tasks_done > 0 {
        exec_sum_us / tasks_done as f64
    } else {
        1.0
    }
}

/// One per-class estimator step (`--exec-per-class`), applied at every
/// task finish to the finished task's class entry. This is the *shared*
/// update rule — the threaded runtime applies it in a CAS loop over
/// f64-bits atomics, the DES over plain fields, both through this one
/// function so the two estimator tables cannot diverge. The rule itself
/// is the [`ewma_update`] EWMA (first sample seeds), which tracks a
/// class whose granularity drifts over the run (e.g. GEMM fronts
/// widening as Cholesky proceeds) instead of averaging over history
/// that Table 1 shows can span orders of magnitude.
pub fn class_estimate_update(prev_us: f64, sample_us: f64) -> f64 {
    ewma_update(prev_us, sample_us)
}

/// The victim's execution-time estimates at one steal decision — the
/// node-wide estimate (running mean or EWMA, per
/// [`MigrateConfig::exec_ewma`]) plus, under
/// [`MigrateConfig::exec_per_class`], the per-class table. Both
/// runtimes build this from incrementally-maintained state, so a
/// decision is still O(1).
#[derive(Clone, Copy, Debug)]
pub struct ExecSnapshot {
    /// Node-wide execution-time estimate (µs); the per-class formula's
    /// fallback for classes with no history.
    pub avg_us: f64,
    /// Per-class estimates (µs; ≤ 0 = no sample yet), indexed by class
    /// discriminant. `None` when `--exec-per-class` is off.
    pub per_class: Option<[f64; TaskClass::COUNT]>,
}

impl ExecSnapshot {
    /// A snapshot with only the node-wide estimate — the paper-faithful
    /// configuration, and the natural spelling in tests and benches.
    pub fn uniform(avg_us: f64) -> ExecSnapshot {
        ExecSnapshot {
            avg_us,
            per_class: None,
        }
    }
}

/// The victim's execution-time knowledge, shipped with a granted steal
/// reply under [`MigrateConfig::share_estimates`]: the node-wide
/// estimate plus the per-[`TaskClass`] table with sample counts, so the
/// thief can weight the merge ([`merge_estimate`]). Entries with zero
/// samples (class never completed a task at the victim) are unseeded:
/// they cost nothing on the wire ([`EstimateDigest::wire_bytes`]) and
/// merge as no-ops.
///
/// This is the DuctTeip-style hierarchical metadata propagation / AAWS
/// performance-estimate sharing applied to the paper's waiting-time
/// gate: a thief that has never executed a GEMM would otherwise gate
/// its next victim-side decision on a node-wide fallback while the
/// tasks it just stole carry the victim's measured cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EstimateDigest {
    /// Victim's node-wide execution-time estimate (µs).
    pub avg_us: f64,
    /// Tasks behind `avg_us` (its merge weight at the thief).
    pub avg_samples: u64,
    /// Per-class estimates (µs; ≤ 0 with 0 samples = unseeded), indexed
    /// by class discriminant.
    pub class_est_us: [f64; TaskClass::COUNT],
    /// Completed-task counts behind each class estimate.
    pub class_samples: [u64; TaskClass::COUNT],
}

/// Cap on the sample weight any single digest entry may carry, applied
/// victim-side when the digest is built ([`EstimateDigest::snapshot`]).
/// Successive steals from the same victim re-ship its *cumulative*
/// history; uncapped, a prolific victim's counts would grow a thief's
/// merge weights without bound, letting one remote estimate permanently
/// outvote the thief's own measurements (and echo back inflated when
/// the thief later serves as victim). With the cap, one merge moves a
/// warm entry by at most `CAP / (local + CAP)`, while the thief's own
/// per-finish EWMA keeps its fixed 1/8 gain — local measurements
/// dominate in steady state.
pub const DIGEST_SAMPLE_CAP: u64 = 32;

impl EstimateDigest {
    /// Build a digest from a victim's estimator state, capping every
    /// sample weight at [`DIGEST_SAMPLE_CAP`]. The single shared
    /// constructor — both runtimes build their reply digests through
    /// it, so the cap cannot diverge.
    pub fn snapshot(
        avg_us: f64,
        avg_samples: u64,
        class_est_us: [f64; TaskClass::COUNT],
        class_samples: [u64; TaskClass::COUNT],
    ) -> EstimateDigest {
        EstimateDigest {
            avg_us,
            avg_samples: avg_samples.min(DIGEST_SAMPLE_CAP),
            class_est_us,
            class_samples: class_samples.map(|n| n.min(DIGEST_SAMPLE_CAP)),
        }
    }

    /// Merge this digest's class entries into a plain estimator table
    /// through [`merge_estimate`], returning the number of cold-class
    /// adoptions. This is the *shared merge loop* — unseeded-entry
    /// skip, adoption accounting, sample accumulation — used verbatim
    /// by the DES and the benches; the threaded runtime's per-cell CAS
    /// loop (`node/cluster.rs::merge_digest`) is its atomic twin.
    pub fn merge_into(
        &self,
        table: &mut [f64; TaskClass::COUNT],
        samples: &mut [u64; TaskClass::COUNT],
    ) -> u64 {
        let mut adoptions = 0u64;
        for c in 0..TaskClass::COUNT {
            let (remote_us, remote_n) = (self.class_est_us[c], self.class_samples[c]);
            if remote_n == 0 || remote_us <= 0.0 {
                continue; // unseeded at the victim: nothing to learn
            }
            adoptions += u64::from(!(samples[c] > 0 && table[c] > 0.0));
            let (merged, n) = merge_estimate(table[c], samples[c], remote_us, remote_n);
            table[c] = merged;
            samples[c] = n;
        }
        adoptions
    }

    /// Classes whose entry actually carries information (≥ 1 sample and
    /// a positive estimate) — the only entries that travel on the wire.
    pub fn seeded_entries(&self) -> usize {
        (0..TaskClass::COUNT)
            .filter(|&c| self.class_samples[c] > 0 && self.class_est_us[c] > 0.0)
            .count()
    }

    /// Wire cost of the digest inside a steal reply: a 16-byte header
    /// (node-wide estimate + sample count) plus 20 bytes per seeded
    /// class entry (4-byte class tag, 8-byte estimate, 8-byte count).
    /// Unseeded entries do not travel.
    pub fn wire_bytes(&self) -> u64 {
        16 + 20 * self.seeded_entries() as u64
    }
}

/// The estimate-sharing merge rule (`--share-estimates`), shared by the
/// threaded runtime (f64-bits CAS per table cell, like
/// [`class_estimate_update`]) and the DES (plain fields) so the two
/// cannot diverge. Returns the merged `(estimate, samples)`:
///
/// * a remote entry with no samples (or a non-positive estimate) merges
///   as a no-op — an unseeded victim teaches nothing;
/// * an unseeded local entry **adopts** the remote one — the cold-class
///   seeding the digest exists for;
/// * two seeded entries **blend by sample weight**, so ten observed
///   GEMMs outvote one, whichever side observed them.
///
/// Sample counts add, which makes merging commutative and associative
/// up to floating-point rounding — property-tested order-insensitive in
/// this module's tests.
///
/// ```
/// use parsteal::migrate::merge_estimate;
///
/// // Unseeded local adopts; unseeded remote is a no-op.
/// assert_eq!(merge_estimate(0.0, 0, 200.0, 4), (200.0, 4));
/// assert_eq!(merge_estimate(100.0, 2, 0.0, 0), (100.0, 2));
/// // Seeded entries blend by sample weight: (100·2 + 400·6) / 8.
/// assert_eq!(merge_estimate(100.0, 2, 400.0, 6), (325.0, 8));
/// ```
pub fn merge_estimate(
    local_us: f64,
    local_samples: u64,
    remote_us: f64,
    remote_samples: u64,
) -> (f64, u64) {
    let remote_seeded = remote_samples > 0 && remote_us > 0.0;
    let local_seeded = local_samples > 0 && local_us > 0.0;
    match (local_seeded, remote_seeded) {
        (_, false) => (local_us, local_samples),
        (false, true) => (remote_us, remote_samples),
        (true, true) => {
            let n = local_samples + remote_samples;
            let blended = (local_us * local_samples as f64 + remote_us * remote_samples as f64)
                / n as f64;
            (blended, n)
        }
    }
}

/// The node-wide estimate with a remote seed (`--share-estimates`): the
/// local estimate ([`exec_estimate_us`]) whenever any local history
/// exists, else the digest-merged seed from past victims — so a node
/// that has not finished a single task gates on its victims' measured
/// average instead of the optimistic 1 µs cold start.
///
/// ```
/// use parsteal::migrate::exec_estimate_seeded_us;
///
/// // Local history wins…
/// assert_eq!(exec_estimate_seeded_us(false, 0.0, 800.0, 4, 50.0), 200.0);
/// // …a cold node uses the remote seed…
/// assert_eq!(exec_estimate_seeded_us(false, 0.0, 0.0, 0, 50.0), 50.0);
/// // …and with no seed either, the optimistic cold start survives.
/// assert_eq!(exec_estimate_seeded_us(false, 0.0, 0.0, 0, 0.0), 1.0);
/// ```
pub fn exec_estimate_seeded_us(
    use_ewma: bool,
    ewma_us: f64,
    exec_sum_us: f64,
    tasks_done: u64,
    remote_seed_us: f64,
) -> f64 {
    if tasks_done == 0 && !(use_ewma && ewma_us > 0.0) && remote_seed_us > 0.0 {
        remote_seed_us
    } else {
        exec_estimate_us(use_ewma, ewma_us, exec_sum_us, tasks_done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starvation_ready_only_ignores_future() {
        let view = StarvationView {
            ready: 0,
            executing_local_successors: 12,
        };
        assert!(is_starving(ThiefPolicy::ReadyOnly, view));
        assert!(!is_starving(ThiefPolicy::ReadySuccessors, view));
    }

    #[test]
    fn starvation_requires_empty_queue() {
        let view = StarvationView {
            ready: 1,
            executing_local_successors: 0,
        };
        assert!(!is_starving(ThiefPolicy::ReadyOnly, view));
        assert!(!is_starving(ThiefPolicy::ReadySuccessors, view));
    }

    #[test]
    fn allowances() {
        assert_eq!(steal_allowance(VictimPolicy::Half, 40), 20);
        assert_eq!(steal_allowance(VictimPolicy::Half, 1), 0);
        assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 7), 7);
        assert_eq!(steal_allowance(VictimPolicy::Chunk(20), 100), 20);
        assert_eq!(steal_allowance(VictimPolicy::Single, 9), 1);
        assert_eq!(steal_allowance(VictimPolicy::Single, 0), 0);
    }

    #[test]
    fn ewma_seeds_then_smooths() {
        assert_eq!(ewma_update(0.0, 40.0), 40.0);
        assert_eq!(ewma_update(-1.0, 40.0), 40.0, "negative = no history");
        let mut avg = 40.0;
        for _ in 0..64 {
            avg = ewma_update(avg, 10.0);
        }
        assert!((avg - 10.0).abs() < 1.0, "converges to the new regime: {avg}");
    }

    #[test]
    fn waiting_time_formula() {
        // (#ready/#workers + 1) * avg: (40/40 + 1) * 10 = 20
        assert_eq!(waiting_time_us(40, 40, 10.0), 20.0);
        // empty queue still waits one average task
        assert_eq!(waiting_time_us(0, 8, 5.0), 5.0);
    }

    #[test]
    fn per_class_waiting_time_weighs_composition() {
        let mut counts = [0usize; TaskClass::COUNT];
        let mut est = [0.0f64; TaskClass::COUNT];
        counts[TaskClass::Potrf.idx()] = 2;
        est[TaskClass::Potrf.idx()] = 10.0;
        counts[TaskClass::Gemm.idx()] = 6;
        est[TaskClass::Gemm.idx()] = 1000.0;
        // (2·10 + 6·1000) / 2 + 50 = 3060
        assert_eq!(waiting_time_per_class_us(&counts, &est, 2, 50.0), 3060.0);
        // A class without history falls back to the node-wide estimate.
        est[TaskClass::Gemm.idx()] = 0.0;
        // (2·10 + 6·50) / 2 + 50 = 210
        assert_eq!(waiting_time_per_class_us(&counts, &est, 2, 50.0), 210.0);
        // An empty queue still waits one fallback slot.
        assert_eq!(
            waiting_time_per_class_us(&[0; TaskClass::COUNT], &est, 4, 7.0),
            7.0
        );
        // Uniform estimates degenerate to the paper's formula.
        let uniform = [5.0; TaskClass::COUNT];
        assert_eq!(
            waiting_time_per_class_us(&counts, &uniform, 2, 5.0),
            waiting_time_us(8, 2, 5.0)
        );
    }

    #[test]
    fn merge_unseeded_local_adopts_remote() {
        // The cold-class case the digest exists for.
        assert_eq!(merge_estimate(0.0, 0, 250.0, 3), (250.0, 3));
        // A zero-sample local with a stale positive estimate still
        // counts as unseeded (samples are the source of truth).
        assert_eq!(merge_estimate(99.0, 0, 250.0, 3), (250.0, 3));
    }

    #[test]
    fn merge_seeded_entries_blend_by_sample_weight() {
        let (est, n) = merge_estimate(100.0, 1, 200.0, 3);
        assert_eq!(n, 4);
        assert_eq!(est, 175.0, "(100·1 + 200·3)/4");
        // Weights matter: flipping the counts flips the blend.
        let (est, _) = merge_estimate(100.0, 3, 200.0, 1);
        assert_eq!(est, 125.0);
    }

    #[test]
    fn merge_zero_sample_remote_is_noop() {
        assert_eq!(merge_estimate(100.0, 2, 0.0, 0), (100.0, 2));
        // A positive remote estimate with zero samples is distrusted.
        assert_eq!(merge_estimate(100.0, 2, 777.0, 0), (100.0, 2));
        // Both unseeded: still unseeded.
        assert_eq!(merge_estimate(0.0, 0, 0.0, 0), (0.0, 0));
    }

    #[test]
    fn digest_snapshot_caps_sample_weights() {
        let mut class_est = [0.0; TaskClass::COUNT];
        let mut class_n = [0u64; TaskClass::COUNT];
        class_est[TaskClass::Gemm.idx()] = 500.0;
        class_n[TaskClass::Gemm.idx()] = 10_000; // prolific victim
        class_est[TaskClass::Potrf.idx()] = 40.0;
        class_n[TaskClass::Potrf.idx()] = 3; // under the cap: untouched
        let d = EstimateDigest::snapshot(120.0, 9_999, class_est, class_n);
        assert_eq!(d.avg_samples, DIGEST_SAMPLE_CAP);
        assert_eq!(d.class_samples[TaskClass::Gemm.idx()], DIGEST_SAMPLE_CAP);
        assert_eq!(d.class_samples[TaskClass::Potrf.idx()], 3);
        assert_eq!(d.class_est_us[TaskClass::Gemm.idx()], 500.0);
        // A warm thief cannot be clobbered by one heavy digest: 128
        // local samples vs the capped 32 keep the blend local-majority.
        let mut table = [0.0; TaskClass::COUNT];
        let mut samples = [0u64; TaskClass::COUNT];
        table[TaskClass::Gemm.idx()] = 100.0;
        samples[TaskClass::Gemm.idx()] = 128;
        let adoptions = d.merge_into(&mut table, &mut samples);
        assert_eq!(adoptions, 1, "only the POTRF entry is a cold adoption");
        let blended = table[TaskClass::Gemm.idx()];
        assert!(
            blended < 200.0,
            "capped weight must not clobber local history: {blended}"
        );
        assert_eq!(samples[TaskClass::Gemm.idx()], 128 + DIGEST_SAMPLE_CAP);
        assert_eq!(table[TaskClass::Potrf.idx()], 40.0, "cold adoption");
    }

    #[test]
    fn digest_wire_bytes_count_only_seeded_entries() {
        let mut d = EstimateDigest {
            avg_us: 10.0,
            avg_samples: 4,
            class_est_us: [0.0; TaskClass::COUNT],
            class_samples: [0; TaskClass::COUNT],
        };
        assert_eq!(d.seeded_entries(), 0);
        assert_eq!(d.wire_bytes(), 16, "header only");
        d.class_est_us[TaskClass::Gemm.idx()] = 300.0;
        d.class_samples[TaskClass::Gemm.idx()] = 7;
        d.class_est_us[TaskClass::Potrf.idx()] = 50.0;
        d.class_samples[TaskClass::Potrf.idx()] = 1;
        // A zero-sample entry with a positive estimate does not travel.
        d.class_est_us[TaskClass::Trsm.idx()] = 9.0;
        assert_eq!(d.seeded_entries(), 2);
        assert_eq!(d.wire_bytes(), 16 + 2 * 20);
    }

    #[test]
    fn seeded_estimate_prefers_local_history() {
        // Local running mean beats the seed.
        assert_eq!(exec_estimate_seeded_us(false, 0.0, 400.0, 2, 33.0), 200.0);
        // Local EWMA beats the seed.
        assert_eq!(exec_estimate_seeded_us(true, 55.0, 0.0, 0, 33.0), 55.0);
        // Cold node: seed replaces the optimistic 1 µs.
        assert_eq!(exec_estimate_seeded_us(true, 0.0, 0.0, 0, 33.0), 33.0);
        assert_eq!(exec_estimate_seeded_us(false, 0.0, 0.0, 0, 0.0), 1.0);
    }

    #[test]
    fn class_estimate_update_is_the_shared_ewma() {
        assert_eq!(class_estimate_update(0.0, 40.0), 40.0, "first sample seeds");
        assert_eq!(class_estimate_update(40.0, 40.0), 40.0);
        assert_eq!(class_estimate_update(100.0, 200.0), ewma_update(100.0, 200.0));
    }

    #[test]
    fn builder_setters_equal_exhaustive_literal() {
        // The one place a full MigrateConfig literal is allowed to live:
        // the builders' own equivalence check.
        let built = MigrateConfig::default()
            .with_enabled(false)
            .with_thief(ThiefPolicy::ReadyOnly)
            .with_victim(VictimPolicy::Chunk(9))
            .with_use_waiting_time(false)
            .with_poll_interval_us(55.0)
            .with_max_inflight(3)
            .with_migrate_overhead_us(40.0)
            .with_exec_ewma(true)
            .with_exec_per_class(true)
            .with_share_estimates(true)
            .with_victim_select(VictimSelect::Targeted);
        let literal = MigrateConfig {
            enabled: false,
            thief: ThiefPolicy::ReadyOnly,
            victim: VictimPolicy::Chunk(9),
            use_waiting_time: false,
            poll_interval_us: 55.0,
            max_inflight: 3,
            migrate_overhead_us: 40.0,
            exec_ewma: true,
            exec_per_class: true,
            share_estimates: true,
            victim_select: VictimSelect::Targeted,
        };
        assert_eq!(format!("{built:?}"), format!("{literal:?}"));
    }

    #[test]
    fn policy_parsing() {
        assert_eq!("half".parse::<VictimPolicy>().unwrap(), VictimPolicy::Half);
        assert_eq!(
            "chunk20".parse::<VictimPolicy>().unwrap(),
            VictimPolicy::Chunk(20)
        );
        assert_eq!("chunk".parse::<VictimPolicy>().unwrap(), VictimPolicy::Chunk(20));
        assert_eq!("single".parse::<VictimPolicy>().unwrap(), VictimPolicy::Single);
        assert!("quarter".parse::<VictimPolicy>().is_err());
        assert_eq!(
            "ready-successors".parse::<ThiefPolicy>().unwrap(),
            ThiefPolicy::ReadySuccessors
        );
        assert!("eager".parse::<ThiefPolicy>().is_err());
    }
}
