//! Run reports and the paper's derived metrics.
//!
//! Both execution backends (the real threaded runtime and the DES)
//! produce a [`RunReport`]; the figure harness post-processes reports
//! into the quantities the paper plots: the work-stealing potential
//! `E^b` (eq. 1–3), steal success percentages (Fig. 8), and the
//! ready-at-arrival distribution (Fig. 3).

use crate::comm::LinkModel;
use crate::dataflow::task::TaskClass;
use crate::migrate::StealStats;
use crate::sched::{BatchSite, SchedStats};
use crate::topology::{TIER_COUNT, TIER_NAMES};
use crate::util::json::Json;

/// One ready-queue observation, taken whenever a worker completed a
/// successful `select` (exactly the paper's §4.2 polling rule).
#[derive(Clone, Copy, Debug)]
pub struct PollSample {
    pub t_us: f64,
    pub ready: u32,
}

/// Per-node outcome of a run.
#[derive(Clone, Debug, Default)]
pub struct NodeReport {
    pub tasks_executed: u64,
    /// Total busy worker time (µs).
    pub busy_us: f64,
    /// Running mean execution time at end of run (µs).
    pub avg_exec_us: f64,
    /// Per-class execution-time estimates at end of run (µs, indexed by
    /// [`TaskClass`] discriminant; 0 = the class never completed a task
    /// or neither `--exec-per-class` nor `--share-estimates` was on).
    pub class_est_us: [f64; TaskClass::COUNT],
    /// Steal-reply estimate digests merged into this node's tables
    /// (`--share-estimates`): exactly one per successful steal by this
    /// node when the flag is on, 0 otherwise.
    pub digest_merges: u64,
    /// Class entries this node adopted cold from a digest — the thief
    /// had no local history for the class, so the victim's estimate
    /// seeded it outright.
    pub digest_class_adoptions: u64,
    /// Non-empty activation ready sets delivered through the batched
    /// path — asserted equal to the scheduler's activation-site batch
    /// counter (exactly one batched insert per ready set).
    pub activation_ready_batches: u64,
    pub steal: StealStats,
    /// Thief-side reply outcomes by victim (index = victim node id):
    /// granted replies. Recorded for every reply regardless of
    /// `--victim-select`; empty when this report was built by hand or
    /// the run had one node. Per node, `victim_grants.iter().sum()`
    /// equals `steal.successful_steals`.
    pub victim_grants: Vec<u64>,
    /// Waiting-time-gate denials by victim (same indexing).
    pub victim_wt_denials: Vec<u64>,
    /// Empty-queue denials by victim (same indexing).
    pub victim_empties: Vec<u64>,
    /// Abandoned (timed-out) requests by victim (same indexing; only
    /// nonzero under `--faults`, where the fabric may eat a request or
    /// reply and the thief's watchdog gives up on it).
    pub victim_timeouts: Vec<u64>,
    /// Quarantine records by victim (same indexing): at most one per
    /// victim — the permanent verdict a thief passes on a crashed peer
    /// (membership update) or on one that never answered within the
    /// whole retry budget. A quarantined victim is never picked again.
    pub victim_quarantined: Vec<u64>,
    /// Thief-side steal requests this node sent, by topology tier of
    /// the victim (0 = socket, 1 = rack, 2 = cluster; see
    /// [`crate::topology::TIER_NAMES`]). On a flat topology every
    /// remote victim is cluster-distance, so only index 2 is nonzero.
    /// Sums to `steal.requests_sent`.
    pub tier_steal_requests: [u64; TIER_COUNT],
    /// Granted replies this node received, by victim tier. Sums to
    /// `steal.successful_steals`.
    pub tier_steal_grants: [u64; TIER_COUNT],
    /// Stolen-task payload bytes that crossed each tier toward this
    /// node (granted-reply wire bytes, by victim tier).
    pub tier_steal_bytes: [u64; TIER_COUNT],
    /// Steal requests this node abandoned after the watchdog deadline
    /// (`--faults` only; reliable fabrics answer every request).
    pub steal_timeouts: u64,
    /// Abandoned requests re-issued within the retry budget.
    pub steal_retries: u64,
    /// Transfer-ledger entries this node (as victim) reclaimed on a
    /// thief's nack — granted tasks that came home and re-entered the
    /// queue instead of being lost with their dropped reply.
    pub ledger_reclaims: u64,
    /// Duplicate or late steal replies suppressed by request id — each
    /// one a double-execution the exactly-once protocol prevented.
    pub dup_replies_suppressed: u64,
    /// End-of-run scheduler counters for this node's queue: batched-
    /// insert accounting, gate-feedback events and (sharded) the final
    /// adaptive spill watermark.
    pub sched: SchedStats,
    /// Select-time ready-queue polls (drives Fig. 1).
    pub polls: Vec<PollSample>,
    /// Ready-queue length observed when each stolen task arrived
    /// (drives Fig. 3).
    pub arrival_ready: Vec<PollSample>,
}

/// Crash-recovery telemetry, identical across both runtimes: the DES
/// fills it from its omniscient Crash/Recover events, the threaded
/// runtime from the leader's heartbeat detector and recovery sweep.
/// All-zero (the `Default`) on fault-free runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Nodes the failure detector suspected (and, with injected
    /// crash-stop faults only, confirmed — false positives are zero by
    /// construction of the suspicion threshold).
    pub nodes_suspected: u64,
    /// Nodes actually crashed by the fault plan.
    pub nodes_crashed: u64,
    /// Tasks re-homed onto survivors by lineage recovery: the dead
    /// node's ready queue, executing set, unabsorbed transfer-ledger
    /// grants, and partially-activated tasks whose lineage replayed.
    pub tasks_recovered: u64,
    /// Safra ring repairs (token splices) performed.
    pub ring_repairs: u64,
    /// Detection latency (µs): crash instant to the recovery sweep. In
    /// the DES this is exactly the modeled suspicion threshold; in the
    /// threaded runtime it is the measured wall-clock gap.
    pub detect_latency_us: f64,
}

/// Outcome of one run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub workload: String,
    pub makespan_us: f64,
    pub nodes: Vec<NodeReport>,
    pub total_tasks: u64,
    pub workers_per_node: usize,
    pub link: LinkModel,
    /// DES only: events processed (engine throughput accounting).
    pub events: u64,
    /// DES only: Deliver (wire message) events — the quantity activation
    /// batching shrinks.
    pub deliver_events: u64,
    /// Steal-class messages the fault plan dropped (`--faults`; in the
    /// threaded runtime these are delivered marked-dropped to balance
    /// the Safra accounting, but the payload is discarded).
    pub faults_dropped: u64,
    /// Extra steal-class message copies the fault plan injected.
    pub faults_duplicated: u64,
    /// Crash-stop detection/repair/recovery counters (`--faults
    /// crash-*`; all-zero otherwise).
    pub recovery: RecoveryStats,
}

impl RunReport {
    pub fn total_steals(&self) -> StealStats {
        let mut s = StealStats::default();
        for n in &self.nodes {
            s.merge(&n.steal);
        }
        s
    }

    pub fn tasks_total_executed(&self) -> u64 {
        self.nodes.iter().map(|n| n.tasks_executed).sum()
    }

    /// Workload imbalance / potential-for-stealing series (§4.2).
    ///
    /// Splits `[0, makespan)` into intervals of `interval_us` and
    /// computes, per interval `b`:
    ///
    /// ```text
    /// w_i^b = mean_j(o_j^b) / max_j(o_j^b)      per-node normalized load
    /// I^b   = max_i(w_i^b) − mean_i(w_i^b)      imbalance
    /// E^b   = I^b · P                           potential
    /// ```
    ///
    /// A node with no polls in an interval contributes `w_i = 0`
    /// (no successful select ⇒ nothing to run ⇒ zero load).
    pub fn potential_series(&self, interval_us: f64) -> Vec<f64> {
        let p = self.nodes.len();
        if p == 0 || self.makespan_us <= 0.0 {
            return Vec::new();
        }
        let buckets = ((self.makespan_us / interval_us).ceil() as usize).max(1);
        let mut series = Vec::with_capacity(buckets);
        for b in 0..buckets {
            let lo = b as f64 * interval_us;
            let hi = lo + interval_us;
            let mut w = Vec::with_capacity(p);
            for node in &self.nodes {
                let polled: Vec<f64> = node
                    .polls
                    .iter()
                    .filter(|s| s.t_us >= lo && s.t_us < hi)
                    .map(|s| s.ready as f64)
                    .collect();
                if polled.is_empty() {
                    w.push(0.0);
                    continue;
                }
                let max = polled.iter().cloned().fold(0.0, f64::max);
                let mean = polled.iter().sum::<f64>() / polled.len() as f64;
                w.push(if max > 0.0 { mean / max } else { 0.0 });
            }
            let wmax = w.iter().cloned().fold(0.0, f64::max);
            let wmean = w.iter().sum::<f64>() / p as f64;
            series.push((wmax - wmean) * p as f64);
        }
        series
    }

    /// All ready-at-arrival samples pooled across nodes (Fig. 3).
    pub fn arrival_ready_all(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .nodes
            .iter()
            .flat_map(|n| n.arrival_ready.iter().map(|s| s.ready))
            .collect();
        v.sort_unstable();
        v
    }

    /// Per-call-site batch totals across all nodes, ordered as
    /// [`BatchSite::ALL`].
    pub fn batch_site_totals(&self) -> [(BatchSite, u64, u64); BatchSite::COUNT] {
        std::array::from_fn(|i| {
            let site = BatchSite::ALL[i];
            let batches: u64 = self.nodes.iter().map(|n| n.sched.site(site).batches).sum();
            let saved: u64 = self
                .nodes
                .iter()
                .map(|n| n.sched.site(site).saved_locks())
                .sum();
            (site, batches, saved)
        })
    }

    /// End-of-run per-class execution estimates, pooled across nodes
    /// (max over nodes — a snapshot, not a mean; 0 = no samples).
    pub fn class_est_us_max(&self) -> [f64; TaskClass::COUNT] {
        std::array::from_fn(|c| {
            self.nodes
                .iter()
                .map(|n| n.class_est_us[c])
                .fold(0.0, f64::max)
        })
    }

    /// Total steal-reply digests merged across nodes
    /// (`--share-estimates`).
    pub fn digest_merges_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.digest_merges).sum()
    }

    /// Total cold-class adoptions across nodes (`--share-estimates`).
    pub fn digest_class_adoptions_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.digest_class_adoptions).sum()
    }

    /// Per-victim reply outcomes summed across all thieves, indexed by
    /// victim node id: `(grants, wt_denials, empties, timeouts,
    /// quarantines)` — how often each node was successfully robbed,
    /// turned thieves away, (under `--faults`) left them hanging past
    /// the watchdog deadline, or was written off permanently (crash
    /// declarations and exhausted retry budgets). Missing per-node
    /// tables (hand-built reports) count zero.
    pub fn victim_totals(&self) -> Vec<(u64, u64, u64, u64, u64)> {
        let p = self.nodes.len();
        let mut out = vec![(0u64, 0u64, 0u64, 0u64, 0u64); p];
        for n in &self.nodes {
            for (v, slot) in out.iter_mut().enumerate() {
                slot.0 += n.victim_grants.get(v).copied().unwrap_or(0);
                slot.1 += n.victim_wt_denials.get(v).copied().unwrap_or(0);
                slot.2 += n.victim_empties.get(v).copied().unwrap_or(0);
                slot.3 += n.victim_timeouts.get(v).copied().unwrap_or(0);
                slot.4 += n.victim_quarantined.get(v).copied().unwrap_or(0);
            }
        }
        out
    }

    /// Total abandoned (timed-out) steal requests across nodes.
    pub fn steal_timeouts_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.steal_timeouts).sum()
    }

    /// Total watchdog-driven retries across nodes.
    pub fn steal_retries_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.steal_retries).sum()
    }

    /// Total nack-reclaimed transfer-ledger entries across nodes.
    pub fn ledger_reclaims_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.ledger_reclaims).sum()
    }

    /// Total duplicate replies suppressed across nodes.
    pub fn dup_replies_suppressed_total(&self) -> u64 {
        self.nodes.iter().map(|n| n.dup_replies_suppressed).sum()
    }

    /// Per-tier steal traffic summed across thieves: `(requests,
    /// grants, bytes)` indexed by topology tier
    /// ([`crate::topology::TIER_NAMES`]).
    pub fn tier_steal_totals(&self) -> [(u64, u64, u64); TIER_COUNT] {
        let mut out = [(0u64, 0u64, 0u64); TIER_COUNT];
        for n in &self.nodes {
            for t in 0..TIER_COUNT {
                out[t].0 += n.tier_steal_requests[t];
                out[t].1 += n.tier_steal_grants[t];
                out[t].2 += n.tier_steal_bytes[t];
            }
        }
        out
    }

    /// Steal requests that left their socket (rack + cluster tiers) —
    /// the traffic hierarchical steal domains exist to shrink.
    pub fn cross_tier_steal_requests(&self) -> u64 {
        let tiers = self.tier_steal_totals();
        tiers[1].0 + tiers[2].0
    }

    /// Stolen-payload bytes that left their socket.
    pub fn cross_tier_steal_bytes(&self) -> u64 {
        let tiers = self.tier_steal_totals();
        tiers[1].2 + tiers[2].2
    }

    pub fn to_json(&self) -> Json {
        let steals = self.total_steals();
        let victims = self.victim_totals();
        let tiers = self.tier_steal_totals();
        let batch_inserts: u64 = self.nodes.iter().map(|n| n.sched.batch_inserts()).sum();
        let saved_locks: u64 = self.nodes.iter().map(|n| n.sched.batch_saved_locks()).sum();
        let denials_fed: u64 = self.nodes.iter().map(|n| n.sched.feedback_wt_denials).sum();
        let fallback_walks: u64 = self.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
        let payload_resets: u64 = self.nodes.iter().map(|n| n.sched.min_payload_resets).sum();
        let watermark_max = self
            .nodes
            .iter()
            .map(|n| n.sched.watermark)
            .max()
            .unwrap_or(0);
        let site_totals = self.batch_site_totals();
        let class_est = self.class_est_us_max();
        Json::obj(vec![
            ("workload", Json::Str(self.workload.clone())),
            ("makespan_us", Json::Num(self.makespan_us)),
            ("total_tasks", Json::Num(self.total_tasks as f64)),
            ("tasks_executed", Json::Num(self.tasks_total_executed() as f64)),
            ("nodes", Json::Num(self.nodes.len() as f64)),
            ("workers_per_node", Json::Num(self.workers_per_node as f64)),
            ("events", Json::Num(self.events as f64)),
            ("deliver_events", Json::Num(self.deliver_events as f64)),
            ("faults_dropped", Json::Num(self.faults_dropped as f64)),
            (
                "faults_duplicated",
                Json::Num(self.faults_duplicated as f64),
            ),
            (
                "steal_timeouts",
                Json::Num(self.steal_timeouts_total() as f64),
            ),
            (
                "steal_retries",
                Json::Num(self.steal_retries_total() as f64),
            ),
            (
                "ledger_reclaims",
                Json::Num(self.ledger_reclaims_total() as f64),
            ),
            (
                "dup_replies_suppressed",
                Json::Num(self.dup_replies_suppressed_total() as f64),
            ),
            (
                "nodes_suspected",
                Json::Num(self.recovery.nodes_suspected as f64),
            ),
            (
                "nodes_crashed",
                Json::Num(self.recovery.nodes_crashed as f64),
            ),
            (
                "tasks_recovered",
                Json::Num(self.recovery.tasks_recovered as f64),
            ),
            ("ring_repairs", Json::Num(self.recovery.ring_repairs as f64)),
            (
                "detect_latency_us",
                Json::Num(self.recovery.detect_latency_us),
            ),
            ("steal_requests", Json::Num(steals.requests_sent as f64)),
            ("steal_successes", Json::Num(steals.successful_steals as f64)),
            ("steal_success_pct", Json::Num(steals.success_pct())),
            ("tasks_migrated", Json::Num(steals.tasks_migrated as f64)),
            (
                "waiting_time_denials",
                Json::Num(steals.waiting_time_denials as f64),
            ),
            ("sched_batch_inserts", Json::Num(batch_inserts as f64)),
            ("sched_batch_saved_locks", Json::Num(saved_locks as f64)),
            (
                "sched_batches_by_site",
                Json::obj(
                    site_totals
                        .iter()
                        .map(|&(site, batches, saved)| {
                            (
                                site.label(),
                                Json::obj(vec![
                                    ("batches", Json::Num(batches as f64)),
                                    ("saved_locks", Json::Num(saved as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            ("sched_gate_denials_fed", Json::Num(denials_fed as f64)),
            ("sched_fallback_walks", Json::Num(fallback_walks as f64)),
            ("sched_watermark_max", Json::Num(watermark_max as f64)),
            (
                "sched_min_payload_resets",
                Json::Num(payload_resets as f64),
            ),
            (
                "digest_merges",
                Json::Num(self.digest_merges_total() as f64),
            ),
            (
                "digest_class_adoptions",
                Json::Num(self.digest_class_adoptions_total() as f64),
            ),
            (
                "digest_merges_per_node",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| Json::Num(n.digest_merges as f64))
                        .collect(),
                ),
            ),
            (
                "victim_grants",
                Json::Arr(
                    victims
                        .iter()
                        .map(|&(g, _, _, _, _)| Json::Num(g as f64))
                        .collect(),
                ),
            ),
            (
                "victim_denials",
                Json::Arr(
                    victims
                        .iter()
                        .map(|&(_, d, e, _, _)| Json::Num((d + e) as f64))
                        .collect(),
                ),
            ),
            (
                "victim_timeouts",
                Json::Arr(
                    victims
                        .iter()
                        .map(|&(_, _, _, t, _)| Json::Num(t as f64))
                        .collect(),
                ),
            ),
            (
                "victim_quarantined",
                Json::Arr(
                    victims
                        .iter()
                        .map(|&(_, _, _, _, q)| Json::Num(q as f64))
                        .collect(),
                ),
            ),
            (
                "class_est_us",
                Json::obj(
                    TaskClass::ALL
                        .iter()
                        .map(|c| (c.name(), Json::Num(class_est[c.idx()])))
                        .collect(),
                ),
            ),
            (
                "steal_tier_requests",
                Json::obj(
                    TIER_NAMES
                        .iter()
                        .enumerate()
                        .map(|(t, name)| (*name, Json::Num(tiers[t].0 as f64)))
                        .collect(),
                ),
            ),
            (
                "steal_tier_grants",
                Json::obj(
                    TIER_NAMES
                        .iter()
                        .enumerate()
                        .map(|(t, name)| (*name, Json::Num(tiers[t].1 as f64)))
                        .collect(),
                ),
            ),
            (
                "steal_tier_bytes",
                Json::obj(
                    TIER_NAMES
                        .iter()
                        .enumerate()
                        .map(|(t, name)| (*name, Json::Num(tiers[t].2 as f64)))
                        .collect(),
                ),
            ),
            (
                "cross_tier_steal_requests",
                Json::Num(self.cross_tier_steal_requests() as f64),
            ),
            (
                "cross_tier_steal_bytes",
                Json::Num(self.cross_tier_steal_bytes() as f64),
            ),
            (
                "per_node_tasks",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| Json::Num(n.tasks_executed as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node_with_polls(polls: &[(f64, u32)]) -> NodeReport {
        NodeReport {
            polls: polls
                .iter()
                .map(|&(t_us, ready)| PollSample { t_us, ready })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn balanced_load_has_zero_potential() {
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 100.0,
            nodes: vec![
                node_with_polls(&[(10.0, 4), (20.0, 4)]),
                node_with_polls(&[(10.0, 7), (20.0, 7)]),
            ],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        // each node's mean/max = 1 -> I = 0
        let e = r.potential_series(100.0);
        assert_eq!(e.len(), 1);
        assert!(e[0].abs() < 1e-12);
    }

    #[test]
    fn starving_node_raises_potential() {
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 100.0,
            nodes: vec![
                node_with_polls(&[(10.0, 4), (20.0, 4)]), // w=1
                node_with_polls(&[]),                      // w=0 (starving)
            ],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        let e = r.potential_series(100.0);
        // w = [1, 0]: I = 1 - 0.5 = 0.5; E = I*P = 1.0
        assert!((e[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn interval_bucketing() {
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 30.0,
            nodes: vec![node_with_polls(&[(5.0, 1), (15.0, 1), (25.0, 1)])],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        assert_eq!(r.potential_series(10.0).len(), 3);
    }

    #[test]
    fn victim_totals_sum_across_thieves() {
        let mut n0 = NodeReport::default();
        n0.victim_grants = vec![0, 3, 1];
        n0.victim_wt_denials = vec![0, 2, 0];
        n0.victim_empties = vec![0, 0, 4];
        n0.victim_timeouts = vec![0, 1, 0];
        n0.victim_quarantined = vec![0, 0, 1];
        let n1 = NodeReport::default(); // hand-built: empty tables = zeros
        let mut n2 = NodeReport::default();
        n2.victim_grants = vec![5, 0, 0];
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 1.0,
            nodes: vec![n0, n1, n2],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        assert_eq!(
            r.victim_totals(),
            vec![(5, 0, 0, 0, 0), (3, 2, 0, 1, 0), (1, 0, 4, 0, 1)],
            "summed across thieves, indexed by victim"
        );
    }

    #[test]
    fn tier_totals_sum_across_thieves() {
        let mut n0 = NodeReport::default();
        n0.tier_steal_requests = [4, 2, 1];
        n0.tier_steal_grants = [3, 1, 0];
        n0.tier_steal_bytes = [300, 100, 0];
        let mut n1 = NodeReport::default();
        n1.tier_steal_requests = [0, 0, 6];
        n1.tier_steal_bytes = [0, 0, 640];
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 1.0,
            nodes: vec![n0, n1],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        assert_eq!(
            r.tier_steal_totals(),
            [(4, 3, 300), (2, 1, 100), (7, 0, 640)]
        );
        assert_eq!(r.cross_tier_steal_requests(), 9, "rack + cluster");
        assert_eq!(r.cross_tier_steal_bytes(), 740);
    }

    #[test]
    fn arrival_pool_sorted() {
        let mut n1 = NodeReport::default();
        n1.arrival_ready.push(PollSample { t_us: 1.0, ready: 9 });
        let mut n2 = NodeReport::default();
        n2.arrival_ready.push(PollSample { t_us: 2.0, ready: 3 });
        let r = RunReport {
            workload: "t".into(),
            makespan_us: 1.0,
            nodes: vec![n1, n2],
            total_tasks: 0,
            workers_per_node: 1,
            link: LinkModel::ideal(),
            events: 0,
            deliver_events: 0,
            faults_dropped: 0,
            faults_duplicated: 0,
            recovery: RecoveryStats::default(),
        };
        assert_eq!(r.arrival_ready_all(), vec![3, 9]);
    }
}
