//! The real (threaded) runtime: in-process multi-node execution.
//!
//! Each "node" (one MPI rank in the paper's deployment) is a runtime
//! domain with its own scheduler queue, activation tracker, worker
//! threads, a comm thread draining its mailbox, and — when stealing is
//! enabled — the migrate thread of §3. Cross-node traffic goes through
//! [`crate::comm::Network`] (activations, the steal protocol, Safra
//! termination tokens, shutdown).
//!
//! Task bodies are supplied by a [`TaskExecutor`]: the PJRT-backed
//! executor runs the AOT-compiled tile kernels (the production path);
//! synthetic executors busy-spin per the cost model (protocol tests
//! without XLA).

pub mod cluster;
pub mod executor;

pub use cluster::{Cluster, ClusterConfig};
pub use executor::{NullExecutor, SpinExecutor, TaskExecutor};
