//! Task-body execution backends for the real runtime.

use std::time::{Duration, Instant};

use crate::dataflow::task::{NodeId, TaskDesc};
use crate::sim::CostModel;

/// Executes one task body on a worker thread. Implementations must be
/// shareable across all workers of all nodes (`Send + Sync`): per-tile
/// locking is the implementation's concern.
pub trait TaskExecutor: Send + Sync {
    /// Run the task to completion (blocking the worker, like any real
    /// task body).
    fn execute(&self, node: NodeId, task: TaskDesc);

    /// Human-readable backend name for reports.
    fn name(&self) -> &'static str {
        "executor"
    }
}

/// Busy-spins for the cost-model duration of the task: exercises every
/// protocol path with realistic timing but no numerics. `work_units`
/// must be supplied per task by the graph, so the executor holds a
/// closure resolving them.
pub struct SpinExecutor<F: Fn(TaskDesc) -> f64 + Send + Sync> {
    cost: CostModel,
    tile_size: u32,
    work_units: F,
    /// Scale factor on durations (shrink for fast tests).
    pub time_scale: f64,
}

impl<F: Fn(TaskDesc) -> f64 + Send + Sync> SpinExecutor<F> {
    pub fn new(cost: CostModel, tile_size: u32, work_units: F) -> Self {
        SpinExecutor {
            cost,
            tile_size,
            work_units,
            time_scale: 1.0,
        }
    }

    pub fn with_time_scale(mut self, s: f64) -> Self {
        self.time_scale = s;
        self
    }
}

impl<F: Fn(TaskDesc) -> f64 + Send + Sync> TaskExecutor for SpinExecutor<F> {
    fn execute(&self, _node: NodeId, task: TaskDesc) {
        let us = self
            .cost
            .exec_us(task.class, self.tile_size, (self.work_units)(task))
            * self.time_scale;
        let dur = Duration::from_nanos((us * 1e3) as u64);
        // Busy-wait (not sleep): a worker executing a task occupies its
        // core exactly like a real tile kernel would.
        let t0 = Instant::now();
        while t0.elapsed() < dur {
            std::hint::spin_loop();
        }
    }

    fn name(&self) -> &'static str {
        "spin"
    }
}

/// No-op executor (pure protocol tests: termination, steal bookkeeping).
pub struct NullExecutor;

impl TaskExecutor for NullExecutor {
    fn execute(&self, _node: NodeId, _task: TaskDesc) {}

    fn name(&self) -> &'static str {
        "null"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::task::TaskClass;

    #[test]
    fn spin_executor_takes_time() {
        let ex = SpinExecutor::new(CostModel::default_calibrated(), 16, |_| 1.0);
        let t = TaskDesc::indexed(TaskClass::Gemm, 1, 0, 0);
        let t0 = Instant::now();
        ex.execute(NodeId(0), t);
        // GEMM(16) ≈ 12.9 µs under the default model
        assert!(t0.elapsed() >= Duration::from_micros(10));
    }

    #[test]
    fn null_is_instant() {
        let t = TaskDesc::indexed(TaskClass::Gemm, 1, 0, 0);
        let t0 = Instant::now();
        NullExecutor.execute(NodeId(0), t);
        assert!(t0.elapsed() < Duration::from_millis(5));
    }
}
