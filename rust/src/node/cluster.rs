//! Multi-node threaded runtime: workers + comm thread + migrate thread
//! per node, Safra termination, steal protocol over the message fabric.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::comm::{LinkModel, Msg, Network, NodeMailbox};
use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;
use crate::dataflow::ActivationTracker;
use crate::faults::{FaultMark, FaultPlan};
use crate::metrics::{NodeReport, PollSample, RunReport};
use crate::migrate::{
    class_estimate_update, classify_reply, ewma_update, exec_estimate_seeded_us, is_starving,
    merge_estimate, protocol::decide_steal, steal_req_id, steal_timeout_us, EstimateDigest,
    ExecSnapshot, MigrateConfig, StarvationView, StealStats, VictimOutcome, VictimSelect,
    VictimSelector, THIEF_RETRY_BUDGET,
};
use crate::sched::{BatchSite, POOL_FLOOR, SchedBackend, Scheduler, StealOutcome, TaskMeta};
use crate::term::{SafraAction, SafraState};
use crate::util::rng::thief_rng;

/// Real-mode run configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub workers_per_node: usize,
    pub link: LinkModel,
    pub migrate: MigrateConfig,
    pub seed: u64,
    /// Record Fig.1/Fig.3 poll samples.
    pub record_polls: bool,
    /// Scheduler backend per node (`--sched central|sharded|workassist`).
    pub sched: SchedBackend,
    /// Coalesce same-destination successor activations into one
    /// `ActivateBatch` message (`--batch-activations`; off reproduces
    /// the per-edge protocol for ablations). Also routes each local
    /// activation ready set through one batched queue insert.
    pub batch_activations: bool,
    /// Sharded steal-pool floor (`--pool-floor`; see
    /// [`crate::sched::POOL_FLOOR`]).
    pub pool_floor: usize,
    /// Fault-injection plan (`--faults`) applied by the message fabric
    /// to steal traffic, plus the self-healing protocol it activates
    /// (request timeouts, retries, the victim-side transfer ledger).
    /// Disabled by default — the fabric and protocol are then
    /// byte-identical to the fault-free runtime.
    pub faults: FaultPlan,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers_per_node: 4,
            link: LinkModel::ideal(),
            migrate: MigrateConfig::default(),
            seed: 1,
            record_polls: true,
            sched: SchedBackend::Central,
            batch_activations: true,
            pool_floor: POOL_FLOOR,
            faults: FaultPlan::default(),
        }
    }
}

/// One outstanding thief-side steal request. The map is maintained even
/// with `--faults` off: matching replies to requests is what lets the
/// shutdown drain reclaim the inflight slot of a reply that never got
/// processed (the pre-PR 7 `inflight_steals` leak).
#[derive(Clone, Copy, Debug)]
struct PendingSteal {
    victim: NodeId,
    sent_at: Instant,
    /// Retry number (0 = first try) — indexes the capped exponential
    /// backoff in [`steal_timeout_us`].
    attempt: u32,
}

/// Thief-side request bookkeeping, one mutex for both maps: the
/// comm thread's resolve (check `resolved`, remove `pending`, record
/// the outcome) and the migrate thread's timeout claim (remove
/// `pending`, mark Abandoned) must each be atomic against the other,
/// or a reply racing a timeout could both enqueue the tasks *and* nack
/// the victim into reclaiming them — a double execution.
#[derive(Default)]
struct StealBook {
    pending: HashMap<u64, PendingSteal>,
    resolved: HashMap<u64, StealResolution>,
}

/// Terminal state of a thief-side request (`--faults` only), kept so a
/// late or fabric-duplicated reply is suppressed instead of processed
/// twice, and so the victim's retransmits can be re-answered with the
/// ack they are waiting for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StealResolution {
    /// A granted reply was accepted and its tasks enqueued; the ack
    /// went (or is being re-sent) to the victim.
    AckedGrant,
    /// A denial was processed — nothing to ack (the victim keeps no
    /// ledger entry for denials).
    AckedDenial,
    /// The thief timed out and nacked; any reply that still arrives is
    /// discarded and re-nacked so the victim reclaims exactly once.
    Abandoned,
}

/// Victim-side record of a granted-but-unacknowledged transfer
/// (`--faults` only). The tasks live here — off the queue, not yet
/// owned by the thief — until the thief's [`Msg::TransferAck`] retires
/// the entry (accepted) or reclaims it (nack → batch reinsert), so a
/// dropped reply can never lose tasks and a duplicated one can never
/// double them.
struct LedgerEntry {
    thief: NodeId,
    /// The granted tasks, for the nack-reclaim reinsert.
    tasks: Vec<TaskDesc>,
    /// The exact reply message sent, retransmitted verbatim on
    /// ack-timeout and on fabric-duplicated requests.
    reply: Msg,
    sent_at: Instant,
    /// Retransmit number — backoff index, uncapped count (the victim
    /// never unilaterally reclaims; only a nack reclaims).
    attempt: u32,
}

/// Shared state of one runtime domain.
struct NodeState {
    id: NodeId,
    /// The ready queue; backends do their own locking (the sharded one
    /// is the whole point — see [`crate::sched`]).
    queue: Box<dyn Scheduler>,
    /// Pairs with `queue_cv` for idle-worker parking: the queue locks
    /// internally now, so the wait needs its own mutex.
    idle: Mutex<()>,
    queue_cv: Condvar,
    /// Workers currently parked (or about to park) on `queue_cv`.
    /// `enqueue` skips the lock+notify entirely while this is zero, so
    /// the insert hot path stays lock-free node-wide under load.
    parked: AtomicUsize,
    tracker: Mutex<ActivationTracker>,
    executing_count: AtomicUsize,
    /// Local successors of tasks currently executing — the "future
    /// tasks" of the thief policy, maintained incrementally (added at
    /// execution start, subtracted at finish) so the starvation poll is
    /// an O(1) read instead of a walk over the executing set.
    executing_local_succ: AtomicUsize,
    tasks_done: AtomicU64,
    exec_sum_ns: AtomicU64,
    /// EWMA of observed execution times (µs), stored as `f64` bits —
    /// updated at task finish when `MigrateConfig::exec_ewma` is on,
    /// read by the victim-side waiting-time gate. 0 bits = 0.0 = no
    /// history yet.
    exec_ewma_us_bits: AtomicU64,
    /// Per-class execution-time estimates (µs as `f64` bits), updated
    /// at task finish when [`MigrateConfig::track_per_class`] via the
    /// shared [`class_estimate_update`] rule — the threaded twin of
    /// the DES's plain-field table. 0 bits = no history for the class.
    /// Under `--share-estimates`, steal-reply digests merge into the
    /// same cells through [`merge_estimate`] (CAS over the f64 bits).
    class_est_us_bits: [AtomicU64; TaskClass::COUNT],
    /// Completed-task counts behind each class estimate — the merge
    /// weights for `--share-estimates` (local finishes count 1 each,
    /// merged digests add the victim's sample count).
    class_samples: [AtomicU64; TaskClass::COUNT],
    /// Digest-merged node-wide estimate from past victims (µs as `f64`
    /// bits) and its sample weight: the cold-start fallback the gate
    /// uses before this node has finished a single task
    /// ([`exec_estimate_seeded_us`]).
    remote_avg_us_bits: AtomicU64,
    remote_avg_samples: AtomicU64,
    /// Steal-reply digests merged into this node's tables.
    digest_merges: AtomicU64,
    /// Class entries adopted cold from a digest (no local history).
    digest_class_adoptions: AtomicU64,
    /// Non-empty activation ready sets delivered through the batched
    /// path — the runtime-layer count the scheduler's activation-site
    /// batch counter is asserted against (exactly one batched insert
    /// per non-empty ready set).
    activation_ready_batches: AtomicU64,
    busy_ns: AtomicU64,
    steal: Mutex<StealStats>,
    /// Thief-side per-victim reply outcomes (index = victim node):
    /// granted / waiting-time-denied / empty, recorded for every reply
    /// regardless of `--victim-select` so the targeted-vs-uniform
    /// ablation is observable without a debugger.
    victim_grants: Vec<AtomicU64>,
    victim_wt_denials: Vec<AtomicU64>,
    victim_empties: Vec<AtomicU64>,
    /// Thief-side steal timeouts per victim (`--faults`), the fourth
    /// outcome column of the per-victim telemetry.
    victim_timeouts: Vec<AtomicU64>,
    /// The targeted victim selector (`--victim-select targeted`):
    /// picked by the migrate thread, fed replies by the comm thread.
    /// Uniform mode never takes this lock.
    victim_sel: Mutex<VictimSelector>,
    inflight_steals: AtomicUsize,
    /// Monotone request-id counter for [`steal_req_id`].
    next_req: AtomicU64,
    /// Outstanding thief-side requests (always maintained — see
    /// [`PendingSteal`]) and their terminal resolutions (`--faults`
    /// only), under one lock (see [`StealBook`]).
    steal_book: Mutex<StealBook>,
    /// Victim-side request ids already served (`--faults` only):
    /// fabric-duplicated requests re-answer from the ledger instead of
    /// extracting twice.
    served_reqs: Mutex<HashSet<u64>>,
    /// Victim-side transfer ledger (`--faults` only).
    ledger: Mutex<HashMap<u64, LedgerEntry>>,
    /// Tasks parked in the ledger — a node holding unacked transfers is
    /// not passive (Safra safety: those tasks are nowhere else).
    ledger_tasks: AtomicUsize,
    /// `--faults` protocol telemetry (see [`NodeReport`]).
    steal_timeouts: AtomicU64,
    steal_retries: AtomicU64,
    ledger_reclaims: AtomicU64,
    dup_replies_suppressed: AtomicU64,
    safra: Mutex<SafraState>,
    shutdown: AtomicBool,
    polls: Mutex<Vec<PollSample>>,
    arrival_ready: Mutex<Vec<PollSample>>,
    /// ns-since-start of the last task completion (makespan).
    last_finish_ns: AtomicU64,
}

impl NodeState {
    fn passive(&self) -> bool {
        self.executing_count.load(Ordering::SeqCst) == 0
            && self.queue.is_empty()
            // Unacked granted transfers: the tasks exist only in this
            // node's ledger, so the node must stay active until the
            // thief's ack retires them or its nack reclaims them.
            && self.ledger_tasks.load(Ordering::SeqCst) == 0
    }
}

/// The in-process cluster. Build with [`Cluster::run`] — it owns the
/// whole lifecycle (spawn, execute, detect termination, join, report).
pub struct Cluster;

struct Shared {
    graph: Arc<dyn TaskGraph>,
    net: Arc<Network>,
    nodes: Vec<Arc<NodeState>>,
    cfg: ClusterConfig,
    start: Instant,
}

impl Cluster {
    /// Execute `graph` with `executor` task bodies; blocks until
    /// distributed termination and returns the merged report.
    pub fn run(
        graph: Arc<dyn TaskGraph>,
        cfg: ClusterConfig,
        executor: Arc<dyn super::TaskExecutor>,
    ) -> RunReport {
        let n = graph.num_nodes();
        let (net, mailboxes) = Network::new_with_faults(n, cfg.link, cfg.faults, cfg.seed);
        let nodes: Vec<Arc<NodeState>> = (0..n)
            .map(|i| {
                Arc::new(NodeState {
                    id: NodeId(i as u32),
                    queue: cfg.sched.build_with(cfg.workers_per_node, cfg.pool_floor),
                    idle: Mutex::new(()),
                    queue_cv: Condvar::new(),
                    parked: AtomicUsize::new(0),
                    tracker: Mutex::new(ActivationTracker::new()),
                    executing_count: AtomicUsize::new(0),
                    executing_local_succ: AtomicUsize::new(0),
                    tasks_done: AtomicU64::new(0),
                    exec_sum_ns: AtomicU64::new(0),
                    exec_ewma_us_bits: AtomicU64::new(0),
                    class_est_us_bits: std::array::from_fn(|_| AtomicU64::new(0)),
                    class_samples: std::array::from_fn(|_| AtomicU64::new(0)),
                    remote_avg_us_bits: AtomicU64::new(0),
                    remote_avg_samples: AtomicU64::new(0),
                    digest_merges: AtomicU64::new(0),
                    digest_class_adoptions: AtomicU64::new(0),
                    activation_ready_batches: AtomicU64::new(0),
                    busy_ns: AtomicU64::new(0),
                    steal: Mutex::new(StealStats::default()),
                    victim_grants: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_wt_denials: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_empties: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_timeouts: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_sel: Mutex::new(
                        VictimSelector::new(i, n.max(2), thief_rng(cfg.seed, i))
                            .with_link(cfg.link.latency_us, cfg.link.bw_bytes_per_us),
                    ),
                    inflight_steals: AtomicUsize::new(0),
                    next_req: AtomicU64::new(0),
                    steal_book: Mutex::new(StealBook::default()),
                    served_reqs: Mutex::new(HashSet::new()),
                    ledger: Mutex::new(HashMap::new()),
                    ledger_tasks: AtomicUsize::new(0),
                    steal_timeouts: AtomicU64::new(0),
                    steal_retries: AtomicU64::new(0),
                    ledger_reclaims: AtomicU64::new(0),
                    dup_replies_suppressed: AtomicU64::new(0),
                    safra: Mutex::new(SafraState::new(NodeId(i as u32), n)),
                    shutdown: AtomicBool::new(false),
                    polls: Mutex::new(Vec::new()),
                    arrival_ready: Mutex::new(Vec::new()),
                    last_finish_ns: AtomicU64::new(0),
                })
            })
            .collect();

        let shared = Arc::new(Shared {
            graph: graph.clone(),
            net: net.clone(),
            nodes: nodes.clone(),
            cfg,
            start: Instant::now(),
        });

        // Seed roots at their owners.
        for root in graph.roots() {
            let owner = graph.owner(root);
            let node = &nodes[owner.idx()];
            node.tracker.lock().unwrap().mark_root(root);
            enqueue(node, graph.as_ref(), root);
        }

        let mut handles = Vec::new();
        let mut boxes = mailboxes;
        // drain in reverse so indices stay valid
        let mut mail: Vec<Option<NodeMailbox>> = boxes.drain(..).map(Some).collect();
        for i in 0..n {
            let node = nodes[i].clone();
            let sh = shared.clone();
            let mb = mail[i].take().unwrap();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("comm-{i}"))
                    .spawn(move || comm_loop(sh, node, mb))
                    .unwrap(),
            );
            for w in 0..cfg.workers_per_node {
                let node = nodes[i].clone();
                let sh = shared.clone();
                let ex = executor.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("worker-{i}.{w}"))
                        .spawn(move || worker_loop(sh, node, w, ex))
                        .unwrap(),
                );
            }
            if cfg.migrate.enabled && n > 1 {
                let node = nodes[i].clone();
                let sh = shared.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("migrate-{i}"))
                        .spawn(move || migrate_loop(sh, node))
                        .unwrap(),
                );
            }
        }

        for h in handles {
            let _ = h.join();
        }
        net.shutdown();

        // Self-healing postconditions. Requests still pending at
        // shutdown (their reply sat undelivered in a mailbox, or was
        // dropped by the fault plan) are abandoned now, reclaiming
        // their inflight slots — then every slot must be accounted for
        // and the transfer ledger empty: exactly-once conservation has
        // no residue under any fault pattern.
        for nd in &nodes {
            let abandoned = nd.steal_book.lock().unwrap().pending.drain().count();
            if abandoned > 0 {
                nd.inflight_steals.fetch_sub(abandoned, Ordering::SeqCst);
            }
            assert_eq!(
                nd.inflight_steals.load(Ordering::SeqCst),
                0,
                "node {} leaked inflight-steal slots",
                nd.id.0
            );
            assert!(
                nd.ledger.lock().unwrap().is_empty(),
                "node {} shut down with transfer-ledger residue",
                nd.id.0
            );
            assert_eq!(nd.ledger_tasks.load(Ordering::SeqCst), 0);
        }

        let makespan_ns = nodes
            .iter()
            .map(|nd| nd.last_finish_ns.load(Ordering::SeqCst))
            .max()
            .unwrap_or(0);

        let executed: u64 = nodes
            .iter()
            .map(|nd| nd.tasks_done.load(Ordering::SeqCst))
            .sum();
        if let Some(total) = graph.total_tasks() {
            assert_eq!(executed, total, "cluster lost tasks");
        }

        RunReport {
            workload: graph.name().to_string(),
            makespan_us: makespan_ns as f64 / 1e3,
            total_tasks: executed,
            workers_per_node: cfg.workers_per_node,
            link: cfg.link,
            events: 0,
            deliver_events: 0,
            faults_dropped: net.faults_dropped.load(Ordering::Relaxed),
            faults_duplicated: net.faults_duplicated.load(Ordering::Relaxed),
            nodes: nodes
                .iter()
                .map(|nd| {
                    let done = nd.tasks_done.load(Ordering::SeqCst);
                    let sum_ns = nd.exec_sum_ns.load(Ordering::SeqCst);
                    NodeReport {
                        tasks_executed: done,
                        busy_us: nd.busy_ns.load(Ordering::SeqCst) as f64 / 1e3,
                        avg_exec_us: if done > 0 {
                            sum_ns as f64 / done as f64 / 1e3
                        } else {
                            0.0
                        },
                        class_est_us: std::array::from_fn(|c| {
                            f64::from_bits(nd.class_est_us_bits[c].load(Ordering::Relaxed))
                        }),
                        digest_merges: nd.digest_merges.load(Ordering::Relaxed),
                        digest_class_adoptions: nd.digest_class_adoptions.load(Ordering::Relaxed),
                        activation_ready_batches: nd
                            .activation_ready_batches
                            .load(Ordering::Relaxed),
                        steal: *nd.steal.lock().unwrap(),
                        victim_grants: nd
                            .victim_grants
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_wt_denials: nd
                            .victim_wt_denials
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_empties: nd
                            .victim_empties
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_timeouts: nd
                            .victim_timeouts
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        steal_timeouts: nd.steal_timeouts.load(Ordering::Relaxed),
                        steal_retries: nd.steal_retries.load(Ordering::Relaxed),
                        ledger_reclaims: nd.ledger_reclaims.load(Ordering::Relaxed),
                        dup_replies_suppressed: nd
                            .dup_replies_suppressed
                            .load(Ordering::Relaxed),
                        sched: nd.queue.stats(),
                        polls: std::mem::take(&mut nd.polls.lock().unwrap()),
                        arrival_ready: std::mem::take(&mut nd.arrival_ready.lock().unwrap()),
                    }
                })
                .collect(),
        }
    }
}

/// Insert a ready task (with its steal-accounting meta) and wake a
/// worker.
fn enqueue(node: &NodeState, graph: &dyn TaskGraph, task: TaskDesc) {
    node.queue
        .insert_meta(task, graph.priority(task), TaskMeta::of(graph, task));
    // Only touch the idle lock when someone is (about to be) parked.
    // SeqCst pairing with the worker makes this sound: the worker
    // bumps `parked` before re-checking emptiness, we insert before
    // reading `parked` — one of the two always observes the other.
    if node.parked.load(Ordering::SeqCst) > 0 {
        // The lock orders us against a worker between its emptiness
        // re-check and its wait, so the notify cannot fall in the gap.
        let _idle = node.idle.lock().unwrap();
        node.queue_cv.notify_one();
    }
}

/// Insert a batch of ready tasks under one queue-lock acquisition
/// (booked to `site` — steal-reply re-enqueue or activation ready set),
/// then wake workers. Mirrors [`enqueue`], including the parked-worker
/// SeqCst protocol; `notify_all` because a batch can feed several
/// parked workers at once.
fn enqueue_batch(node: &NodeState, graph: &dyn TaskGraph, tasks: &[TaskDesc], site: BatchSite) {
    node.queue
        .insert_batch_at(site, &TaskMeta::batch_of(graph, tasks));
    if node.parked.load(Ordering::SeqCst) > 0 {
        let _idle = node.idle.lock().unwrap();
        node.queue_cv.notify_all();
    }
}

/// Deliver one local activation; enqueue if it completed the in-degree.
fn activate_local(node: &NodeState, graph: &dyn TaskGraph, task: TaskDesc) {
    let ready = node.tracker.lock().unwrap().activate(graph, task);
    if ready {
        enqueue(node, graph, task);
    }
}

/// Deliver a coalesced activation batch under a single tracker lock,
/// then enqueue the whole ready set through one batched queue insert —
/// the batch-first activation pipeline: one tracker lock and one
/// queue-lock acquisition per delivery, however many tasks became
/// ready.
fn activate_local_batch(node: &NodeState, graph: &dyn TaskGraph, tasks: &[TaskDesc]) {
    let mut ready = Vec::new();
    {
        let mut tracker = node.tracker.lock().unwrap();
        for &t in tasks {
            if tracker.activate(graph, t) {
                ready.push(t);
            }
        }
    }
    if !ready.is_empty() {
        node.activation_ready_batches.fetch_add(1, Ordering::Relaxed);
        enqueue_batch(node, graph, &ready, BatchSite::Activation);
    }
}

/// Snapshot this node's execution-time knowledge for a granted steal
/// reply (`--share-estimates`): the node-wide estimate the gate just
/// ran on, plus the per-class table and its sample weights — handed to
/// the shared sample-capping [`EstimateDigest::snapshot`] constructor.
fn steal_digest(node: &NodeState, avg_us: f64, avg_samples: u64) -> EstimateDigest {
    EstimateDigest::snapshot(
        avg_us,
        avg_samples,
        std::array::from_fn(|c| {
            f64::from_bits(node.class_est_us_bits[c].load(Ordering::Relaxed))
        }),
        std::array::from_fn(|c| node.class_samples[c].load(Ordering::Relaxed)),
    )
}

/// Merge a steal-reply [`EstimateDigest`] into this node's estimator
/// tables (`--share-estimates`): the atomic twin of the shared
/// [`EstimateDigest::merge_into`] loop — per seeded class entry one CAS
/// loop over the f64-bits cell through the same [`merge_estimate`] rule
/// (the scheme `class_estimate_update` uses at task finish), plus the
/// node-wide cold-start seed. The sample-count read and the estimate
/// CAS are two operations, so a concurrent task finish can interleave —
/// the blend weight is then off by that one in-flight sample, which
/// only nudges a heuristic; counts and estimates both stay
/// monotone-consistent.
fn merge_digest(node: &NodeState, digest: &EstimateDigest) {
    let mut adoptions = 0u64;
    for c in 0..TaskClass::COUNT {
        let (remote_us, remote_n) = (digest.class_est_us[c], digest.class_samples[c]);
        if remote_n == 0 || remote_us <= 0.0 {
            continue; // unseeded at the victim: nothing to learn
        }
        let local_n = node.class_samples[c].load(Ordering::Relaxed);
        let mut adopted = false;
        let _ = node.class_est_us_bits[c].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| {
                let local_us = f64::from_bits(bits);
                adopted = !(local_n > 0 && local_us > 0.0);
                let (merged, _) = merge_estimate(local_us, local_n, remote_us, remote_n);
                Some(merged.to_bits())
            },
        );
        node.class_samples[c].fetch_add(remote_n, Ordering::Relaxed);
        adoptions += adopted as u64;
    }
    if digest.avg_samples > 0 && digest.avg_us > 0.0 {
        let local_n = node.remote_avg_samples.load(Ordering::Relaxed);
        let _ = node.remote_avg_us_bits.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| {
                let (merged, _) = merge_estimate(
                    f64::from_bits(bits),
                    local_n,
                    digest.avg_us,
                    digest.avg_samples,
                );
                Some(merged.to_bits())
            },
        );
        node.remote_avg_samples
            .fetch_add(digest.avg_samples, Ordering::Relaxed);
    }
    node.digest_merges.fetch_add(1, Ordering::Relaxed);
    node.digest_class_adoptions
        .fetch_add(adoptions, Ordering::Relaxed);
}

fn worker_loop(
    sh: Arc<Shared>,
    node: Arc<NodeState>,
    worker: usize,
    ex: Arc<dyn super::TaskExecutor>,
) {
    let graph = sh.graph.as_ref();
    loop {
        if node.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Claim execution intent BEFORE popping: from the instant a
        // task leaves the queue until it is accounted as executing, the
        // node must never look passive — otherwise a Safra token round
        // could declare termination with the task in flight.
        node.executing_count.fetch_add(1, Ordering::SeqCst);
        // select (worker index = shard hint for the sharded backend)
        let Some(task) = node.queue.select(worker) else {
            node.executing_count.fetch_sub(1, Ordering::SeqCst);
            let idle = node.idle.lock().unwrap();
            // Declare ourselves parked BEFORE re-checking emptiness:
            // `enqueue` reads the counter after its insert, so either
            // it sees us parked (and notifies) or we see its task
            // (and skip the wait). The timeout is belt-and-braces.
            node.parked.fetch_add(1, Ordering::SeqCst);
            if node.queue.is_empty() && !node.shutdown.load(Ordering::SeqCst) {
                let _unused = node
                    .queue_cv
                    .wait_timeout(idle, Duration::from_micros(200))
                    .unwrap();
            }
            node.parked.fetch_sub(1, Ordering::SeqCst);
            continue;
        };
        if sh.cfg.record_polls {
            let sample = PollSample {
                t_us: sh.start.elapsed().as_nanos() as f64 / 1e3,
                ready: node.queue.len() as u32,
            };
            node.polls.lock().unwrap().push(sample);
        }

        // Successor derivation is a pure function of the descriptor, so
        // it can run before the body: the count feeds the O(1)
        // starvation view while the task executes, and the same vec
        // drives the activation fan-out afterwards.
        let succs = graph.successors(task);
        let dynamic = graph.dynamic_placement();
        let local_succ = succs
            .iter()
            .filter(|s| dynamic || graph.owner(**s) == node.id)
            .count();
        node.executing_local_succ
            .fetch_add(local_succ, Ordering::SeqCst);

        let t0 = Instant::now();
        ex.execute(node.id, task);
        let dur_ns = t0.elapsed().as_nanos() as u64;

        // Propagate activations BEFORE leaving the executing state so the
        // node is never "passive" with un-sent messages (Safra safety).
        // Remote successors sharing a destination coalesce into one
        // ActivateBatch message (one wire header, one Safra deficit
        // entry, one tracker lock at the receiver); local successors
        // coalesce the same way into one tracker lock + one batched
        // queue insert. `--batch-activations false` restores the
        // per-edge protocol on both paths for ablations.
        let mut local: Vec<TaskDesc> = Vec::new();
        let mut remote: Vec<(NodeId, Vec<TaskDesc>)> = Vec::new();
        for s in succs {
            let dest = if dynamic { node.id } else { graph.owner(s) };
            if dest == node.id {
                if sh.cfg.batch_activations {
                    local.push(s);
                } else {
                    activate_local(&node, graph, s);
                }
            } else if sh.cfg.batch_activations {
                match remote.iter_mut().find(|(d, _)| *d == dest) {
                    Some((_, bucket)) => bucket.push(s),
                    None => remote.push((dest, vec![s])),
                }
            } else {
                node.safra.lock().unwrap().on_send();
                sh.net.send(node.id, dest, Msg::Activate { task: s });
            }
        }
        if !local.is_empty() {
            activate_local_batch(&node, graph, &local);
        }
        for (dest, tasks) in remote {
            node.safra.lock().unwrap().on_send();
            let msg = if tasks.len() == 1 {
                Msg::Activate { task: tasks[0] }
            } else {
                Msg::ActivateBatch { tasks }
            };
            sh.net.send(node.id, dest, msg);
        }

        node.executing_local_succ
            .fetch_sub(local_succ, Ordering::SeqCst);
        node.executing_count.fetch_sub(1, Ordering::SeqCst);
        node.tasks_done.fetch_add(1, Ordering::SeqCst);
        node.exec_sum_ns.fetch_add(dur_ns, Ordering::SeqCst);
        if sh.cfg.migrate.exec_ewma {
            // CAS loop over the f64 bits: lock-free per-finish EWMA
            // update (contended only by the other workers' finishes).
            let dur_us = dur_ns as f64 / 1e3;
            let _ = node
                .exec_ewma_us_bits
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                    Some(ewma_update(f64::from_bits(bits), dur_us).to_bits())
                });
        }
        if sh.cfg.migrate.track_per_class() {
            // Same CAS-over-bits scheme, one cell per class, through the
            // shared update rule so the DES table cannot diverge. Also
            // maintained under --share-estimates alone: a victim with an
            // empty table would have nothing worth shipping to thieves.
            let dur_us = dur_ns as f64 / 1e3;
            let cell = &node.class_est_us_bits[task.class.idx()];
            let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some(class_estimate_update(f64::from_bits(bits), dur_us).to_bits())
            });
            node.class_samples[task.class.idx()].fetch_add(1, Ordering::Relaxed);
        }
        node.busy_ns.fetch_add(dur_ns, Ordering::SeqCst);
        node.last_finish_ns
            .fetch_max(sh.start.elapsed().as_nanos() as u64, Ordering::SeqCst);
    }
}

fn comm_loop(sh: Arc<Shared>, node: Arc<NodeState>, mailbox: NodeMailbox) {
    let graph = sh.graph.as_ref();
    let mut last_probe = Instant::now();
    loop {
        if node.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let env = mailbox.recv_timeout(Duration::from_micros(200));
        if let Some(env) = env {
            // FaultMark contract (see `crate::faults`): a Dropped
            // envelope is delivered for Safra accounting only — count
            // the receive, discard the payload. A Duplicate is the
            // fabric's extra copy — process it (the protocol's request
            // ids dedup it) but do NOT count it, so the message deficit
            // stays balanced at one receive per send.
            if env.msg.is_basic() && env.fault != FaultMark::Duplicate {
                node.safra.lock().unwrap().on_receive();
            }
            if env.fault == FaultMark::Dropped {
                continue;
            }
            // A steal reply's sender IS the victim it reports on.
            let src = env.src;
            match env.msg {
                Msg::Activate { task } => activate_local(&node, graph, task),
                Msg::ActivateBatch { tasks } => activate_local_batch(&node, graph, &tasks),
                Msg::StealRequest { thief, req } => {
                    let faults_on = sh.cfg.faults.enabled;
                    if faults_on && !node.served_reqs.lock().unwrap().insert(req) {
                        // Fabric-duplicated request: the first copy was
                        // served. If its grant still awaits the ack,
                        // retransmit the stored reply verbatim (the
                        // thief dedups on `req`); otherwise the
                        // original answer already covers this copy.
                        let resend = node
                            .ledger
                            .lock()
                            .unwrap()
                            .get(&req)
                            .map(|e| e.reply.clone());
                        if let Some(msg) = resend {
                            node.safra.lock().unwrap().on_send();
                            sh.net.send(node.id, thief, msg);
                        }
                        continue;
                    }
                    let workers = sh.cfg.workers_per_node;
                    // The gate's execution-time estimates (shared policy
                    // helpers, so the DES cannot diverge): EWMA or
                    // running mean node-wide (digest-seeded while this
                    // node is cold under --share-estimates), plus the
                    // per-class table under --exec-per-class — all O(1)
                    // reads of incrementally-maintained state.
                    let done = node.tasks_done.load(Ordering::SeqCst);
                    let ewma = f64::from_bits(node.exec_ewma_us_bits.load(Ordering::Relaxed));
                    let est = ExecSnapshot {
                        avg_us: exec_estimate_seeded_us(
                            sh.cfg.migrate.exec_ewma,
                            ewma,
                            node.exec_sum_ns.load(Ordering::SeqCst) as f64 / 1e3,
                            done,
                            f64::from_bits(node.remote_avg_us_bits.load(Ordering::Relaxed)),
                        ),
                        per_class: sh.cfg.migrate.exec_per_class.then(|| {
                            std::array::from_fn(|c| {
                                f64::from_bits(node.class_est_us_bits[c].load(Ordering::Relaxed))
                            })
                        }),
                    };
                    let decision = decide_steal(
                        &sh.cfg.migrate,
                        graph,
                        node.queue.as_ref(),
                        workers,
                        &est,
                        sh.cfg.link.latency_us,
                        sh.cfg.link.bw_bytes_per_us,
                    );
                    {
                        let mut st = node.steal.lock().unwrap();
                        st.requests_served += 1;
                        if decision.tasks.is_empty() {
                            if decision.denied_by_waiting_time {
                                st.waiting_time_denials += 1;
                            } else {
                                st.empty_denials += 1;
                            }
                        } else {
                            st.tasks_migrated += decision.tasks.len() as u64;
                            st.payload_bytes += decision.payload_bytes;
                        }
                    }
                    // Execution-time knowledge travels with stolen work
                    // (--share-estimates): a granted reply carries this
                    // victim's estimate digest, priced into wire_bytes.
                    let digest = (sh.cfg.migrate.share_estimates && !decision.tasks.is_empty())
                        .then(|| steal_digest(&node, est.avg_us, done));
                    let granted = decision.tasks.clone();
                    let reply = Msg::StealReply {
                        req,
                        tasks: decision.tasks,
                        payload_bytes: decision.payload_bytes,
                        digest,
                        denied_by_waiting_time: decision.denied_by_waiting_time,
                    };
                    if faults_on && !granted.is_empty() {
                        // Park the granted tasks in the transfer ledger
                        // until the thief acks: order matters — the
                        // tasks must be accounted somewhere before the
                        // reply leaves, or a dropped reply could race a
                        // Safra probe into a false termination.
                        node.ledger_tasks.fetch_add(granted.len(), Ordering::SeqCst);
                        node.ledger.lock().unwrap().insert(
                            req,
                            LedgerEntry {
                                thief,
                                tasks: granted,
                                reply: reply.clone(),
                                sent_at: Instant::now(),
                                attempt: 0,
                            },
                        );
                    }
                    node.safra.lock().unwrap().on_send();
                    sh.net.send(node.id, thief, reply);
                }
                Msg::StealReply {
                    req,
                    tasks,
                    digest,
                    denied_by_waiting_time,
                    ..
                } => {
                    let faults_on = sh.cfg.faults.enabled;
                    // Resolve the reply atomically against the timeout
                    // scan (one StealBook lock): either this request is
                    // already resolved — duplicate/late reply, suppress
                    // and re-answer with the ack the victim's
                    // retransmit loop is waiting for — or this reply
                    // resolves it now.
                    let granted = !tasks.is_empty();
                    let dup = {
                        let mut book = node.steal_book.lock().unwrap();
                        match book.resolved.get(&req).copied() {
                            Some(res) => Some(res),
                            None => {
                                // Release the inflight slot only on a
                                // matched request: an unmatched reply
                                // must not push the counter negative —
                                // the pre-PR 7 accounting decremented
                                // unconditionally and leaked on every
                                // abandoned path.
                                if book.pending.remove(&req).is_some() {
                                    node.inflight_steals.fetch_sub(1, Ordering::SeqCst);
                                }
                                if faults_on {
                                    book.resolved.insert(
                                        req,
                                        if granted {
                                            StealResolution::AckedGrant
                                        } else {
                                            StealResolution::AckedDenial
                                        },
                                    );
                                }
                                None
                            }
                        }
                    };
                    if let Some(res) = dup {
                        node.dup_replies_suppressed.fetch_add(1, Ordering::Relaxed);
                        let ack = match res {
                            StealResolution::AckedGrant => Some(true),
                            StealResolution::Abandoned => Some(false),
                            StealResolution::AckedDenial => None,
                        };
                        if let Some(accepted) = ack {
                            node.safra.lock().unwrap().on_send();
                            sh.net
                                .send(node.id, src, Msg::TransferAck { req, accepted });
                        }
                        continue;
                    }
                    if faults_on && granted {
                        // Ack the transfer so the victim retires its
                        // ledger entry; denials keep none.
                        node.safra.lock().unwrap().on_send();
                        sh.net
                            .send(node.id, src, Msg::TransferAck { req, accepted: true });
                    }
                    // Per-victim outcome telemetry (always) and the
                    // targeted selector's history (only when it will be
                    // consulted — uniform mode never takes the lock).
                    let outcome = classify_reply(!tasks.is_empty(), denied_by_waiting_time);
                    let table = match outcome {
                        VictimOutcome::Granted => &node.victim_grants,
                        VictimOutcome::DeniedWaitingTime => &node.victim_wt_denials,
                        VictimOutcome::DeniedEmpty => &node.victim_empties,
                        VictimOutcome::TimedOut => &node.victim_timeouts,
                    };
                    table[src.idx()].fetch_add(1, Ordering::Relaxed);
                    if sh.cfg.migrate.victim_select == VictimSelect::Targeted {
                        node.victim_sel.lock().unwrap().record(
                            src.idx(),
                            outcome,
                            digest.as_ref().map(|d| d.avg_us),
                        );
                    }
                    // Merge the victim's estimates BEFORE the stolen
                    // tasks enter the queue: the very next gate decision
                    // on this node must already see the seeded table.
                    if let Some(d) = &digest {
                        merge_digest(&node, d);
                    }
                    if !tasks.is_empty() {
                        {
                            let mut st = node.steal.lock().unwrap();
                            st.successful_steals += 1;
                            st.tasks_received += tasks.len() as u64;
                        }
                        if sh.cfg.record_polls {
                            // Fig. 3 instrumentation: queue length each
                            // stolen task would have seen arriving
                            // one-by-one (len, len+1, …), sampled before
                            // the batch insert.
                            let ready = node.queue.len() as u32;
                            let t_us = sh.start.elapsed().as_nanos() as f64 / 1e3;
                            let mut ar = node.arrival_ready.lock().unwrap();
                            for k in 0..tasks.len() as u32 {
                                ar.push(PollSample {
                                    t_us,
                                    ready: ready + k,
                                });
                            }
                        }
                        // Recreate the stolen tasks locally (same uids)
                        // in one batched insert: one queue-lock
                        // acquisition per reply, not one per task.
                        enqueue_batch(&node, graph, &tasks, BatchSite::StealReply);
                    }
                }
                Msg::TransferAck { req, accepted } => {
                    // Retire (ack) or reclaim (nack) the ledger entry.
                    // Unknown req = the entry was already retired by an
                    // earlier copy of this ack — idempotent no-op.
                    let entry = node.ledger.lock().unwrap().remove(&req);
                    if let Some(entry) = entry {
                        if !accepted {
                            // The thief abandoned the transfer: the
                            // tasks come home through the same batch
                            // site a gate denial uses. Reinsert before
                            // releasing the ledger accounting so the
                            // node never looks passive in between.
                            node.ledger_reclaims.fetch_add(1, Ordering::Relaxed);
                            enqueue_batch(&node, graph, &entry.tasks, BatchSite::GateDenial);
                        }
                        node.ledger_tasks
                            .fetch_sub(entry.tasks.len(), Ordering::SeqCst);
                    }
                }
                Msg::Token(tok) => {
                    let passive = node.passive();
                    let action = node.safra.lock().unwrap().on_token(tok, passive);
                    perform_safra_action(&sh, &node, action);
                }
                Msg::Shutdown => {
                    node.shutdown.store(true, Ordering::SeqCst);
                    node.queue_cv.notify_all();
                    return;
                }
            }
        }

        // Parked token: retry forwarding whenever we might be passive.
        let passive = node.passive();
        if passive {
            let action = node.safra.lock().unwrap().try_forward(true);
            perform_safra_action(&sh, &node, action);
        }

        // Leader initiates probes while passive (rate-limited).
        if node.id.idx() == 0 && passive && last_probe.elapsed() > Duration::from_micros(500) {
            last_probe = Instant::now();
            let action = node.safra.lock().unwrap().leader_start_probe(true);
            perform_safra_action(&sh, &node, action);
        }
    }
}

fn perform_safra_action(sh: &Arc<Shared>, node: &Arc<NodeState>, action: SafraAction) {
    match action {
        SafraAction::None => {}
        SafraAction::Forward(dst, tok) => {
            sh.net.send(node.id, dst, Msg::Token(tok));
        }
        SafraAction::Terminate => {
            // Leader announces shutdown to everyone, then stops itself.
            sh.net.broadcast_from(node.id, Msg::Shutdown);
            node.shutdown.store(true, Ordering::SeqCst);
            node.queue_cv.notify_all();
        }
    }
}

fn migrate_loop(sh: Arc<Shared>, node: Arc<NodeState>) {
    let mut rng = thief_rng(sh.cfg.seed, node.id.idx());
    let n = sh.nodes.len();
    let poll = Duration::from_nanos((sh.cfg.migrate.poll_interval_us * 1e3) as u64);
    loop {
        if node.shutdown.load(Ordering::SeqCst) {
            return;
        }
        std::thread::sleep(poll);
        if sh.cfg.faults.enabled {
            scan_steal_timeouts(&sh, &node);
            scan_ledger_acks(&sh, &node);
        }
        // Both fields are O(1) counter reads — the starvation poll no
        // longer walks the executing set calling successors() per task.
        let view = StarvationView {
            ready: node.queue.len(),
            executing_local_successors: match sh.cfg.migrate.thief {
                crate::migrate::ThiefPolicy::ReadyOnly => 0,
                crate::migrate::ThiefPolicy::ReadySuccessors => {
                    node.executing_local_succ.load(Ordering::SeqCst)
                }
            },
        };
        if is_starving(sh.cfg.migrate.thief, view)
            && node.inflight_steals.load(Ordering::SeqCst) < sh.cfg.migrate.max_inflight
        {
            node.inflight_steals.fetch_add(1, Ordering::SeqCst);
            node.steal.lock().unwrap().requests_sent += 1;
            let victim = match sh.cfg.migrate.victim_select {
                VictimSelect::Uniform => NodeId(rng.pick_other(n, node.id.idx()) as u32),
                VictimSelect::Targeted => {
                    // The selector's fallback win per stolen task is the
                    // thief's own node-wide estimate — the same quantity
                    // the victim-side gate runs on, digest-seeded while
                    // this node is still cold under --share-estimates.
                    let done = node.tasks_done.load(Ordering::SeqCst);
                    let ewma = f64::from_bits(node.exec_ewma_us_bits.load(Ordering::Relaxed));
                    let fallback = exec_estimate_seeded_us(
                        sh.cfg.migrate.exec_ewma,
                        ewma,
                        node.exec_sum_ns.load(Ordering::SeqCst) as f64 / 1e3,
                        done,
                        f64::from_bits(node.remote_avg_us_bits.load(Ordering::Relaxed)),
                    );
                    NodeId(node.victim_sel.lock().unwrap().pick(fallback) as u32)
                }
            };
            let req = steal_req_id(node.id.0, node.next_req.fetch_add(1, Ordering::Relaxed));
            node.steal_book.lock().unwrap().pending.insert(
                req,
                PendingSteal {
                    victim,
                    sent_at: Instant::now(),
                    attempt: 0,
                },
            );
            node.safra.lock().unwrap().on_send();
            sh.net
                .send(node.id, victim, Msg::StealRequest { thief: node.id, req });
        }
    }
}

/// Thief-side timeout sweep (`--faults` only, from the migrate
/// thread): every pending request older than its
/// [`steal_timeout_us`] deadline is abandoned — nacked so the victim
/// reclaims any parked grant — and, while the retry budget lasts,
/// re-issued to the same victim under a fresh request id with the
/// inflight slot retained. Budget exhausted → the slot is released.
fn scan_steal_timeouts(sh: &Arc<Shared>, node: &Arc<NodeState>) {
    let now = Instant::now();
    let mc = &sh.cfg.migrate;
    let expired: Vec<(u64, PendingSteal)> = node
        .steal_book
        .lock()
        .unwrap()
        .pending
        .iter()
        .filter(|(_, p)| {
            now.duration_since(p.sent_at).as_secs_f64() * 1e6
                >= steal_timeout_us(
                    sh.cfg.link.latency_us,
                    sh.cfg.link.bw_bytes_per_us,
                    mc.migrate_overhead_us,
                    mc.poll_interval_us,
                    p.attempt,
                )
        })
        .map(|(r, p)| (*r, *p))
        .collect();
    for (req, p) in expired {
        // Claim the request atomically against the comm thread's
        // resolve (one StealBook lock): remove it from pending and
        // mark it Abandoned in one critical section, so a racing reply
        // is suppressed (and re-nacked) instead of double-resolving.
        // If the remove misses, the reply won — this timeout never
        // happened.
        let claimed = {
            let mut book = node.steal_book.lock().unwrap();
            if book.pending.remove(&req).is_some() {
                book.resolved.insert(req, StealResolution::Abandoned);
                true
            } else {
                false
            }
        };
        if !claimed {
            continue;
        }
        node.steal_timeouts.fetch_add(1, Ordering::Relaxed);
        node.victim_timeouts[p.victim.idx()].fetch_add(1, Ordering::Relaxed);
        if mc.victim_select == VictimSelect::Targeted {
            node.victim_sel.lock().unwrap().record(
                p.victim.idx(),
                VictimOutcome::TimedOut,
                None,
            );
        }
        // A timeout is a denial-flavored signal to the scheduler: the
        // fabric just proved migration is slower than planned.
        node.queue.feedback(StealOutcome::TimedOut);
        // Nack so a grant parked in the victim's ledger comes home.
        node.safra.lock().unwrap().on_send();
        sh.net
            .send(node.id, p.victim, Msg::TransferAck { req, accepted: false });
        if p.attempt < THIEF_RETRY_BUDGET {
            let retry = steal_req_id(node.id.0, node.next_req.fetch_add(1, Ordering::Relaxed));
            node.steal_book.lock().unwrap().pending.insert(
                retry,
                PendingSteal {
                    victim: p.victim,
                    sent_at: Instant::now(),
                    attempt: p.attempt + 1,
                },
            );
            node.steal_retries.fetch_add(1, Ordering::Relaxed);
            node.steal.lock().unwrap().requests_sent += 1;
            node.safra.lock().unwrap().on_send();
            sh.net.send(
                node.id,
                p.victim,
                Msg::StealRequest {
                    thief: node.id,
                    req: retry,
                },
            );
        } else {
            node.inflight_steals.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Victim-side ack sweep (`--faults` only, from the migrate thread):
/// ledger entries whose ack is overdue get their stored reply
/// retransmitted verbatim, with the same capped backoff as the thief's
/// timeout — and *unbounded* retries: the victim never unilaterally
/// reclaims (the thief may be executing the tasks), only a nack does.
/// With per-class fault probabilities capped below 1, some retransmit
/// eventually lands and its ack (or nack) retires the entry w.p. 1.
fn scan_ledger_acks(sh: &Arc<Shared>, node: &Arc<NodeState>) {
    let now = Instant::now();
    let mc = &sh.cfg.migrate;
    let resend: Vec<(NodeId, Msg)> = {
        let mut ledger = node.ledger.lock().unwrap();
        let mut out = Vec::new();
        for (_, e) in ledger.iter_mut() {
            let deadline = steal_timeout_us(
                sh.cfg.link.latency_us,
                sh.cfg.link.bw_bytes_per_us,
                mc.migrate_overhead_us,
                mc.poll_interval_us,
                e.attempt,
            );
            if now.duration_since(e.sent_at).as_secs_f64() * 1e6 >= deadline {
                e.sent_at = now;
                e.attempt += 1;
                out.push((e.thief, e.reply.clone()));
            }
        }
        out
    };
    for (thief, reply) in resend {
        node.safra.lock().unwrap().on_send();
        sh.net.send(node.id, thief, reply);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::executor::{NullExecutor, SpinExecutor};
    use crate::sim::CostModel;
    use crate::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

    fn chol(tiles: u32, nodes: u32) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size: 8,
            nodes,
            dense_fraction: 0.5,
            seed: 3,
            all_dense: false,
        }))
    }

    #[test]
    fn null_executor_cholesky_no_steal() {
        let g = chol(8, 2);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig::disabled(),
                ..Default::default()
            },
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
    }

    #[test]
    fn null_executor_cholesky_with_steal() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 50.0,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
        // Faults off: none of the self-healing machinery may engage.
        for n in &r.nodes {
            assert_eq!(n.steal_timeouts, 0);
            assert_eq!(n.steal_retries, 0);
            assert_eq!(n.ledger_reclaims, 0);
            assert_eq!(n.dup_replies_suppressed, 0);
            assert!(n.victim_timeouts.iter().all(|&t| t == 0));
        }
    }

    /// The acceptance scenario: an 8-node Cholesky over a fabric that
    /// drops 20% of steal replies (and duplicates 10% of everything)
    /// still executes every task exactly once — dropped grants come
    /// home through the transfer ledger's nack-reclaim, duplicated
    /// replies are suppressed by request id, and the end-of-run
    /// asserts inside [`Cluster::run`] prove zero ledger residue and
    /// zero inflight-slot leaks.
    #[test]
    fn faulty_fabric_cholesky_completes_exactly_once() {
        let g = chol(10, 8);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 50.0,
                    ..Default::default()
                },
                faults: "drop-reply=0.2,dup=0.1".parse().unwrap(),
                ..Default::default()
            },
            Arc::new(NullExecutor),
        );
        assert_eq!(
            r.tasks_total_executed(),
            total,
            "exactly-once under 20% reply loss"
        );
    }

    /// Same under an irregular workload with real (spinning) task
    /// bodies and a plan that drops *and* delays every steal-message
    /// class — the worst case for the timeout derivation, since
    /// delayed replies race the retry path.
    #[test]
    fn faulty_fabric_uts_completes_exactly_once() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 30.0,
                    ..Default::default()
                },
                faults: "drop=0.2,delay=2x,delay-p=0.3".parse().unwrap(),
                ..Default::default()
            },
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
    }

    #[test]
    fn spin_executor_uts_spreads_work() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0, // 30 µs/task
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 30.0,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let spread: u64 = r.nodes[1..].iter().map(|n| n.tasks_executed).sum();
        assert!(spread > 0, "steals moved work off node 0");
        assert!(r.total_steals().successful_steals > 0);
    }

    #[test]
    fn single_node_terminates() {
        let g = chol(5, 1);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                ..Default::default()
            },
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), 35);
    }

    /// The unbatched (per-edge) activation path stays available as an
    /// ablation and must complete every task, stealing or not.
    #[test]
    fn unbatched_activation_path_still_completes() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig {
                    workers_per_node: 2,
                    batch_activations: false,
                    migrate: if steal {
                        MigrateConfig {
                            poll_interval_us: 50.0,
                            ..Default::default()
                        }
                    } else {
                        MigrateConfig::disabled()
                    },
                    ..Default::default()
                },
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
        }
    }

    /// The closed loop end to end in the threaded runtime: an
    /// all-on-node-0 UTS run whose migrate overhead makes every steal
    /// lose the waiting-time comparison must deny heavily and raise
    /// node 0's sharded spill watermark through the feedback hook
    /// (central runs the same scenario and records the denials).
    #[test]
    fn denial_heavy_run_raises_sharded_watermark() {
        use crate::sched::SPILL_THRESHOLD;
        for sched in SchedBackend::ALL {
            let g = Arc::new(UtsGraph::new(UtsParams {
                b0: 24,
                m: 4,
                q: 0.3,
                g: 30_000.0, // 30 µs/task
                seed: 5,
                nodes: 3,
                max_depth: 18,
            }));
            let size = g.tree_size(10_000_000);
            let r = Cluster::run(
                g,
                ClusterConfig {
                    workers_per_node: 2,
                    sched,
                    migrate: MigrateConfig {
                        poll_interval_us: 30.0,
                        migrate_overhead_us: 1e9, // gate always denies
                        ..Default::default()
                    },
                    ..Default::default()
                },
                Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                    30_000.0
                })),
            );
            assert_eq!(r.tasks_total_executed(), size, "{sched:?}");
            let steals = r.total_steals();
            assert_eq!(steals.successful_steals, 0, "{sched:?}: gate denies all");
            assert!(
                steals.waiting_time_denials > 0,
                "{sched:?}: wanted denials, got {steals:?}"
            );
            let fed: u64 = r.nodes.iter().map(|n| n.sched.feedback_wt_denials).sum();
            assert!(fed > 0, "{sched:?}: denials fed back");
            if sched == SchedBackend::Sharded {
                assert!(
                    r.nodes[0].sched.watermark > SPILL_THRESHOLD as u64,
                    "denials must raise the watermark, got {}",
                    r.nodes[0].sched.watermark
                );
                // The overhead floor proves every denial from the O(1)
                // accounting, so extraction never runs — and therefore
                // never pays the all-shards fallback walk.
                let walks: u64 = r.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
                assert_eq!(walks, 0, "certain denials must skip extraction");
            }
        }
    }

    /// Thief-side steal-reply re-enqueue is one batched insert per
    /// non-empty reply (gate off, so nothing else batches).
    #[test]
    fn steal_reply_reenqueue_batches_once_per_reply() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 30.0,
                    use_waiting_time: false,
                    victim: crate::migrate::VictimPolicy::Chunk(4),
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0);
        // Per-call-site accounting keeps the reply assertion exact even
        // though activation ready sets batch on the same queues.
        let reply: Vec<_> = r
            .nodes
            .iter()
            .map(|n| n.sched.site(BatchSite::StealReply))
            .collect();
        let batches: u64 = reply.iter().map(|b| b.batches).sum();
        let saved: u64 = reply.iter().map(|b| b.saved_locks()).sum();
        assert_eq!(
            batches, steals.successful_steals,
            "exactly one batched insert per non-empty reply"
        );
        assert_eq!(saved, steals.tasks_received - steals.successful_steals);
    }

    /// The batch-first activation pipeline e2e: every non-empty ready
    /// set delivered through the batched path performs exactly one
    /// activation-site batched insert — the runtime-layer ready-set
    /// count and the scheduler-layer batch counter must agree per node
    /// — and the ablation flag restores the per-edge protocol.
    #[test]
    fn activation_ready_sets_batch_exactly_once() {
        let run = |batch: bool| {
            let g = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles: 10,
                tile_size: 8,
                nodes: 3,
                dense_fraction: 1.0,
                seed: 3,
                all_dense: true,
            }));
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig {
                    workers_per_node: 2,
                    batch_activations: batch,
                    migrate: MigrateConfig::disabled(),
                    ..Default::default()
                },
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "batch={batch}");
            r
        };
        let r = run(true);
        let mut ready_sets = 0;
        for (ix, n) in r.nodes.iter().enumerate() {
            assert_eq!(
                n.sched.site(BatchSite::Activation).batches,
                n.activation_ready_batches,
                "node {ix}: one batched insert per non-empty ready set"
            );
            ready_sets += n.activation_ready_batches;
        }
        assert!(ready_sets > 0, "dense Cholesky fan-out must batch");
        // Nothing else books the activation site.
        let unbatched = run(false);
        for n in &unbatched.nodes {
            assert_eq!(n.sched.site(BatchSite::Activation).batches, 0);
            assert_eq!(n.activation_ready_batches, 0);
        }
    }

    /// `--exec-per-class` in the threaded runtime: the gate runs on the
    /// per-class estimator table, every task still executes exactly
    /// once, and the finished classes have populated their estimates.
    #[test]
    fn exec_per_class_run_completes_and_populates_table() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let g2 = g.clone();
        let ex = SpinExecutor::new(CostModel::default_calibrated(), 8, move |t| g2.work_units(t))
            .with_time_scale(0.05);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 50.0,
                    exec_per_class: true,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(ex),
        );
        assert_eq!(r.tasks_total_executed(), total);
        let gemm_est: f64 = r
            .nodes
            .iter()
            .map(|n| n.class_est_us[TaskClass::Gemm.idx()])
            .fold(0.0, f64::max);
        assert!(gemm_est > 0.0, "GEMM completions seeded the class table");
        let uts_est: f64 = r
            .nodes
            .iter()
            .map(|n| n.class_est_us[TaskClass::UtsNode.idx()])
            .fold(0.0, f64::max);
        assert_eq!(uts_est, 0.0, "no UTS tasks ran, so no UTS estimate");
    }

    /// `--share-estimates` in the threaded runtime: every granted steal
    /// reply carries the victim's digest, thieves merge it (cold classes
    /// adopted), and every task still executes exactly once.
    #[test]
    fn share_estimates_run_merges_digests() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 30.0,
                    exec_per_class: true,
                    share_estimates: true,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0, "steals must land: {steals:?}");
        let merges: u64 = r.nodes.iter().map(|n| n.digest_merges).sum();
        assert_eq!(
            merges, steals.successful_steals,
            "every granted reply ships exactly one digest"
        );
        let adoptions: u64 = r.nodes.iter().map(|n| n.digest_class_adoptions).sum();
        assert!(
            adoptions > 0,
            "cold thieves must adopt the UTS class estimate"
        );
    }

    /// `--victim-select targeted` in the threaded runtime: every task
    /// still executes exactly once, steals land, and the per-victim
    /// outcome telemetry obeys its invariants — grants per node equal
    /// that node's successful steals (same code path), a node never
    /// records an outcome against itself, and at most `max_inflight`
    /// requests per node can be unanswered at shutdown.
    #[test]
    fn targeted_victim_selection_completes_and_accounts() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 30.0,
                    share_estimates: true,
                    victim_select: VictimSelect::Targeted,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0, "steals must land: {steals:?}");
        for (ix, n) in r.nodes.iter().enumerate() {
            let grants: u64 = n.victim_grants.iter().sum();
            assert_eq!(
                grants, n.steal.successful_steals,
                "node {ix}: per-victim grants mirror successful steals"
            );
            assert_eq!(n.victim_grants[ix], 0, "node {ix}: never robs itself");
            assert_eq!(n.victim_wt_denials[ix] + n.victim_empties[ix], 0);
            let replies: u64 = grants
                + n.victim_wt_denials.iter().sum::<u64>()
                + n.victim_empties.iter().sum::<u64>();
            assert!(
                replies <= n.steal.requests_sent
                    && n.steal.requests_sent - replies <= 1,
                "node {ix}: ≤ max_inflight requests unanswered at shutdown \
                 ({replies} of {})",
                n.steal.requests_sent
            );
        }
    }

    /// `--exec-ewma` in the threaded runtime: the gate runs on the
    /// observed-execution EWMA and every task still runs exactly once.
    #[test]
    fn exec_ewma_run_completes() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig {
                workers_per_node: 2,
                migrate: MigrateConfig {
                    poll_interval_us: 50.0,
                    exec_ewma: true,
                    ..Default::default()
                },
                ..Default::default()
            },
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
    }

    /// The sharded backend must run the full protocol — workers, comm,
    /// migrate thread, Safra termination — to the same task counts.
    #[test]
    fn sharded_backend_executes_every_task() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig {
                    workers_per_node: 2,
                    sched: SchedBackend::Sharded,
                    migrate: if steal {
                        MigrateConfig {
                            poll_interval_us: 50.0,
                            ..Default::default()
                        }
                    } else {
                        MigrateConfig::disabled()
                    },
                    ..Default::default()
                },
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
        }
    }

    /// The lock-free workassist backend must run the full protocol —
    /// workers, comm, migrate thread, Safra termination — to the same
    /// task counts, without ever taking a queue lock on any node.
    #[test]
    fn workassist_backend_executes_every_task_lock_free() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig {
                    workers_per_node: 2,
                    sched: SchedBackend::Workassist,
                    migrate: if steal {
                        MigrateConfig {
                            poll_interval_us: 50.0,
                            ..Default::default()
                        }
                    } else {
                        MigrateConfig::disabled()
                    },
                    ..Default::default()
                },
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
            let locks: u64 = r.nodes.iter().map(|n| n.sched.lock_acquisitions).sum();
            assert_eq!(locks, 0, "steal={steal}: workassist took a lock");
        }
    }
}
